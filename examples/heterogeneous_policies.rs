//! Scheduling-policy comparison on one heterogeneous workload.
//!
//! The paper's discussion (§V-B) argues platforms will need *"complex
//! event scheduling and filtering mechanisms to ensure acceptable
//! performance"*.  This example runs the same overload workload under
//! three policies and prints the trade-offs:
//!
//!   warm-first   — the paper's queue-scan behaviour
//!   fifo         — plain pop (ablation baseline)
//!   deadline:N   — fail-fast admission for stale events (future work)
//!
//! ```bash
//! cargo run --release --example heterogeneous_policies
//! ```

use hardless::accel::paper_all_accel;
use hardless::coordinator::cluster::{Cluster, ExecutorKind};
use hardless::metrics::summarize;
use hardless::scheduler::parse_policy;
use hardless::workload::{run_workload, synthetic_image_datasets, Workload};
use std::time::Duration;

struct Row {
    policy: String,
    succeeded: usize,
    failed: usize,
    rlat_p50: f64,
    rlat_p95: f64,
    warm_frac: f64,
    cold_starts: u64,
}

fn run_policy(policy_name: &str) -> anyhow::Result<Row> {
    let cluster = Cluster::builder()
        .time_scale(60.0)
        .policy(parse_policy(policy_name)?)
        .executors(ExecutorKind::Mock { scale: 1.0, delay: Duration::from_millis(1) })
        .node("node-1", paper_all_accel())
        .build()?;
    let datasets = synthetic_image_datasets(&cluster, 4, 5)?;
    // Short overload burst: 3.5 trps for 60 sim-s against ~3/s capacity.
    let wl = Workload::paper_protocol("tinyyolo", 1.0, 3.5, 0.05).with_datasets(datasets);
    let report = run_workload(&cluster, &wl, Duration::from_secs(180))?;
    let records = cluster.metrics.records();
    let mut s = summarize(records.iter());
    let cold_starts = cluster
        .pool_stats()
        .iter()
        .map(|(_, p)| p.cold_starts)
        .sum();
    cluster.shutdown();
    Ok(Row {
        policy: policy_name.to_string(),
        succeeded: report.succeeded,
        failed: report.completed - report.succeeded,
        rlat_p50: s.rlat.median().unwrap_or(f64::NAN),
        rlat_p95: s.rlat.p95().unwrap_or(f64::NAN),
        warm_frac: s.warm_fraction,
        cold_starts,
    })
}

fn main() -> anyhow::Result<()> {
    println!(
        "{:<16} {:>9} {:>7} {:>12} {:>12} {:>6} {:>6}",
        "policy", "succeeded", "failed", "RLat p50 ms", "RLat p95 ms", "warm%", "colds"
    );
    for policy in ["warm-first", "fifo", "deadline:6000"] {
        let r = run_policy(policy)?;
        println!(
            "{:<16} {:>9} {:>7} {:>12.0} {:>12.0} {:>5.0}% {:>6}",
            r.policy,
            r.succeeded,
            r.failed,
            r.rlat_p50,
            r.rlat_p95,
            100.0 * r.warm_frac,
            r.cold_starts
        );
    }
    println!(
        "\nwarm-first minimizes cold starts; deadline trades completions for\n\
         bounded client latency (failed = rejected-stale); fifo is the baseline."
    );
    Ok(())
}
