//! Elasticity demo: dynamic node membership under load (§IV-C).
//!
//! The paper's queue design lets worker nodes join and leave at any time —
//! *"Workers do not interact with the event queue again, which enables
//! dynamic addition and removal of worker nodes."*  This example drives a
//! steady event stream while the cluster scales:
//!
//!   phase 1: one dual-GPU node            (capacity ≈ 2.4/s)
//!   phase 2: + a second node with a VPU   (scale-out absorbs backlog)
//!   phase 3: remove the first node        (scale-in; work keeps flowing)
//!   phase 4: remove all nodes             (scale-to-zero; events queue up)
//!   phase 5: one node returns             (queued work drains)
//!
//! ```bash
//! cargo run --release --example elastic_scaling
//! ```

use hardless::accel::{paper_dualgpu, AcceleratorProfile, Device, DeviceRegistry};
use hardless::api::HardlessClient;
use hardless::coordinator::cluster::{Cluster, ExecutorKind};
use hardless::events::EventSpec;
use hardless::util::Rng;
use std::time::Duration;

fn vpu_node() -> DeviceRegistry {
    DeviceRegistry::new(vec![Device::new("vpu0", AcceleratorProfile::movidius_ncs())])
}

fn submit_burst(cluster: &Cluster, datasets: &[String], n: usize) -> anyhow::Result<()> {
    for i in 0..n {
        cluster.submit(EventSpec::new("tinyyolo", &datasets[i % datasets.len()]))?;
    }
    Ok(())
}

fn status(cluster: &Cluster, label: &str) {
    let s = cluster.cluster_stats().unwrap();
    println!(
        "[{label}] nodes={} free_slots={} queued={} in_flight={} done={}",
        cluster.node_count(),
        cluster.free_slots(),
        s.queue.queued,
        s.queue.in_flight,
        s.completed,
    );
}

fn main() -> anyhow::Result<()> {
    // Mock executors keep this demo fast; swap for ExecutorKind::Pjrt to
    // run the real artifacts (see serve_cluster.rs).
    let cluster = Cluster::builder()
        .time_scale(120.0)
        .executors(ExecutorKind::Mock { scale: 1.0, delay: Duration::from_millis(1) })
        .node("node-a", paper_dualgpu())
        .build()?;

    let mut rng = Rng::new(11);
    let datasets: Vec<String> = (0..4)
        .map(|i| {
            let img: Vec<f32> = (0..64 * 64 * 3).map(|_| 255.0 * rng.f64() as f32).collect();
            cluster.upload_dataset(&format!("img-{i}"), &img).unwrap()
        })
        .collect();

    println!("phase 1: single dual-GPU node absorbing a burst");
    submit_burst(&cluster, &datasets, 12)?;
    std::thread::sleep(Duration::from_millis(400));
    status(&cluster, "P1");

    println!("\nphase 2: scale-out — second node (VPU) joins mid-run");
    cluster.add_node("node-b", vpu_node())?;
    submit_burst(&cluster, &datasets, 12)?;
    std::thread::sleep(Duration::from_millis(400));
    status(&cluster, "P2");

    println!("\nphase 3: scale-in — node-a leaves; node-b keeps serving");
    cluster.remove_node("node-a");
    submit_burst(&cluster, &datasets, 4)?;
    std::thread::sleep(Duration::from_millis(400));
    status(&cluster, "P3");

    println!("\nphase 4: scale-to-zero — all nodes leave; events accumulate");
    cluster.remove_node("node-b");
    submit_burst(&cluster, &datasets, 6)?;
    std::thread::sleep(Duration::from_millis(300));
    status(&cluster, "P4");
    assert!(
        cluster.cluster_stats()?.queue.queued >= 6,
        "work must wait, not vanish"
    );

    println!("\nphase 5: a node returns and drains the backlog");
    cluster.add_node("node-c", paper_dualgpu())?;
    let lost = cluster.drain(Duration::from_secs(120));
    status(&cluster, "P5");
    assert_eq!(lost, 0, "every event must eventually complete");

    // Which node served what?
    let records = cluster.metrics.records();
    let mut per_node: std::collections::BTreeMap<String, usize> = Default::default();
    for r in &records {
        *per_node.entry(r.node.clone().unwrap_or_default()).or_default() += 1;
    }
    println!("\ncompletions per node: {per_node:?}");
    assert!(per_node.len() >= 3, "all three nodes served work");
    println!("elasticity demo OK: {} events, 0 lost", records.len());
    cluster.shutdown();
    Ok(())
}
