//! Distributed deployment: gateway + queue + store + node over TCP.
//!
//! The paper's architecture (Fig. 2) separates the invocation queue
//! (Bedrock), object storage (Minio), node managers, and the benchmark
//! client into independent services.  This example adds the piece the
//! paper leaves implicit — the client-facing gateway — and pushes events
//! through the full remote path with the same [`HardlessClient`] calls
//! the in-process examples use:
//!
//! ```text
//! client ──RemoteClient──▶ gateway ──publish──▶ queue ◀──long-poll── node
//! client ◀──wait/result── gateway ◀──report(RPC)─────────────────── node
//! ```
//!
//! ```bash
//! cargo run --release --example distributed
//! ```

use hardless::api::{GatewayConfig, GatewayServer, HardlessClient, RemoteClient, RemoteReporter};
use hardless::node::{spawn_node, CompletionSink, InstanceReserve, NodeConfig, NodeDeps};
use hardless::queue::{MemQueue, QueueClient, QueueServer};
use hardless::runtime::instance::MockExecutor;
use hardless::runtime::RuntimeInstance;
use hardless::scheduler::WarmFirst;
use hardless::store::{MemStore, ObjectStore, StoreClient, StoreServer};
use hardless::util::clock::ScaledClock;
use hardless::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // --- "infrastructure machine": gateway + queue + store services -------
    let clock = ScaledClock::new(60.0);
    let queue_backend = MemQueue::new(clock.clone());
    let store_backend = Arc::new(MemStore::new());
    let queue_srv = QueueServer::serve("127.0.0.1:0", queue_backend.clone())?;
    let store_srv = StoreServer::serve("127.0.0.1:0", store_backend.clone())?;
    let gateway = GatewayServer::serve(
        "127.0.0.1:0",
        queue_backend,
        store_backend,
        clock.clone(),
        GatewayConfig {
            announce_runtimes: vec!["tinyyolo".into()],
            ..GatewayConfig::default()
        },
    )?;
    println!("gateway service on {}", gateway.addr());
    println!("queue service on {}", queue_srv.addr());
    println!("store service on {}", store_srv.addr());

    // --- "client machine": the gateway client + a store connection --------
    let client = RemoteClient::connect(gateway.addr())?;
    let client_store = StoreClient::connect(store_srv.addr())?;
    let mut rng = Rng::new(3);
    let img: Vec<f32> = (0..64 * 64 * 3).map(|_| 255.0 * rng.f64() as f32).collect();
    let img_bytes: Vec<u8> = img.iter().flat_map(|f| f.to_le_bytes()).collect();
    client_store.put("datasets/remote-img", &img_bytes)?;
    println!("client uploaded datasets/remote-img ({} KB)", img_bytes.len() / 1024);

    // --- "worker machine": node manager over TCP clients -------------------
    let node_queue = Arc::new(QueueClient::connect(queue_srv.addr())?);
    let node_store = Arc::new(StoreClient::connect(store_srv.addr())?);
    let registry = hardless::accel::paper_all_accel();
    let reserve = InstanceReserve::new();
    for d in registry.devices() {
        for variant in d.profile.runtimes.values() {
            for _ in 0..d.profile.slots {
                reserve.add(RuntimeInstance::start(
                    variant.clone(),
                    d.id.clone(),
                    MockExecutor::factory(1.0, Duration::from_millis(1)),
                )?);
            }
        }
    }
    // Completions travel back to the gateway over RPC — that is where
    // REnd is stamped and where `wait`/`status` observe them.
    let reporter: Arc<dyn CompletionSink> = Arc::new(RemoteReporter::connect(gateway.addr())?);
    let node = spawn_node(
        NodeConfig::new("remote-node-1"),
        registry,
        NodeDeps {
            queue: node_queue,
            store: node_store,
            clock: clock.clone(),
            policy: Arc::new(WarmFirst),
            reserve,
            completions: reporter,
        },
    )?;
    println!("worker node joined (5 slots over TCP)\n");

    // --- drive 10 events through the remote path --------------------------
    let n = 10;
    let specs = (0..n)
        .map(|_| hardless::events::EventSpec::new("tinyyolo", "datasets/remote-img"))
        .collect();
    let ids = client.submit_batch(specs)?;
    println!("submitted {} events in one round trip", ids.len());

    let mut warm = 0;
    for id in &ids {
        let inv = client
            .wait(id, Duration::from_secs(60))?
            .expect("event completes");
        if inv.warm {
            warm += 1;
        }
        println!(
            "  {} -> {:<9} on {} ({}) RLat {:>6.0} ms",
            inv.id,
            inv.status.as_str(),
            inv.accelerator.as_deref().unwrap_or("-"),
            if inv.warm { "warm" } else { "cold" },
            inv.stamps.rlat_ms().unwrap_or(f64::NAN),
        );
    }
    let first_result = client.fetch_result(&ids[0])?.expect("result persisted");
    println!("\nfirst result: {} bytes in the object store", first_result.len());

    let stats = client.cluster_stats()?;
    println!(
        "cluster: submitted {} | completed {} | succeeded {} | warm {warm}/{n}",
        stats.submitted, stats.completed, stats.succeeded
    );
    assert_eq!(stats.succeeded, n);
    println!("runtimes advertised: {:?}", client.list_runtimes()?);

    node.stop();
    println!("distributed demo OK");
    Ok(())
}
