//! Distributed deployment: queue + store + node over TCP in one demo.
//!
//! The paper's architecture (Fig. 2) separates the invocation queue
//! (Bedrock), object storage (Minio), node managers, and the benchmark
//! client into independent services.  This example starts each component
//! on its own socket — the same wiring `hardless serve` / `hardless node`
//! use across machines — and pushes events through the full remote path.
//!
//! ```bash
//! cargo run --release --example distributed
//! ```

use hardless::events::{EventSpec, Invocation};
use hardless::node::{spawn_node, InstanceReserve, NodeConfig, NodeDeps};
use hardless::queue::{InvocationQueue, MemQueue, QueueClient, QueueServer};
use hardless::runtime::instance::MockExecutor;
use hardless::runtime::RuntimeInstance;
use hardless::scheduler::WarmFirst;
use hardless::store::{MemStore, ObjectStore, StoreClient, StoreServer};
use hardless::util::clock::ScaledClock;
use hardless::util::{next_id, Clock, Rng};
use std::sync::{mpsc, Arc};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // --- "infrastructure machine": queue + store services -----------------
    let clock = ScaledClock::new(60.0);
    let queue_backend = MemQueue::new(clock.clone());
    let store_backend = Arc::new(MemStore::new());
    let queue_srv = QueueServer::serve("127.0.0.1:0", queue_backend)?;
    let store_srv = StoreServer::serve("127.0.0.1:0", store_backend)?;
    println!("queue service on {}", queue_srv.addr());
    println!("store service on {}", store_srv.addr());

    // --- "client machine": uploads data, publishes events -----------------
    let client_store = StoreClient::connect(store_srv.addr())?;
    let client_queue = QueueClient::connect(queue_srv.addr())?;
    let mut rng = Rng::new(3);
    let img: Vec<f32> = (0..64 * 64 * 3).map(|_| 255.0 * rng.f64() as f32).collect();
    let img_bytes: Vec<u8> = img.iter().flat_map(|f| f.to_le_bytes()).collect();
    client_store.put("datasets/remote-img", &img_bytes)?;
    println!("client uploaded datasets/remote-img ({} KB)", img_bytes.len() / 1024);

    // --- "worker machine": node manager over TCP clients -------------------
    let node_queue = Arc::new(QueueClient::connect(queue_srv.addr())?);
    let node_store = Arc::new(StoreClient::connect(store_srv.addr())?);
    let registry = hardless::accel::paper_all_accel();
    let reserve = InstanceReserve::new();
    for d in registry.devices() {
        for variant in d.profile.runtimes.values() {
            for _ in 0..d.profile.slots {
                reserve.add(RuntimeInstance::start(
                    variant.clone(),
                    d.id.clone(),
                    MockExecutor::factory(1.0, Duration::from_millis(1)),
                )?);
            }
        }
    }
    let (tx, rx) = mpsc::channel();
    let node = spawn_node(
        NodeConfig::new("remote-node-1"),
        registry,
        NodeDeps {
            queue: node_queue,
            store: node_store,
            clock: clock.clone(),
            policy: Arc::new(WarmFirst),
            reserve,
            completions: tx,
        },
    )?;
    println!("worker node joined (5 slots over TCP)\n");

    // --- drive 10 events through the remote path --------------------------
    let n = 10;
    for _ in 0..n {
        let inv = Invocation::new(
            next_id("inv"),
            EventSpec::new("tinyyolo", "datasets/remote-img"),
            clock.now(),
        );
        client_queue.publish(inv)?;
    }
    let mut done = 0;
    while done < n {
        let inv = rx.recv_timeout(Duration::from_secs(60))?;
        done += 1;
        println!(
            "  [{done:2}/{n}] {} on {} ({}) ELat {:.0} ms",
            inv.id,
            inv.accelerator.as_deref().unwrap_or("-"),
            if inv.warm { "warm" } else { "cold" },
            inv.stamps.elat_ms().unwrap_or(f64::NAN),
        );
        // result object is visible to the client through its own connection
        let key = inv.result_key.expect("result persisted");
        assert!(client_store.exists(&key)?, "client sees {key}");
    }
    let stats = client_queue.stats()?;
    println!("\nqueue stats: acked={} dead={} queued={}", stats.acked, stats.dead, stats.queued);
    assert_eq!(stats.acked, n);
    node.stop();
    println!("distributed demo OK");
    Ok(())
}
