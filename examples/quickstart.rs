//! Quickstart: submit one image-detection event to a HARDLESS cluster.
//!
//! ```bash
//! make artifacts            # once: AOT-lower the model variants
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a single-node cluster with the paper's accelerator mix (2× GPU
//! + 1 VPU as virtual devices), publishes the tinyYOLO runtime bundle,
//! submits one event, and prints the decoded detections.

use hardless::api::HardlessClient;
use hardless::coordinator::cluster::{Cluster, ExecutorKind};
use hardless::events::EventSpec;
use hardless::runtime::{artifacts_available, artifacts_dir, RuntimeBundle};
use hardless::util::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // Engine: real PJRT when artifacts exist, mock otherwise.
    let executor = if artifacts_available() {
        println!("using AOT artifacts from {:?}", artifacts_dir());
        ExecutorKind::Pjrt(RuntimeBundle::load_dir("tinyyolo", artifacts_dir())?)
    } else {
        println!("artifacts not built (run `make artifacts`); using mock executors");
        ExecutorKind::Mock { scale: 1.0, delay: Duration::from_millis(2) }
    };

    // One node with the paper's full accelerator set, real-time clock.
    let cluster = Cluster::builder()
        .time_scale(20.0) // compress the ~1.6 s service times for the demo
        .executors(executor)
        .node("node-1", hardless::accel::paper_all_accel())
        .build()?;

    // Upload a synthetic 64x64 RGB image (any f32 raster works).
    let mut rng = Rng::new(7);
    let image: Vec<f32> = (0..64 * 64 * 3).map(|_| 255.0 * rng.f64() as f32).collect();
    let dataset = cluster.upload_dataset("quickstart-image", &image)?;
    println!("uploaded dataset {dataset}");

    // Submit asynchronously through the unified client API — HARDLESS
    // decides where it runs (§IV-B).  The same trait calls work against a
    // remote gateway via `api::RemoteClient`.
    let id = cluster.submit(EventSpec::new("tinyyolo", &dataset))?;
    println!("submitted event {id}");

    let inv = cluster
        .wait(&id, Duration::from_secs(120))?
        .expect("invocation should complete");

    println!("status:      {:?}", inv.status);
    println!("node:        {}", inv.node.as_deref().unwrap_or("-"));
    println!("accelerator: {}", inv.accelerator.as_deref().unwrap_or("-"));
    println!("variant:     {}", inv.variant.as_deref().unwrap_or("-"));
    println!("warm start:  {}", inv.warm);
    println!("RLat: {:.0} ms | ELat: {:.0} ms | DLat: {:.0} ms",
             inv.stamps.rlat_ms().unwrap_or(f64::NAN),
             inv.stamps.elat_ms().unwrap_or(f64::NAN),
             inv.stamps.dlat_ms().unwrap_or(f64::NAN));

    if let Some(body) = cluster.fetch_result(&id)? {
        println!(
            "result object {}: {}",
            inv.result_key.as_deref().unwrap_or("-"),
            String::from_utf8_lossy(&body)
        );
    }
    cluster.shutdown();
    Ok(())
}
