//! End-to-end serving driver (the repository's headline validation run).
//!
//! Loads the real AOT-compiled tinyYOLO bundle, builds the paper's
//! all-accelerator testbed (2× Quadro-K600-profile GPUs + 1 Movidius-NCS-
//! profile VPU as virtual devices), replays the paper's phased open-loop
//! workload (P0 warm-up / P1 scaling / P2 cool-down) through the full
//! stack — queue scan → node manager → warm pool → PJRT execute →
//! postprocess → object store — and reports latency/throughput in the
//! paper's vocabulary.  Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example serve_cluster
//! ```

use hardless::bench::{run_experiment, Engine};
use hardless::config::Config;
use hardless::metrics::summarize;

fn main() -> anyhow::Result<()> {
    let engine = if hardless::runtime::artifacts_available() {
        Engine::Pjrt
    } else {
        eprintln!("artifacts missing — run `make artifacts`; falling back to mock engine");
        Engine::Mock
    };

    let cfg = Config::paper_all();
    println!(
        "cluster: {} node(s), {} accelerator slots | time x{} | protocol x{}",
        cfg.nodes.len(),
        cfg.total_slots(),
        cfg.time_scale,
        cfg.protocol_scale
    );
    println!(
        "workload: {} events expected over {:.0} sim-s ({:?} arrivals)\n",
        cfg.workload.expected_events(),
        cfg.workload.duration().as_secs_f64(),
        cfg.workload.arrivals
    );

    let result = run_experiment("serve_cluster", &cfg, engine)?;
    print!("{}", result.summary_text());

    // Throughput/latency report (the serving-paper deliverable).
    let total_sim_s = result
        .records
        .iter()
        .filter_map(|r| r.r_end)
        .map(|t| t.as_secs_f64())
        .fold(0.0f64, f64::max);
    println!("\n== serving report ==");
    println!(
        "throughput: {:.2} events/sim-s sustained ({} events / {:.0} sim-s)",
        result.report.succeeded as f64 / total_sim_s,
        result.report.succeeded,
        total_sim_s
    );
    println!("peak completion rate (RFast max): {:.2}/s", result.rfast_max);
    let mut s = summarize(result.records.iter());
    println!(
        "latency (ms): ELat p50 {:.0} / p95 {:.0} | RLat p50 {:.0} / p95 {:.0}",
        s.elat.median().unwrap_or(f64::NAN),
        s.elat.p95().unwrap_or(f64::NAN),
        s.rlat.median().unwrap_or(f64::NAN),
        s.rlat.p95().unwrap_or(f64::NAN),
    );
    println!("warm-start fraction: {:.1}%", 100.0 * s.warm_fraction);
    for (kind, med) in result.median_elat_by_kind() {
        println!("  median ELat [{kind}]: {med:.0} ms");
    }

    result.write_csvs(hardless::bench::bench_out_dir())?;
    println!(
        "series written to {}/serve_cluster_*.csv",
        hardless::bench::bench_out_dir().display()
    );
    Ok(())
}
