//! Typed configuration for clusters, workloads, and experiments.
//!
//! The `hardless` binary and the bench harness consume JSON config files;
//! presets mirror the paper's testbed (`paper-dualgpu`, `paper-all`).

use crate::accel::{AcceleratorProfile, Device, DeviceRegistry};
use crate::json::Json;
use crate::workload::{Arrivals, Phase, Workload};
use anyhow::{anyhow, bail, Result};
use std::time::Duration;

/// One node's device list.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub id: String,
    pub devices: Vec<(String, AcceleratorProfile)>,
}

impl NodeSpec {
    pub fn registry(&self) -> DeviceRegistry {
        DeviceRegistry::new(
            self.devices
                .iter()
                .map(|(id, p)| Device::new(id.clone(), p.clone()))
                .collect(),
        )
    }
}

/// Full experiment/cluster configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Sim-time compression (DESIGN.md S6). 1.0 = real time.
    pub time_scale: f64,
    /// Scale on the paper's 2/10/2-minute protocol durations.
    pub protocol_scale: f64,
    pub nodes: Vec<NodeSpec>,
    pub workload: Workload,
    pub policy: String,
    /// Distinct synthetic datasets to upload.
    pub dataset_count: usize,
    /// Node micro-batching: device batch cap (1 = serial execution).
    pub max_batch: usize,
    /// Adaptive linger ceiling for forming batches, sim-ms.
    pub max_linger_ms: u64,
}

impl Config {
    /// The paper's dual-GPU experiment (Fig. 3) at default compression.
    pub fn paper_dualgpu() -> Config {
        Config {
            time_scale: 6.0,
            protocol_scale: 0.1,
            nodes: vec![NodeSpec {
                id: "node-1".into(),
                devices: vec![
                    ("gpu0".into(), AcceleratorProfile::quadro_k600()),
                    ("gpu1".into(), AcceleratorProfile::quadro_k600()),
                ],
            }],
            workload: Workload::paper_protocol("tinyyolo", 1.0, 4.0, 0.1),
            policy: "warm-first".into(),
            dataset_count: 8,
            max_batch: crate::node::BatchConfig::default().max_batch,
            max_linger_ms: crate::node::BatchConfig::default().max_linger.as_millis() as u64,
        }
    }

    /// The paper's all-accelerator experiment (Fig. 4).
    pub fn paper_all() -> Config {
        let mut cfg = Config::paper_dualgpu();
        cfg.nodes[0]
            .devices
            .push(("vpu0".into(), AcceleratorProfile::movidius_ncs()));
        cfg
    }

    /// Resolve a named preset or load a JSON file.
    pub fn load(name_or_path: &str) -> Result<Config> {
        match name_or_path {
            "paper-dualgpu" => Ok(Config::paper_dualgpu()),
            "paper-all" => Ok(Config::paper_all()),
            path => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("read config {path}: {e}"))?;
                let j = Json::parse(&text).map_err(|e| anyhow!("parse config {path}: {e}"))?;
                Config::from_json(&j)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Config> {
        let time_scale = j.get("time_scale").and_then(|v| v.as_f64()).unwrap_or(1.0);
        let protocol_scale = j
            .get("protocol_scale")
            .and_then(|v| v.as_f64())
            .unwrap_or(1.0);
        if time_scale <= 0.0 || protocol_scale <= 0.0 {
            bail!("scales must be positive");
        }

        let mut nodes = Vec::new();
        for n in j.arr_of("nodes")? {
            let id = n.str_of("id")?.to_string();
            let mut devices = Vec::new();
            for d in n.arr_of("devices")? {
                let dev_id = d.str_of("id")?.to_string();
                let profile = match d.get("preset").and_then(|p| p.as_str()) {
                    Some("quadro-k600") => AcceleratorProfile::quadro_k600(),
                    Some("movidius-ncs") => AcceleratorProfile::movidius_ncs(),
                    Some(other) => bail!("unknown device preset '{other}'"),
                    None => AcceleratorProfile::from_json(d)?,
                };
                devices.push((dev_id, profile));
            }
            if devices.is_empty() {
                bail!("node {id} has no devices");
            }
            nodes.push(NodeSpec { id, devices });
        }
        if nodes.is_empty() {
            bail!("config has no nodes");
        }

        let w = j.req("workload")?;
        let runtime = w.str_of("runtime")?.to_string();
        let mut phases = Vec::new();
        for p in w.arr_of("phases")? {
            phases.push(Phase::new(
                p.str_of("name")?,
                Duration::from_secs_f64(p.f64_of("duration_s")?),
                p.f64_of("target_trps")?,
            ));
        }
        if phases.is_empty() {
            bail!("workload has no phases");
        }
        let arrivals = match w.get("arrivals").and_then(|a| a.as_str()).unwrap_or("uniform") {
            "uniform" => Arrivals::Uniform,
            "poisson" => Arrivals::Poisson,
            other => bail!("unknown arrivals '{other}'"),
        };
        let workload = Workload {
            runtime,
            phases,
            arrivals,
            datasets: Vec::new(),
            seed: w.get("seed").and_then(|s| s.as_u64()).unwrap_or(42),
        };

        Ok(Config {
            time_scale,
            protocol_scale,
            nodes,
            workload,
            policy: j
                .get("policy")
                .and_then(|p| p.as_str())
                .unwrap_or("warm-first")
                .to_string(),
            dataset_count: j
                .get("dataset_count")
                .and_then(|d| d.as_usize())
                .unwrap_or(8),
            // Micro-batching knobs parse leniently (configs predating
            // them get the defaults); max_batch 0 is rejected.
            max_batch: match j.get("max_batch").and_then(|v| v.as_usize()) {
                Some(0) => bail!("max_batch must be >= 1"),
                Some(n) => n,
                None => crate::node::BatchConfig::default().max_batch,
            },
            max_linger_ms: j
                .get("max_linger_ms")
                .and_then(|v| v.as_u64())
                .unwrap_or_else(|| {
                    crate::node::BatchConfig::default().max_linger.as_millis() as u64
                }),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("time_scale", self.time_scale)
            .set("protocol_scale", self.protocol_scale)
            .set(
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            Json::obj().set("id", n.id.as_str()).set(
                                "devices",
                                Json::Arr(
                                    n.devices
                                        .iter()
                                        .map(|(id, p)| p.to_json().set("id", id.as_str()))
                                        .collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            )
            .set("workload", self.workload.to_json())
            .set("policy", self.policy.as_str())
            .set("dataset_count", self.dataset_count)
            .set("max_batch", self.max_batch)
            .set("max_linger_ms", self.max_linger_ms)
    }

    /// The node-level batching knobs as a [`crate::node::BatchConfig`].
    pub fn batch_config(&self) -> crate::node::BatchConfig {
        crate::node::BatchConfig {
            max_batch: self.max_batch,
            max_linger: Duration::from_millis(self.max_linger_ms),
            ..crate::node::BatchConfig::default()
        }
    }

    pub fn total_slots(&self) -> usize {
        self.nodes
            .iter()
            .flat_map(|n| n.devices.iter())
            .map(|(_, p)| p.slots)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_testbed() {
        let dual = Config::paper_dualgpu();
        assert_eq!(dual.total_slots(), 4);
        let all = Config::paper_all();
        assert_eq!(all.total_slots(), 5);
        assert_eq!(all.workload.phases.len(), 3);
    }

    #[test]
    fn load_by_preset_name() {
        assert_eq!(Config::load("paper-dualgpu").unwrap().total_slots(), 4);
        assert_eq!(Config::load("paper-all").unwrap().total_slots(), 5);
        assert!(Config::load("/nonexistent/file.json").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = Config::paper_all();
        let back = Config::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.total_slots(), 5);
        assert_eq!(back.nodes[0].devices.len(), 3);
        assert_eq!(back.workload.phases.len(), 3);
        assert!((back.time_scale - cfg.time_scale).abs() < 1e-9);
    }

    #[test]
    fn from_json_with_device_presets() {
        let j = Json::parse(
            r#"{
              "time_scale": 10,
              "nodes": [{"id": "n1", "devices": [
                {"id": "gpu0", "preset": "quadro-k600"},
                {"id": "vpu0", "preset": "movidius-ncs"}
              ]}],
              "workload": {"runtime": "tinyyolo",
                           "phases": [{"name": "P0", "duration_s": 5, "target_trps": 2}]}
            }"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.total_slots(), 3);
        assert_eq!(cfg.policy, "warm-first");
        // batching knobs default leniently when absent
        assert_eq!(cfg.max_batch, crate::node::BatchConfig::default().max_batch);
        assert_eq!(cfg.batch_config().max_batch, cfg.max_batch);
    }

    #[test]
    fn batching_knobs_roundtrip_and_validate() {
        let mut cfg = Config::paper_dualgpu();
        cfg.max_batch = 16;
        cfg.max_linger_ms = 2;
        let back = Config::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.max_batch, 16);
        assert_eq!(back.max_linger_ms, 2);
        assert_eq!(back.batch_config().max_linger, Duration::from_millis(2));
        let j = cfg.to_json().set("max_batch", 0usize);
        assert!(Config::from_json(&j).is_err(), "max_batch 0 rejected");
    }

    #[test]
    fn rejects_invalid_configs() {
        for bad in [
            r#"{"nodes": [], "workload": {"runtime": "r", "phases": [{"name":"P","duration_s":1,"target_trps":1}]}}"#,
            r#"{"time_scale": -1, "nodes": [{"id":"n","devices":[{"id":"g","preset":"quadro-k600"}]}], "workload": {"runtime":"r","phases":[{"name":"P","duration_s":1,"target_trps":1}]}}"#,
            r#"{"nodes": [{"id":"n","devices":[{"id":"g","preset":"hal9000"}]}], "workload": {"runtime":"r","phases":[{"name":"P","duration_s":1,"target_trps":1}]}}"#,
            r#"{"nodes": [{"id":"n","devices":[{"id":"g","preset":"quadro-k600"}]}], "workload": {"runtime":"r","phases":[]}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Config::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn loads_shipped_config_files() {
        // The sample configs under configs/ must stay loadable — they are
        // the documented entry point for custom fleets.
        for name in ["configs/paper_all.json", "configs/custom_fleet.json"] {
            if !std::path::Path::new(name).is_file() {
                eprintln!("skipping: {name} not found (cwd {:?})", std::env::current_dir());
                continue;
            }
            let cfg = Config::load(name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(cfg.total_slots() > 0);
            assert!(!cfg.workload.phases.is_empty());
        }
        // custom_fleet exercises inline (non-preset) profiles + a custom kind
        if std::path::Path::new("configs/custom_fleet.json").is_file() {
            let cfg = Config::load("configs/custom_fleet.json").unwrap();
            assert_eq!(cfg.nodes.len(), 2);
            let npu = &cfg.nodes[1].devices[0].1;
            assert_eq!(npu.kind.as_str(), "npu-x9");
            assert_eq!(npu.slots, 4);
            assert_eq!(cfg.policy, "deadline:20000");
        }
    }

    #[test]
    fn registry_from_node_spec() {
        let cfg = Config::paper_all();
        let reg = cfg.nodes[0].registry();
        assert_eq!(reg.total_slots(), 5);
        assert!(reg.get("vpu0").is_some());
    }
}
