//! Event-selection policies.
//!
//! The paper's node managers scan the shared queue and choose what to take
//! (§IV-C/D); its discussion section calls for *"complex event scheduling
//! and filtering mechanisms"* as future work.  This module makes the
//! policy pluggable:
//!
//! * [`WarmFirst`] — the paper's behaviour: take anything supported, but
//!   prefer events whose runtime is warm locally.
//! * [`Fifo`] — ablation baseline: plain SQS-style pop of the oldest
//!   supported event, ignoring warmth (see `benches/ablation_warmfirst`).
//! * [`KindAffinity`] — prefer events that can run on a given accelerator
//!   kind while it has free slots (bias work toward cheap accelerators).
//! * [`DeadlineFilter`] — the future-work latency guarantee: drop events
//!   that have already waited past a deadline instead of running them.

use crate::accel::DeviceRegistry;
use crate::events::Invocation;
use crate::queue::TakeFilter;
use crate::runtime::InstancePool;
use crate::util::SimTime;
use std::collections::HashSet;
use std::time::Duration;

/// Decision for a leased event before execution.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    Run,
    /// Fail the event without executing (reason recorded on the
    /// invocation).  The lease is still acked — the decision is final.
    Reject(String),
}

/// Node-side scheduling policy.
pub trait Policy: Send + Sync {
    /// Build the queue-scan filter for the next poll, given the node's
    /// devices and warm pool.
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter;

    /// Admission check after the lease is obtained.
    fn admit(&self, _inv: &Invocation, _now: SimTime) -> Admission {
        Admission::Run
    }

    fn name(&self) -> &'static str;
}

/// Runtimes that are warm *somewhere usable*: an idle instance exists for
/// (variant, device) where the device implements the logical runtime via
/// that variant and has a free slot.  Returned as a [`HashSet`] so it
/// moves straight into [`TakeFilter::warm`] — no per-poll `Vec` rebuild
/// and re-collect (the sets are rebuilt every manager poll).
pub fn warm_runtimes(registry: &DeviceRegistry, pool: &InstancePool) -> HashSet<String> {
    let mut out = HashSet::new();
    for rt in registry.supported_runtimes() {
        let usable = registry.devices().iter().any(|d| {
            d.free_slots() > 0
                && d.profile
                    .variant_for(&rt)
                    .map(|v| pool.has_idle(v, &d.id))
                    .unwrap_or(false)
        });
        if usable {
            out.insert(rt);
        }
    }
    out
}

/// The paper's policy: scan for warm work first, cold otherwise.
#[derive(Debug, Default)]
pub struct WarmFirst;

impl Policy for WarmFirst {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        TakeFilter {
            runtimes: registry.supported_runtimes().into_iter().collect(),
            warm: warm_runtimes(registry, pool),
            ..TakeFilter::default()
        }
    }

    fn name(&self) -> &'static str {
        "warm-first"
    }
}

/// Batch-aware decorator: the inner policy's take set, with the filter's
/// deep-lane preference switched on so the queue's grouped takes coalesce
/// the deepest same-variant lane (feeding the node's micro-batch
/// aggregator the biggest chunks).  Applied by the node manager whenever
/// its [`crate::node::BatchConfig`] allows batches > 1.
pub struct BatchAware {
    pub inner: std::sync::Arc<dyn Policy>,
}

impl Policy for BatchAware {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        self.inner.filter(registry, pool).preferring_deep(true)
    }

    fn admit(&self, inv: &Invocation, now: SimTime) -> Admission {
        self.inner.admit(inv, now)
    }

    fn name(&self) -> &'static str {
        "batch-aware"
    }
}

/// Ablation baseline: strict FIFO, warmth ignored.
#[derive(Debug, Default)]
pub struct Fifo;

impl Policy for Fifo {
    fn filter(&self, registry: &DeviceRegistry, _pool: &InstancePool) -> TakeFilter {
        TakeFilter::supporting(registry.supported_runtimes())
    }

    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Prefer runtimes executable on `kind` while that kind has free slots;
/// fall back to everything supported otherwise.
#[derive(Debug)]
pub struct KindAffinity {
    pub kind: crate::accel::AcceleratorKind,
}

impl Policy for KindAffinity {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        let preferred: HashSet<String> = registry
            .devices()
            .iter()
            .filter(|d| d.profile.kind == self.kind && d.free_slots() > 0)
            .flat_map(|d| d.profile.runtimes.keys().cloned())
            .collect();
        if preferred.is_empty() {
            WarmFirst.filter(registry, pool)
        } else {
            TakeFilter {
                runtimes: preferred,
                warm: warm_runtimes(registry, pool),
                ..TakeFilter::default()
            }
        }
    }

    fn name(&self) -> &'static str {
        "kind-affinity"
    }
}

/// Priority-pinned decorator: warm-first take sets restricted to one QoS
/// lane.  A node running `priority:interactive` serves only the
/// interactive lane (dedicated low-latency capacity); `priority:batch`
/// makes a node invisible to interactive traffic (bulk offload).  Nodes
/// without the pin see both lanes through the queue's weighted-take rule.
#[derive(Debug)]
pub struct PriorityLane {
    pub lane: crate::events::Priority,
}

impl Policy for PriorityLane {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        TakeFilter {
            priority: Some(self.lane),
            ..WarmFirst.filter(registry, pool)
        }
    }

    fn name(&self) -> &'static str {
        match self.lane {
            crate::events::Priority::Interactive => "priority-interactive",
            crate::events::Priority::Batch => "priority-batch",
        }
    }
}

/// Warm-first + deadline admission: events that already waited longer than
/// `deadline` are rejected instead of executed (fail-fast semantics for
/// the paper's "customers might want specific latency guarantees").
#[derive(Debug)]
pub struct DeadlineFilter {
    pub deadline: Duration,
}

impl Policy for DeadlineFilter {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        WarmFirst.filter(registry, pool)
    }

    fn admit(&self, inv: &Invocation, now: SimTime) -> Admission {
        match inv.stamps.r_start {
            Some(start) if now.since(start) > self.deadline => Admission::Reject(format!(
                "deadline exceeded: waited {:.0} ms > {:.0} ms",
                now.since(start).as_secs_f64() * 1e3,
                self.deadline.as_secs_f64() * 1e3
            )),
            _ => Admission::Run,
        }
    }

    fn name(&self) -> &'static str {
        "deadline-filter"
    }
}

/// Parse a policy by name (CLI/config).
pub fn parse_policy(name: &str) -> anyhow::Result<std::sync::Arc<dyn Policy>> {
    match name {
        "warm-first" => Ok(std::sync::Arc::new(WarmFirst)),
        "fifo" => Ok(std::sync::Arc::new(Fifo)),
        s if s.starts_with("deadline:") => {
            let ms: u64 = s["deadline:".len()..]
                .parse()
                .map_err(|e| anyhow::anyhow!("bad deadline in '{s}': {e}"))?;
            Ok(std::sync::Arc::new(DeadlineFilter {
                deadline: Duration::from_millis(ms),
            }))
        }
        s if s.starts_with("priority:") => {
            let lane = crate::events::Priority::parse(&s["priority:".len()..])
                .map_err(|e| anyhow::anyhow!("bad lane in '{s}': {e}"))?;
            Ok(std::sync::Arc::new(PriorityLane { lane }))
        }
        other => anyhow::bail!(
            "unknown policy '{other}' (expected warm-first | fifo | deadline:<ms> | \
             priority:interactive | priority:batch)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{paper_all_accel, AcceleratorKind};
    use crate::events::EventSpec;
    use crate::runtime::instance::MockExecutor;
    use crate::runtime::RuntimeInstance;

    fn pool_with_warm(variant: &str, device: &str) -> std::sync::Arc<InstancePool> {
        let pool = InstancePool::new(8);
        drop(
            pool.acquire_or_start(variant, device, || {
                RuntimeInstance::start(
                    variant,
                    device,
                    MockExecutor::factory(1.0, Duration::ZERO),
                )
            })
            .unwrap(),
        );
        pool
    }

    fn set(names: &[&str]) -> std::collections::HashSet<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn warm_first_filter_contents() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let f = WarmFirst.filter(&reg, &pool);
        assert_eq!(f.runtimes, set(&["tinyyolo"]));
        assert_eq!(f.warm, set(&["tinyyolo"]));
        assert!(!f.warm_only);
    }

    #[test]
    fn warm_requires_matching_device_with_free_slot() {
        let reg = paper_all_accel();
        // warm instance exists for the *vpu* variant on a gpu device id:
        // no device maps tinyyolo -> tinyyolo-vpu except vpu0, and vpu0 has
        // no instance — so nothing is "usably warm".
        let pool = pool_with_warm("tinyyolo-vpu", "gpu0");
        assert!(warm_runtimes(&reg, &pool).is_empty());
        // saturate vpu0's only slot: a warm vpu instance becomes unusable
        let pool = pool_with_warm("tinyyolo-vpu", "vpu0");
        assert_eq!(warm_runtimes(&reg, &pool), set(&["tinyyolo"]));
        let _slot = reg.get("vpu0").unwrap().try_acquire().unwrap();
        assert!(warm_runtimes(&reg, &pool).is_empty());
    }

    #[test]
    fn fifo_has_no_warm_set() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let f = Fifo.filter(&reg, &pool);
        assert!(f.warm.is_empty());
    }

    #[test]
    fn kind_affinity_prefers_kind_with_capacity() {
        let reg = paper_all_accel();
        let pool = InstancePool::new(4);
        let policy = KindAffinity { kind: AcceleratorKind::Vpu };
        let f = policy.filter(&reg, &pool);
        assert_eq!(f.runtimes, set(&["tinyyolo"]));
        // saturate the vpu -> falls back to warm-first over all devices
        let _slot = reg.get("vpu0").unwrap().try_acquire().unwrap();
        let f = policy.filter(&reg, &pool);
        assert_eq!(
            f.runtimes,
            reg.supported_runtimes().into_iter().collect::<std::collections::HashSet<_>>()
        );
    }

    #[test]
    fn batch_aware_sets_deep_preference_and_delegates() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let inner: std::sync::Arc<dyn Policy> =
            std::sync::Arc::new(DeadlineFilter { deadline: Duration::from_millis(500) });
        let policy = BatchAware { inner };
        let f = policy.filter(&reg, &pool);
        assert!(f.prefer_deep, "grouped takes must coalesce deep lanes");
        assert_eq!(f.runtimes, set(&["tinyyolo"]), "take set comes from the inner policy");
        assert_eq!(f.warm, set(&["tinyyolo"]));
        // admission delegates (deadline still enforced under batching)
        let inv = Invocation::new("1", EventSpec::new("r", "d"), SimTime::from_millis(0));
        assert!(matches!(
            policy.admit(&inv, SimTime::from_millis(900)),
            Admission::Reject(_)
        ));
    }

    #[test]
    fn deadline_rejects_stale_events() {
        let policy = DeadlineFilter { deadline: Duration::from_millis(500) };
        let inv = Invocation::new("1", EventSpec::new("r", "d"), SimTime::from_millis(0));
        assert_eq!(policy.admit(&inv, SimTime::from_millis(100)), Admission::Run);
        match policy.admit(&inv, SimTime::from_millis(900)) {
            Admission::Reject(reason) => assert!(reason.contains("deadline"), "{reason}"),
            other => panic!("expected reject, got {other:?}"),
        }
    }

    #[test]
    fn parse_policy_names() {
        assert_eq!(parse_policy("warm-first").unwrap().name(), "warm-first");
        assert_eq!(parse_policy("fifo").unwrap().name(), "fifo");
        assert_eq!(parse_policy("deadline:2000").unwrap().name(), "deadline-filter");
        assert_eq!(
            parse_policy("priority:interactive").unwrap().name(),
            "priority-interactive"
        );
        assert_eq!(parse_policy("priority:batch").unwrap().name(), "priority-batch");
        assert!(parse_policy("priority:urgent").is_err());
        assert!(parse_policy("deadline:xx").is_err());
        assert!(parse_policy("zzz").is_err());
    }

    #[test]
    fn priority_lane_pins_the_filter_and_keeps_warm_sets() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let f = PriorityLane { lane: crate::events::Priority::Interactive }
            .filter(&reg, &pool);
        assert_eq!(f.priority, Some(crate::events::Priority::Interactive));
        assert_eq!(f.runtimes, set(&["tinyyolo"]), "take set is warm-first's");
        assert_eq!(f.warm, set(&["tinyyolo"]));
    }
}
