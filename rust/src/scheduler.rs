//! Event-selection policies.
//!
//! The paper's node managers scan the shared queue and choose what to take
//! (§IV-C/D); its discussion section calls for *"complex event scheduling
//! and filtering mechanisms"* as future work.  This module makes the
//! policy pluggable:
//!
//! * [`WarmFirst`] — the paper's behaviour: take anything supported, but
//!   prefer events whose runtime is warm locally.
//! * [`Fifo`] — ablation baseline: plain SQS-style pop of the oldest
//!   supported event, ignoring warmth (see `benches/ablation_warmfirst`).
//! * [`KindAffinity`] — prefer events that can run on a given accelerator
//!   kind while it has free slots (bias work toward cheap accelerators).
//! * [`DeadlineFilter`] — the future-work latency guarantee: drop events
//!   that have already waited past a deadline instead of running them.
//! * [`CacheAffinity`] — data-locality decorator: advertise the node's
//!   hot cached datasets in the take filter so the queue moves compute
//!   to nodes that already hold the data (warm ▸ hot ▸ FIFO).

use crate::accel::DeviceRegistry;
use crate::events::Invocation;
use crate::queue::TakeFilter;
use crate::runtime::InstancePool;
use crate::store::CachedStore;
use crate::util::SimTime;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

/// Decision for a leased event before execution.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    Run,
    /// Fail the event without executing (reason recorded on the
    /// invocation).  The lease is still acked — the decision is final.
    Reject(String),
}

/// Node-side scheduling policy.
pub trait Policy: Send + Sync {
    /// Build the queue-scan filter for the next poll, given the node's
    /// devices and warm pool.
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter;

    /// Admission check after the lease is obtained.
    fn admit(&self, _inv: &Invocation, _now: SimTime) -> Admission {
        Admission::Run
    }

    /// Bind this policy to a node's local content cache, returning the
    /// node-specific policy to poll with — or `None` when the policy is
    /// cache-oblivious (the default; the shared instance keeps serving).
    ///
    /// A cluster shares **one** policy `Arc` across every node it
    /// spawns, but [`CacheAffinity`] must read the *taking node's own*
    /// cache; `spawn_node` calls this after building the node's
    /// [`CachedStore`] so each node polls with a policy bound to its own
    /// hot-set.  Decorators forward the call and re-wrap.
    fn bind_cache(&self, _cache: &Arc<CachedStore>) -> Option<Arc<dyn Policy>> {
        None
    }

    fn name(&self) -> &'static str;
}

/// Runtimes that are warm *somewhere usable*: an idle instance exists for
/// (variant, device) where the device implements the logical runtime via
/// that variant and has a free slot.  Returned as a [`HashSet`] so it
/// moves straight into [`TakeFilter::warm`] — no per-poll `Vec` rebuild
/// and re-collect (the sets are rebuilt every manager poll).
pub fn warm_runtimes(registry: &DeviceRegistry, pool: &InstancePool) -> HashSet<String> {
    let mut out = HashSet::new();
    for rt in registry.supported_runtimes() {
        let usable = registry.devices().iter().any(|d| {
            d.free_slots() > 0
                && d.profile
                    .variant_for(&rt)
                    .map(|v| pool.has_idle(v, &d.id))
                    .unwrap_or(false)
        });
        if usable {
            out.insert(rt);
        }
    }
    out
}

/// The paper's policy: scan for warm work first, cold otherwise.
#[derive(Debug, Default)]
pub struct WarmFirst;

impl Policy for WarmFirst {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        TakeFilter {
            runtimes: registry.supported_runtimes().into_iter().collect(),
            warm: warm_runtimes(registry, pool),
            ..TakeFilter::default()
        }
    }

    fn name(&self) -> &'static str {
        "warm-first"
    }
}

/// Batch-aware decorator: the inner policy's take set, with the filter's
/// deep-lane preference switched on so the queue's grouped takes coalesce
/// the deepest same-variant lane (feeding the node's micro-batch
/// aggregator the biggest chunks).  Applied by the node manager whenever
/// its [`crate::node::BatchConfig`] allows batches > 1.
pub struct BatchAware {
    pub inner: std::sync::Arc<dyn Policy>,
}

impl Policy for BatchAware {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        self.inner.filter(registry, pool).preferring_deep(true)
    }

    fn admit(&self, inv: &Invocation, now: SimTime) -> Admission {
        self.inner.admit(inv, now)
    }

    fn bind_cache(&self, cache: &Arc<CachedStore>) -> Option<Arc<dyn Policy>> {
        self.inner
            .bind_cache(cache)
            .map(|inner| Arc::new(BatchAware { inner }) as Arc<dyn Policy>)
    }

    fn name(&self) -> &'static str {
        "batch-aware"
    }
}

/// Cache-affinity decorator (DESIGN.md §15): the inner policy's take
/// set, with [`TakeFilter::hot_datasets`] filled from the taking node's
/// local content cache each poll, so the queue ranks warm ▸ hot ▸ FIFO
/// and compute moves to the data instead of re-fetching it.
///
/// Unbound (before [`Policy::bind_cache`], or on a node with caching
/// disabled) the hot-set stays empty and every take is byte-identical
/// to the inner policy — the affinity-off property the reference-model
/// tests pin.  A stale hot-set entry costs at most one backing fetch on
/// the node that advertised it (see `CachedStore::contains_cached`).
pub struct CacheAffinity {
    pub inner: Arc<dyn Policy>,
    /// The node-local cache to summarize; `None` until bound.
    cache: Option<Arc<CachedStore>>,
    /// Hot-set size advertised per poll (top-K LRU keys).
    pub top_k: usize,
}

/// Default hot-set breadth: enough for a node's working set of datasets
/// while keeping the per-take membership probes and the gossip payload
/// small.
pub const DEFAULT_HOT_SET: usize = 16;

impl CacheAffinity {
    /// Decorate `inner` with cache-affinity; bind with
    /// [`Policy::bind_cache`] once the node's cache exists.
    pub fn over(inner: Arc<dyn Policy>) -> CacheAffinity {
        CacheAffinity { inner, cache: None, top_k: DEFAULT_HOT_SET }
    }
}

impl Policy for CacheAffinity {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        let f = self.inner.filter(registry, pool);
        match &self.cache {
            Some(cache) => {
                let (keys, _generation) = cache.hot_keys(self.top_k);
                f.with_hot_datasets(keys)
            }
            None => f,
        }
    }

    fn admit(&self, inv: &Invocation, now: SimTime) -> Admission {
        self.inner.admit(inv, now)
    }

    fn bind_cache(&self, cache: &Arc<CachedStore>) -> Option<Arc<dyn Policy>> {
        // Re-bind the inner policy too, so stacked decorators all see
        // the node's cache.
        let inner = self.inner.bind_cache(cache).unwrap_or_else(|| self.inner.clone());
        Some(Arc::new(CacheAffinity {
            inner,
            cache: Some(cache.clone()),
            top_k: self.top_k,
        }))
    }

    fn name(&self) -> &'static str {
        "affinity"
    }
}

/// Ablation baseline: strict FIFO, warmth ignored.
#[derive(Debug, Default)]
pub struct Fifo;

impl Policy for Fifo {
    fn filter(&self, registry: &DeviceRegistry, _pool: &InstancePool) -> TakeFilter {
        TakeFilter::supporting(registry.supported_runtimes())
    }

    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Prefer runtimes executable on `kind` while that kind has free slots;
/// fall back to everything supported otherwise.
#[derive(Debug)]
pub struct KindAffinity {
    pub kind: crate::accel::AcceleratorKind,
}

impl Policy for KindAffinity {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        let preferred: HashSet<String> = registry
            .devices()
            .iter()
            .filter(|d| d.profile.kind == self.kind && d.free_slots() > 0)
            .flat_map(|d| d.profile.runtimes.keys().cloned())
            .collect();
        if preferred.is_empty() {
            WarmFirst.filter(registry, pool)
        } else {
            TakeFilter {
                runtimes: preferred,
                warm: warm_runtimes(registry, pool),
                ..TakeFilter::default()
            }
        }
    }

    fn name(&self) -> &'static str {
        "kind-affinity"
    }
}

/// Priority-pinned decorator: warm-first take sets restricted to one QoS
/// lane.  A node running `priority:interactive` serves only the
/// interactive lane (dedicated low-latency capacity); `priority:batch`
/// makes a node invisible to interactive traffic (bulk offload).  Nodes
/// without the pin see both lanes through the queue's weighted-take rule.
#[derive(Debug)]
pub struct PriorityLane {
    pub lane: crate::events::Priority,
}

impl Policy for PriorityLane {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        TakeFilter {
            priority: Some(self.lane),
            ..WarmFirst.filter(registry, pool)
        }
    }

    fn name(&self) -> &'static str {
        match self.lane {
            crate::events::Priority::Interactive => "priority-interactive",
            crate::events::Priority::Batch => "priority-batch",
        }
    }
}

/// Warm-first + deadline admission: events that already waited longer than
/// `deadline` are rejected instead of executed (fail-fast semantics for
/// the paper's "customers might want specific latency guarantees").
#[derive(Debug)]
pub struct DeadlineFilter {
    pub deadline: Duration,
}

impl Policy for DeadlineFilter {
    fn filter(&self, registry: &DeviceRegistry, pool: &InstancePool) -> TakeFilter {
        WarmFirst.filter(registry, pool)
    }

    fn admit(&self, inv: &Invocation, now: SimTime) -> Admission {
        match inv.stamps.r_start {
            Some(start) if now.since(start) > self.deadline => Admission::Reject(format!(
                "deadline exceeded: waited {:.0} ms > {:.0} ms",
                now.since(start).as_secs_f64() * 1e3,
                self.deadline.as_secs_f64() * 1e3
            )),
            _ => Admission::Run,
        }
    }

    fn name(&self) -> &'static str {
        "deadline-filter"
    }
}

/// Parse a policy by name (CLI/config).
pub fn parse_policy(name: &str) -> anyhow::Result<std::sync::Arc<dyn Policy>> {
    match name {
        "warm-first" => Ok(std::sync::Arc::new(WarmFirst)),
        "fifo" => Ok(std::sync::Arc::new(Fifo)),
        s if s.starts_with("deadline:") => {
            let ms: u64 = s["deadline:".len()..]
                .parse()
                .map_err(|e| anyhow::anyhow!("bad deadline in '{s}': {e}"))?;
            Ok(std::sync::Arc::new(DeadlineFilter {
                deadline: Duration::from_millis(ms),
            }))
        }
        s if s.starts_with("priority:") => {
            let lane = crate::events::Priority::parse(&s["priority:".len()..])
                .map_err(|e| anyhow::anyhow!("bad lane in '{s}': {e}"))?;
            Ok(std::sync::Arc::new(PriorityLane { lane }))
        }
        "affinity" => Ok(std::sync::Arc::new(CacheAffinity::over(std::sync::Arc::new(
            WarmFirst,
        )))),
        s if s.starts_with("affinity:") => {
            let inner = parse_policy(&s["affinity:".len()..])?;
            Ok(std::sync::Arc::new(CacheAffinity::over(inner)))
        }
        other => anyhow::bail!(
            "unknown policy '{other}' (expected warm-first | fifo | deadline:<ms> | \
             priority:interactive | priority:batch | affinity[:<inner>])"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{paper_all_accel, AcceleratorKind};
    use crate::events::EventSpec;
    use crate::runtime::instance::MockExecutor;
    use crate::runtime::RuntimeInstance;

    fn pool_with_warm(variant: &str, device: &str) -> std::sync::Arc<InstancePool> {
        let pool = InstancePool::new(8);
        drop(
            pool.acquire_or_start(variant, device, || {
                RuntimeInstance::start(
                    variant,
                    device,
                    MockExecutor::factory(1.0, Duration::ZERO),
                )
            })
            .unwrap(),
        );
        pool
    }

    fn set(names: &[&str]) -> std::collections::HashSet<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn warm_first_filter_contents() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let f = WarmFirst.filter(&reg, &pool);
        assert_eq!(f.runtimes, set(&["tinyyolo"]));
        assert_eq!(f.warm, set(&["tinyyolo"]));
        assert!(!f.warm_only);
    }

    #[test]
    fn warm_requires_matching_device_with_free_slot() {
        let reg = paper_all_accel();
        // warm instance exists for the *vpu* variant on a gpu device id:
        // no device maps tinyyolo -> tinyyolo-vpu except vpu0, and vpu0 has
        // no instance — so nothing is "usably warm".
        let pool = pool_with_warm("tinyyolo-vpu", "gpu0");
        assert!(warm_runtimes(&reg, &pool).is_empty());
        // saturate vpu0's only slot: a warm vpu instance becomes unusable
        let pool = pool_with_warm("tinyyolo-vpu", "vpu0");
        assert_eq!(warm_runtimes(&reg, &pool), set(&["tinyyolo"]));
        let _slot = reg.get("vpu0").unwrap().try_acquire().unwrap();
        assert!(warm_runtimes(&reg, &pool).is_empty());
    }

    #[test]
    fn fifo_has_no_warm_set() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let f = Fifo.filter(&reg, &pool);
        assert!(f.warm.is_empty());
    }

    #[test]
    fn kind_affinity_prefers_kind_with_capacity() {
        let reg = paper_all_accel();
        let pool = InstancePool::new(4);
        let policy = KindAffinity { kind: AcceleratorKind::Vpu };
        let f = policy.filter(&reg, &pool);
        assert_eq!(f.runtimes, set(&["tinyyolo"]));
        // saturate the vpu -> falls back to warm-first over all devices
        let _slot = reg.get("vpu0").unwrap().try_acquire().unwrap();
        let f = policy.filter(&reg, &pool);
        assert_eq!(
            f.runtimes,
            reg.supported_runtimes().into_iter().collect::<std::collections::HashSet<_>>()
        );
    }

    #[test]
    fn batch_aware_sets_deep_preference_and_delegates() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let inner: std::sync::Arc<dyn Policy> =
            std::sync::Arc::new(DeadlineFilter { deadline: Duration::from_millis(500) });
        let policy = BatchAware { inner };
        let f = policy.filter(&reg, &pool);
        assert!(f.prefer_deep, "grouped takes must coalesce deep lanes");
        assert_eq!(f.runtimes, set(&["tinyyolo"]), "take set comes from the inner policy");
        assert_eq!(f.warm, set(&["tinyyolo"]));
        // admission delegates (deadline still enforced under batching)
        let inv = Invocation::new("1", EventSpec::new("r", "d"), SimTime::from_millis(0));
        assert!(matches!(
            policy.admit(&inv, SimTime::from_millis(900)),
            Admission::Reject(_)
        ));
    }

    #[test]
    fn deadline_rejects_stale_events() {
        let policy = DeadlineFilter { deadline: Duration::from_millis(500) };
        let inv = Invocation::new("1", EventSpec::new("r", "d"), SimTime::from_millis(0));
        assert_eq!(policy.admit(&inv, SimTime::from_millis(100)), Admission::Run);
        match policy.admit(&inv, SimTime::from_millis(900)) {
            Admission::Reject(reason) => assert!(reason.contains("deadline"), "{reason}"),
            other => panic!("expected reject, got {other:?}"),
        }
    }

    #[test]
    fn parse_policy_names() {
        assert_eq!(parse_policy("warm-first").unwrap().name(), "warm-first");
        assert_eq!(parse_policy("fifo").unwrap().name(), "fifo");
        assert_eq!(parse_policy("deadline:2000").unwrap().name(), "deadline-filter");
        assert_eq!(
            parse_policy("priority:interactive").unwrap().name(),
            "priority-interactive"
        );
        assert_eq!(parse_policy("priority:batch").unwrap().name(), "priority-batch");
        assert_eq!(parse_policy("affinity").unwrap().name(), "affinity");
        assert_eq!(parse_policy("affinity:fifo").unwrap().name(), "affinity");
        assert_eq!(parse_policy("affinity:deadline:2000").unwrap().name(), "affinity");
        assert!(parse_policy("affinity:zzz").is_err());
        assert!(parse_policy("priority:urgent").is_err());
        assert!(parse_policy("deadline:xx").is_err());
        assert!(parse_policy("zzz").is_err());
    }

    /// A node-local cache with a few resident datasets, for binding
    /// affinity policies in tests.
    fn cache_with(keys: &[&str]) -> Arc<CachedStore> {
        use crate::store::ObjectStore;
        let backing = Arc::new(crate::store::MemStore::new());
        let cache = Arc::new(CachedStore::new(backing, 1 << 20));
        for k in keys {
            cache.put(k, b"payload").unwrap();
            drop(cache.get(k).unwrap());
        }
        cache
    }

    #[test]
    fn unbound_affinity_is_byte_identical_to_inner() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let policy = CacheAffinity::over(std::sync::Arc::new(WarmFirst));
        let f = policy.filter(&reg, &pool);
        let inner = WarmFirst.filter(&reg, &pool);
        assert_eq!(f.to_json().to_string(), inner.to_json().to_string());
        assert!(f.hot_datasets.is_empty());
    }

    #[test]
    fn bound_affinity_advertises_the_cache_hot_set() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let cache = cache_with(&["datasets/a", "datasets/b"]);
        let policy = CacheAffinity::over(std::sync::Arc::new(WarmFirst))
            .bind_cache(&cache)
            .expect("affinity binds");
        let f = policy.filter(&reg, &pool);
        assert_eq!(f.hot_datasets, set(&["datasets/a", "datasets/b"]));
        assert_eq!(f.runtimes, set(&["tinyyolo"]), "take set still comes from the inner policy");
        assert_eq!(f.warm, set(&["tinyyolo"]), "warm preference outranks hot and is preserved");
    }

    #[test]
    fn batch_aware_forwards_bind_and_keeps_both_preferences() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let cache = cache_with(&["datasets/hot"]);
        let stack = BatchAware {
            inner: std::sync::Arc::new(CacheAffinity::over(std::sync::Arc::new(WarmFirst))),
        };
        // Cache-oblivious stacks stay on the shared instance.
        assert!(BatchAware { inner: std::sync::Arc::new(WarmFirst) }
            .bind_cache(&cache)
            .is_none());
        let bound = stack.bind_cache(&cache).expect("affinity inside the stack binds");
        assert_eq!(bound.name(), "batch-aware");
        let f = bound.filter(&reg, &pool);
        assert!(f.prefer_deep, "batching preference survives the re-wrap");
        assert_eq!(f.hot_datasets, set(&["datasets/hot"]));
    }

    #[test]
    fn priority_lane_pins_the_filter_and_keeps_warm_sets() {
        let reg = paper_all_accel();
        let pool = pool_with_warm("tinyyolo-gpu", "gpu0");
        let f = PriorityLane { lane: crate::events::Priority::Interactive }
            .filter(&reg, &pool);
        assert_eq!(f.priority, Some(crate::events::Priority::Interactive));
        assert_eq!(f.runtimes, set(&["tinyyolo"]), "take set is warm-first's");
        assert_eq!(f.warm, set(&["tinyyolo"]));
    }
}
