//! Queue-semantics equivalence: the indexed engine vs a reference scan
//! model.
//!
//! The reference model is the pre-index implementation, kept verbatim
//! simple: one `VecDeque`, two linear scans per take, a full in-flight
//! scan per reap.  A property test drives identical random
//! publish/take/ack/release/reap sequences through both and asserts
//! identical delivery order, warm-hit flags, attempt counts, queue
//! order, and stats at every step — the indexed rebuild must be
//! observationally indistinguishable.
//!
//! The sharded engine ([`ShardedQueue`], DESIGN.md §13) deliberately
//! relaxes *cross-class* global order (classes on different shards
//! drain independently), so its contract is **per-class** equivalence:
//! under class-restricted takes it must replay byte-identical delivery
//! (ids, warm hits, attempt counts), totals, and per-class gauges
//! against the single-shard engine — with the QoS lanes on *and* off.
//!
//! Cache-affinity hints (DESIGN.md §15) join the replay here too: a
//! take whose hot-set is stale must degrade to the hint-free ranking,
//! and live hints must never desynchronize the sharded engine from the
//! single-shard engine.

use super::{InvocationQueue, MemQueue, QueueConfig, ShardedQueue, TakeFilter};
use crate::events::{EventSpec, Invocation, Priority};
use crate::prop;
use crate::util::clock::TestClock;
use crate::util::{Clock, SimTime};
use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Duration;

struct RefInFlight {
    invocation: Invocation,
    deadline: SimTime,
    attempt: u32,
}

/// The original scan-based queue semantics, as a passive model (the
/// caller passes `now` instead of a clock).
struct ScanModel {
    queued: VecDeque<Invocation>,
    in_flight: HashMap<String, RefInFlight>,
    attempts: HashMap<String, u32>,
    dead: Vec<Invocation>,
    acked: usize,
    visibility: Duration,
    max_attempts: u32,
}

impl ScanModel {
    fn new(visibility: Duration, max_attempts: u32) -> ScanModel {
        ScanModel {
            queued: VecDeque::new(),
            in_flight: HashMap::new(),
            attempts: HashMap::new(),
            dead: Vec::new(),
            acked: 0,
            visibility,
            max_attempts,
        }
    }

    fn publish(&mut self, inv: Invocation) {
        self.queued.push_back(inv);
    }

    /// Two linear passes: earliest warm match, else earliest supported.
    fn take(&mut self, filter: &TakeFilter, now: SimTime) -> Option<(String, bool, u32)> {
        let warm_pos = self
            .queued
            .iter()
            .position(|i| filter.accepts_warm(&i.spec.runtime));
        let pos = match warm_pos {
            Some(p) => Some((p, true)),
            None => self
                .queued
                .iter()
                .position(|i| filter.accepts_cold(&i.spec.runtime))
                .map(|p| (p, false)),
        };
        let (pos, warm_hit) = pos?;
        let invocation = self.queued.remove(pos).expect("position valid");
        let attempt = {
            let a = self.attempts.entry(invocation.id.clone()).or_insert(0);
            *a += 1;
            *a
        };
        let deadline =
            SimTime(now.as_micros() + self.visibility.as_micros() as u64);
        let id = invocation.id.clone();
        self.in_flight
            .insert(id.clone(), RefInFlight { invocation, deadline, attempt });
        Some((id, warm_hit, attempt))
    }

    fn ack(&mut self, id: &str) -> bool {
        if self.in_flight.remove(id).is_none() {
            return false;
        }
        self.attempts.remove(id);
        self.acked += 1;
        true
    }

    fn release(&mut self, id: &str) -> bool {
        let Some(f) = self.in_flight.remove(id) else {
            return false;
        };
        if let Some(a) = self.attempts.get_mut(id) {
            *a = a.saturating_sub(1);
        }
        self.queued.push_front(f.invocation);
        true
    }

    /// Full scan, then requeue in ascending `(deadline, id)` order — the
    /// deterministic order the indexed engine's min-heap pops in.
    fn reap_expired(&mut self, now: SimTime) -> usize {
        let mut expired: Vec<(SimTime, String)> = self
            .in_flight
            .iter()
            .filter(|(_, f)| f.deadline <= now)
            .map(|(id, f)| (f.deadline, id.clone()))
            .collect();
        expired.sort();
        let n = expired.len();
        for (_, id) in expired {
            let f = self.in_flight.remove(&id).expect("present");
            if f.attempt >= self.max_attempts {
                self.dead.push(f.invocation);
            } else {
                self.queued.push_front(f.invocation);
            }
        }
        n
    }

    /// (queued, in_flight, acked, dead)
    fn stats(&self) -> (usize, usize, usize, usize) {
        (self.queued.len(), self.in_flight.len(), self.acked, self.dead.len())
    }

    fn queued_runtimes(&self) -> Vec<String> {
        self.queued.iter().map(|i| i.spec.runtime.clone()).collect()
    }
}

/// Derive a filter from three random words: `runtimes` and `warm` are
/// bit-subsets of {r0..r3} (empty runtimes = match-any), `warm_only`
/// occasionally.
fn filter_from(a: u64, b: u64, c: u64) -> TakeFilter {
    let set = |bits: u64| -> HashSet<String> {
        (0..4).filter(|i| bits & (1 << i) != 0).map(|i| format!("r{i}")).collect()
    };
    TakeFilter { runtimes: set(a), warm: set(b), warm_only: c % 3 == 0, ..TakeFilter::default() }
}

fn inv(id: &str, runtime: &str) -> Invocation {
    Invocation::new(id, EventSpec::new(runtime, "datasets/d"), SimTime(0))
}

#[test]
fn property_indexed_queue_equals_scan_model() {
    // Each op is 4 random words: (kind, a, b, c).
    prop::check(
        "indexed-queue-equals-scan-model",
        40,
        |rng| {
            (0..rng.range(5, 80))
                .map(|_| (rng.below(6), rng.next_u64(), rng.next_u64(), rng.next_u64()))
                .collect::<Vec<(u64, u64, u64, u64)>>()
        },
        |ops| {
            let clock = TestClock::new();
            let cfg = QueueConfig {
                visibility: Duration::from_secs(1),
                max_attempts: 2,
                ..QueueConfig::default()
            };
            let indexed = MemQueue::with_config(clock.clone(), cfg.clone());
            let mut model = ScanModel::new(cfg.visibility, cfg.max_attempts);
            // Ids handed out by takes, in order; acks/releases pick from
            // here (may be stale after a reap — both sides must then
            // agree the op fails).
            let mut outstanding: Vec<String> = Vec::new();
            for (step, &(kind, a, b, c)) in ops.iter().enumerate() {
                match kind {
                    // publish (twice as likely as the other ops)
                    0 | 1 => {
                        let rt = format!("r{}", a % 5); // r4 matches no filter
                        let id = format!("p{step}");
                        indexed.publish(inv(&id, &rt)).unwrap();
                        model.publish(inv(&id, &rt));
                    }
                    // take under a random filter
                    2 => {
                        let f = filter_from(a, b, c);
                        let got = indexed.take(&f).unwrap();
                        let want = model.take(&f, clock.now());
                        match (&got, &want) {
                            (None, None) => {}
                            (Some(lease), Some((id, warm, attempt))) => {
                                if &lease.invocation.id != id
                                    || lease.warm_hit != *warm
                                    || lease.attempt != *attempt
                                {
                                    return false;
                                }
                                outstanding.push(id.clone());
                            }
                            _ => return false,
                        }
                    }
                    // ack a previously-delivered id
                    3 => {
                        if outstanding.is_empty() {
                            continue;
                        }
                        let id = outstanding.remove(a as usize % outstanding.len());
                        if indexed.ack(&id).is_ok() != model.ack(&id) {
                            return false;
                        }
                    }
                    // release a previously-delivered id
                    4 => {
                        if outstanding.is_empty() {
                            continue;
                        }
                        let id = outstanding.remove(a as usize % outstanding.len());
                        if indexed.release(&id).is_ok() != model.release(&id) {
                            return false;
                        }
                    }
                    // advance time and reap
                    _ => {
                        clock.advance(Duration::from_millis(a % 1500));
                        let n1 = indexed.reap_expired().unwrap();
                        let n2 = model.reap_expired(clock.now());
                        if n1 != n2 {
                            return false;
                        }
                    }
                }
                // After every op: identical stats and identical global
                // queue order (runtimes by position).
                let s = indexed.stats().unwrap();
                if (s.queued, s.in_flight, s.acked, s.dead) != model.stats() {
                    return false;
                }
                if indexed.queued_runtimes() != model.queued_runtimes() {
                    return false;
                }
            }
            true
        },
    );
}

/// Single-class filter over `r{a%4}`: the restriction under which the
/// sharded engine promises byte-identical replay (a class lives wholly
/// on one shard, so cross-shard reordering cannot be observed).  `b`
/// toggles the warm set, `c` mixes in warm-only probes and QoS pins.
fn class_filter(a: u64, b: u64, c: u64) -> (String, TakeFilter) {
    let rt = format!("r{}", a % 4);
    let warm: HashSet<String> = if b % 2 == 0 {
        HashSet::from([rt.clone()])
    } else {
        HashSet::new()
    };
    let priority = match c % 7 {
        0 => Some(Priority::Interactive),
        1 => Some(Priority::Batch),
        _ => None,
    };
    let filter = TakeFilter {
        runtimes: HashSet::from([rt.clone()]),
        warm,
        warm_only: c % 5 == 0,
        priority,
        ..TakeFilter::default()
    };
    (rt, filter)
}

fn inv_pri(id: &str, runtime: &str, b: u64) -> Invocation {
    let priority = if b % 2 == 0 { Priority::Interactive } else { Priority::Batch };
    Invocation::new(
        id,
        EventSpec::new(runtime, "datasets/d").with_priority(priority),
        SimTime(0),
    )
}

/// Like [`inv_pri`], but the dataset cycles through three objects so
/// cache-affinity hints can genuinely match queued work.
fn inv_ds(id: &str, runtime: &str, b: u64) -> Invocation {
    let priority = if b % 2 == 0 { Priority::Interactive } else { Priority::Batch };
    Invocation::new(
        id,
        EventSpec::new(runtime, &format!("datasets/d{}", (b >> 8) % 3))
            .with_priority(priority),
        SimTime(0),
    )
}

/// Random hot-set over the same three-object dataset namespace
/// [`inv_ds`] publishes into (bits 16..19 of `c`).
fn hot_hints(c: u64) -> Vec<String> {
    (0..3)
        .filter(|i| c & (1 << (i + 16)) != 0)
        .map(|i| format!("datasets/d{i}"))
        .collect()
}

/// The tentpole acceptance property: a 4-shard [`ShardedQueue`] against
/// the single-shard engine, QoS lanes ON (default burst), mixed
/// priorities, class-restricted takes, acks, releases, and expiry reaps
/// — identical per-class delivery (id, warm hit, attempt), identical
/// totals, identical per-class gauges, identical dead letters, at every
/// step.  PR 6's burst:1 interleave is part of the replay: the per-lane
/// streak state must evolve identically inside whichever shard owns the
/// class.
#[test]
fn property_sharded_queue_equals_single_shard_per_class() {
    prop::check(
        "sharded-equals-single-shard-per-class",
        40,
        |rng| {
            (0..rng.range(5, 80))
                .map(|_| (rng.below(6), rng.next_u64(), rng.next_u64(), rng.next_u64()))
                .collect::<Vec<(u64, u64, u64, u64)>>()
        },
        |ops| {
            let clock = TestClock::new();
            let cfg = QueueConfig {
                visibility: Duration::from_secs(1),
                max_attempts: 2,
                ..QueueConfig::default()
            };
            let sharded = ShardedQueue::with_config(clock.clone(), cfg.clone(), 4);
            let single = MemQueue::with_config(clock.clone(), cfg.clone());
            let mut outstanding: Vec<String> = Vec::new();
            for (step, &(kind, a, b, c)) in ops.iter().enumerate() {
                match kind {
                    // publish (twice as likely), mixed QoS priorities
                    0 | 1 => {
                        let rt = format!("r{}", a % 4);
                        let id = format!("p{step}");
                        sharded.publish(inv_pri(&id, &rt, b)).unwrap();
                        single.publish(inv_pri(&id, &rt, b)).unwrap();
                    }
                    // class-restricted take under a random filter
                    2 => {
                        let (_, f) = class_filter(a, b, c);
                        let got = sharded.take(&f).unwrap();
                        let want = single.take(&f).unwrap();
                        match (&got, &want) {
                            (None, None) => {}
                            (Some(g), Some(w)) => {
                                if g.invocation.id != w.invocation.id
                                    || g.warm_hit != w.warm_hit
                                    || g.attempt != w.attempt
                                {
                                    return false;
                                }
                                outstanding.push(g.invocation.id.clone());
                            }
                            _ => return false,
                        }
                    }
                    // ack a previously-delivered id
                    3 => {
                        if outstanding.is_empty() {
                            continue;
                        }
                        let id = outstanding.remove(a as usize % outstanding.len());
                        if sharded.ack(&id).is_ok() != single.ack(&id).is_ok() {
                            return false;
                        }
                    }
                    // release a previously-delivered id
                    4 => {
                        if outstanding.is_empty() {
                            continue;
                        }
                        let id = outstanding.remove(a as usize % outstanding.len());
                        if sharded.release(&id).is_ok() != single.release(&id).is_ok() {
                            return false;
                        }
                    }
                    // advance time and reap: same expiries on both sides
                    _ => {
                        clock.advance(Duration::from_millis(a % 1500));
                        if sharded.reap_expired().unwrap() != single.reap_expired().unwrap() {
                            return false;
                        }
                    }
                }
                // After every op: identical totals and identical
                // per-class gauges (depths, QoS splits, front ages).
                let s = sharded.stats().unwrap();
                let m = single.stats().unwrap();
                if (s.queued, s.in_flight, s.acked, s.dead)
                    != (m.queued, m.in_flight, m.acked, m.dead)
                {
                    return false;
                }
                if s.classes != m.classes {
                    return false;
                }
                // The shard sections must account for the totals exactly.
                if s.shards.len() != 4
                    || s.shards.iter().map(|x| x.queued).sum::<usize>() != m.queued
                    || s.shards.iter().map(|x| x.in_flight).sum::<usize>() != m.in_flight
                {
                    return false;
                }
                // Dead letters agree as a set (cross-shard concat order
                // vs global order is the one allowed difference).
                let mut d1: Vec<String> =
                    sharded.dead_letters().into_iter().map(|i| i.id).collect();
                let mut d2: Vec<String> =
                    single.dead_letters().into_iter().map(|i| i.id).collect();
                d1.sort();
                d2.sort();
                if d1 != d2 {
                    return false;
                }
            }
            true
        },
    );
}

/// Lanes OFF (`interactive_burst == 0`): per class, the sharded engine
/// must match the priority-unaware *scan model* directly — composing
/// the shard split with the pre-QoS, pre-index semantics end to end.
#[test]
fn property_sharded_lanes_off_equals_scan_model_per_class() {
    prop::check(
        "sharded-lanes-off-equals-scan-model-per-class",
        40,
        |rng| {
            (0..rng.range(5, 60))
                .map(|_| (rng.below(4), rng.next_u64(), rng.next_u64(), rng.next_u64()))
                .collect::<Vec<(u64, u64, u64, u64)>>()
        },
        |ops| {
            let clock = TestClock::new();
            let cfg = QueueConfig { interactive_burst: 0, ..QueueConfig::default() };
            let sharded = ShardedQueue::with_config(clock.clone(), cfg.clone(), 4);
            let mut model = ScanModel::new(cfg.visibility, cfg.max_attempts);
            for (step, &(kind, a, b, c)) in ops.iter().enumerate() {
                match kind {
                    0 | 1 => {
                        let rt = format!("r{}", a % 4);
                        let id = format!("p{step}");
                        sharded.publish(inv_pri(&id, &rt, b)).unwrap();
                        model.publish(inv_pri(&id, &rt, b));
                    }
                    _ => {
                        // QoS pins would be invisible to the model; the
                        // lanes-off contract is about unpinned takes.
                        // (5 keeps warm-only probes in play, 2 is a
                        // plain cold-capable take — neither pins.)
                        let (_, f) = class_filter(a, b, if c % 2 == 0 { 2 } else { 5 });
                        let got = sharded.take(&f).unwrap();
                        let want = model.take(&f, clock.now());
                        match (&got, &want) {
                            (None, None) => {}
                            (Some(lease), Some((id, warm, attempt))) => {
                                if &lease.invocation.id != id
                                    || lease.warm_hit != *warm
                                    || lease.attempt != *attempt
                                {
                                    return false;
                                }
                            }
                            _ => return false,
                        }
                    }
                }
                let s = sharded.stats().unwrap();
                let (mq, mf, ma, md) = model.stats();
                if (s.queued, s.in_flight, s.acked, s.dead) != (mq, mf, ma, md) {
                    return false;
                }
                // Per-class depth projection of the model's global order
                // must match the sharded per-class gauges.
                for cs in &s.classes {
                    let want = model
                        .queued_runtimes()
                        .iter()
                        .filter(|r| **r == cs.runtime)
                        .count();
                    if cs.queued != want {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn property_lanes_off_mixed_priorities_equal_scan_model() {
    // With `interactive_burst == 0` the QoS lanes are an exact no-op:
    // even under mixed priorities, delivery must stay byte-identical to
    // the priority-unaware scan model (the pre-QoS semantics).  This is
    // the ablation mode `benches/micro_pipeline.rs` compares against.
    prop::check(
        "lanes-off-equals-scan-model",
        40,
        |rng| {
            (0..rng.range(5, 60))
                .map(|_| (rng.below(4), rng.next_u64(), rng.next_u64(), rng.next_u64()))
                .collect::<Vec<(u64, u64, u64, u64)>>()
        },
        |ops| {
            let clock = TestClock::new();
            let cfg = QueueConfig { interactive_burst: 0, ..QueueConfig::default() };
            let indexed = MemQueue::with_config(clock.clone(), cfg.clone());
            let mut model = ScanModel::new(cfg.visibility, cfg.max_attempts);
            for (step, &(kind, a, b, c)) in ops.iter().enumerate() {
                match kind {
                    0 | 1 => {
                        let rt = format!("r{}", a % 4);
                        let priority =
                            if b % 2 == 0 { Priority::Interactive } else { Priority::Batch };
                        let id = format!("p{step}");
                        let mk = || {
                            Invocation::new(
                                &id,
                                EventSpec::new(&rt, "datasets/d").with_priority(priority),
                                SimTime(0),
                            )
                        };
                        indexed.publish(mk()).unwrap();
                        model.publish(mk());
                    }
                    _ => {
                        let f = filter_from(a, b, c);
                        let got = indexed.take(&f).unwrap();
                        let want = model.take(&f, clock.now());
                        match (&got, &want) {
                            (None, None) => {}
                            (Some(lease), Some((id, warm, attempt))) => {
                                if &lease.invocation.id != id
                                    || lease.warm_hit != *warm
                                    || lease.attempt != *attempt
                                {
                                    return false;
                                }
                            }
                            _ => return false,
                        }
                    }
                }
                if indexed.queued_runtimes() != model.queued_runtimes() {
                    return false;
                }
            }
            true
        },
    );
}

/// Stale affinity hints are a pure no-op (DESIGN.md §15): a take whose
/// hot-set names datasets nothing queued reads — e.g. objects evicted
/// since the node last gossiped its summary — must replay
/// byte-identical to the hint-free scan model.  The preference degrades
/// to the legacy warm ▸ FIFO ranking; never an error, never a skipped
/// or reordered invocation.
#[test]
fn property_stale_affinity_hints_equal_hint_free_scan_model() {
    prop::check(
        "stale-affinity-hints-equal-scan-model",
        40,
        |rng| {
            (0..rng.range(5, 60))
                .map(|_| (rng.below(4), rng.next_u64(), rng.next_u64(), rng.next_u64()))
                .collect::<Vec<(u64, u64, u64, u64)>>()
        },
        |ops| {
            let clock = TestClock::new();
            let cfg = QueueConfig { interactive_burst: 0, ..QueueConfig::default() };
            let indexed = MemQueue::with_config(clock.clone(), cfg.clone());
            let mut model = ScanModel::new(cfg.visibility, cfg.max_attempts);
            for (step, &(kind, a, b, c)) in ops.iter().enumerate() {
                match kind {
                    0 | 1 => {
                        let rt = format!("r{}", a % 4);
                        let id = format!("p{step}");
                        indexed.publish(inv_ds(&id, &rt, b)).unwrap();
                        model.publish(inv_ds(&id, &rt, b));
                    }
                    _ => {
                        // The indexed engine sees hints for datasets no
                        // queued invocation reads; the model never sees
                        // hints at all.  Both must hand out the same
                        // lease.
                        let f = filter_from(a, b, c);
                        let hinted = f
                            .clone()
                            .with_hot_datasets((0..2).map(|i| format!("datasets/gone{i}")));
                        let got = indexed.take(&hinted).unwrap();
                        let want = model.take(&f, clock.now());
                        match (&got, &want) {
                            (None, None) => {}
                            (Some(lease), Some((id, warm, attempt))) => {
                                if &lease.invocation.id != id
                                    || lease.warm_hit != *warm
                                    || lease.attempt != *attempt
                                {
                                    return false;
                                }
                            }
                            _ => return false,
                        }
                    }
                }
                if indexed.queued_runtimes() != model.queued_runtimes() {
                    return false;
                }
            }
            true
        },
    );
}

/// Affinity hints ride the per-class sharded contract unchanged: with
/// random hot-sets over the live dataset namespace (QoS lanes ON, mixed
/// priorities, acks, releases, expiry reaps), the 4-shard engine must
/// still replay byte-identical per-class delivery against the
/// single-shard engine.  The hot tier runs inside whichever shard owns
/// the class — the same lane code on both sides — so hints must never
/// desynchronize the two engines.
#[test]
fn property_sharded_equals_single_shard_with_affinity_hints() {
    prop::check(
        "sharded-equals-single-shard-with-affinity-hints",
        40,
        |rng| {
            (0..rng.range(5, 80))
                .map(|_| (rng.below(6), rng.next_u64(), rng.next_u64(), rng.next_u64()))
                .collect::<Vec<(u64, u64, u64, u64)>>()
        },
        |ops| {
            let clock = TestClock::new();
            let cfg = QueueConfig {
                visibility: Duration::from_secs(1),
                max_attempts: 2,
                ..QueueConfig::default()
            };
            let sharded = ShardedQueue::with_config(clock.clone(), cfg.clone(), 4);
            let single = MemQueue::with_config(clock.clone(), cfg.clone());
            let mut outstanding: Vec<String> = Vec::new();
            for (step, &(kind, a, b, c)) in ops.iter().enumerate() {
                match kind {
                    0 | 1 => {
                        let rt = format!("r{}", a % 4);
                        let id = format!("p{step}");
                        sharded.publish(inv_ds(&id, &rt, b)).unwrap();
                        single.publish(inv_ds(&id, &rt, b)).unwrap();
                    }
                    2 => {
                        let (_, f) = class_filter(a, b, c);
                        let f = f.with_hot_datasets(hot_hints(c));
                        let got = sharded.take(&f).unwrap();
                        let want = single.take(&f).unwrap();
                        match (&got, &want) {
                            (None, None) => {}
                            (Some(g), Some(w)) => {
                                if g.invocation.id != w.invocation.id
                                    || g.warm_hit != w.warm_hit
                                    || g.attempt != w.attempt
                                {
                                    return false;
                                }
                                outstanding.push(g.invocation.id.clone());
                            }
                            _ => return false,
                        }
                    }
                    3 => {
                        if outstanding.is_empty() {
                            continue;
                        }
                        let id = outstanding.remove(a as usize % outstanding.len());
                        if sharded.ack(&id).is_ok() != single.ack(&id).is_ok() {
                            return false;
                        }
                    }
                    4 => {
                        if outstanding.is_empty() {
                            continue;
                        }
                        let id = outstanding.remove(a as usize % outstanding.len());
                        if sharded.release(&id).is_ok() != single.release(&id).is_ok() {
                            return false;
                        }
                    }
                    _ => {
                        clock.advance(Duration::from_millis(a % 1500));
                        if sharded.reap_expired().unwrap() != single.reap_expired().unwrap() {
                            return false;
                        }
                    }
                }
                let s = sharded.stats().unwrap();
                let m = single.stats().unwrap();
                if (s.queued, s.in_flight, s.acked, s.dead)
                    != (m.queued, m.in_flight, m.acked, m.dead)
                {
                    return false;
                }
                if s.classes != m.classes {
                    return false;
                }
            }
            true
        },
    );
}
