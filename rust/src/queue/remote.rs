//! Distributed invocation queue: TCP server + client over [`crate::wire`].
//!
//! Mirrors the paper's deployment: one shared queue service (Bedrock), many
//! node managers polling it.  `QueueClient` implements [`InvocationQueue`]
//! so node managers are agnostic to whether the queue is in-process or
//! remote.

use super::{InvocationQueue, Lease, QueueStats, TakeFilter};
use crate::events::Invocation;
use crate::json::Json;
use crate::wire::{Handler, RpcClient, RpcServer};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Serves any [`InvocationQueue`] backend over TCP.
pub struct QueueServer {
    inner: RpcServer,
}

impl QueueServer {
    pub fn serve(addr: &str, backend: Arc<dyn InvocationQueue>) -> Result<QueueServer> {
        let handler: Handler = Arc::new(move |method, params, _blob| match method {
            "publish" => {
                let inv = Invocation::from_json(params.req("invocation")?)?;
                backend.publish(inv)?;
                Ok((Json::obj(), None))
            }
            "take" => {
                let filter = TakeFilter::from_json(params.req("filter")?)?;
                match backend.take(&filter)? {
                    Some(lease) => Ok((
                        Json::obj()
                            .set("invocation", lease.invocation.to_json())
                            .set("warm_hit", lease.warm_hit)
                            .set("attempt", lease.attempt as u64),
                        None,
                    )),
                    None => Ok((Json::Null, None)),
                }
            }
            "ack" => {
                backend.ack(params.str_of("id")?)?;
                Ok((Json::obj(), None))
            }
            "release" => {
                backend.release(params.str_of("id")?)?;
                Ok((Json::obj(), None))
            }
            "reap" => Ok((
                Json::obj().set("reaped", backend.reap_expired()?),
                None,
            )),
            "stats" => {
                let s = backend.stats()?;
                Ok((
                    Json::obj()
                        .set("queued", s.queued)
                        .set("in_flight", s.in_flight)
                        .set("acked", s.acked)
                        .set("dead", s.dead),
                    None,
                ))
            }
            other => Err(anyhow!("unknown queue method {other}")),
        });
        Ok(QueueServer { inner: RpcServer::serve(addr, handler)? })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    pub fn shutdown(&mut self) {
        self.inner.shutdown();
    }
}

/// TCP client implementing [`InvocationQueue`].
pub struct QueueClient {
    rpc: RpcClient,
}

impl QueueClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs + std::fmt::Debug) -> Result<QueueClient> {
        Ok(QueueClient { rpc: RpcClient::connect(addr)? })
    }
}

impl InvocationQueue for QueueClient {
    fn publish(&self, inv: Invocation) -> Result<()> {
        self.rpc
            .call("publish", Json::obj().set("invocation", inv.to_json()))?;
        Ok(())
    }

    fn take(&self, filter: &TakeFilter) -> Result<Option<Lease>> {
        let out = self
            .rpc
            .call("take", Json::obj().set("filter", filter.to_json()))?;
        if out.is_null() {
            return Ok(None);
        }
        Ok(Some(Lease {
            invocation: Invocation::from_json(out.req("invocation")?)?,
            warm_hit: out.bool_of("warm_hit")?,
            attempt: out.u64_of("attempt")? as u32,
        }))
    }

    fn ack(&self, invocation_id: &str) -> Result<()> {
        self.rpc.call("ack", Json::obj().set("id", invocation_id))?;
        Ok(())
    }

    fn release(&self, invocation_id: &str) -> Result<()> {
        self.rpc.call("release", Json::obj().set("id", invocation_id))?;
        Ok(())
    }

    fn reap_expired(&self) -> Result<usize> {
        let out = self.rpc.call("reap", Json::obj())?;
        Ok(out.usize_of("reaped")?)
    }

    fn stats(&self) -> Result<QueueStats> {
        let out = self.rpc.call("stats", Json::obj())?;
        Ok(QueueStats {
            queued: out.usize_of("queued")?,
            in_flight: out.usize_of("in_flight")?,
            acked: out.usize_of("acked")?,
            dead: out.usize_of("dead")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::EventSpec;
    use crate::queue::MemQueue;
    use crate::util::clock::TestClock;
    use crate::util::SimTime;

    fn setup() -> (QueueServer, QueueClient) {
        let backend = MemQueue::new(TestClock::new());
        let server = QueueServer::serve("127.0.0.1:0", backend).unwrap();
        let client = QueueClient::connect(server.addr()).unwrap();
        (server, client)
    }

    fn inv(id: &str, runtime: &str) -> Invocation {
        Invocation::new(id, EventSpec::new(runtime, "datasets/d"), SimTime(7))
    }

    #[test]
    fn publish_take_ack_over_tcp() {
        let (_s, q) = setup();
        q.publish(inv("1", "tinyyolo")).unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "1");
        assert_eq!(lease.invocation.spec.runtime, "tinyyolo");
        assert_eq!(lease.attempt, 1);
        assert_eq!(
            lease.invocation.stamps.r_start,
            Some(SimTime(7)),
            "timestamps survive the wire"
        );
        q.ack("1").unwrap();
        assert_eq!(q.stats().unwrap().acked, 1);
    }

    #[test]
    fn empty_take_returns_none() {
        let (_s, q) = setup();
        assert!(q.take(&TakeFilter::default()).unwrap().is_none());
    }

    #[test]
    fn warm_preference_over_tcp() {
        let (_s, q) = setup();
        q.publish(inv("cold", "a")).unwrap();
        q.publish(inv("warm", "b")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_warm(vec!["b".into()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "warm");
        assert!(lease.warm_hit);
    }

    #[test]
    fn errors_propagate_over_tcp() {
        let (_s, q) = setup();
        assert!(q.ack("missing").is_err());
        q.publish(inv("1", "a")).unwrap();
        assert!(q.publish(inv("1", "a")).is_err(), "duplicate id");
    }

    #[test]
    fn multiple_node_clients_share_queue() {
        let backend = MemQueue::new(TestClock::new());
        let server = QueueServer::serve("127.0.0.1:0", backend).unwrap();
        let addr = server.addr();
        let publisher = QueueClient::connect(addr).unwrap();
        for i in 0..60 {
            publisher.publish(inv(&format!("i{i}"), "a")).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                let c = QueueClient::connect(addr).unwrap();
                let mut n = 0;
                while let Some(lease) = c.take(&TakeFilter::default()).unwrap() {
                    c.ack(&lease.invocation.id).unwrap();
                    n += 1;
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 60);
    }
}
