//! Distributed invocation queue: TCP server + client over [`crate::wire`].
//!
//! Mirrors the paper's deployment: one shared queue service (Bedrock), many
//! node managers polling it.  `QueueClient` implements [`InvocationQueue`]
//! so node managers are agnostic to whether the queue is in-process or
//! remote.

use super::{InvocationQueue, Lease, QueueStats, TakeFilter};
use crate::events::Invocation;
use crate::json::Json;
use crate::wire::{
    poll_chunked, ClientConfig, DeferHandler, Outcome, Park, RpcClient, RpcConfig, RpcServer,
    LONG_POLL_CHUNK,
};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn lease_to_json(lease: Option<Lease>) -> Json {
    match lease {
        Some(lease) => Json::obj()
            .set("invocation", lease.invocation.to_json())
            .set("warm_hit", lease.warm_hit)
            .set("attempt", lease.attempt as u64),
        None => Json::Null,
    }
}

fn lease_from_json(out: &Json) -> Result<Option<Lease>> {
    if out.is_null() {
        return Ok(None);
    }
    Ok(Some(Lease {
        invocation: Invocation::from_json(out.req("invocation")?)?,
        warm_hit: out.bool_of("warm_hit")?,
        attempt: out.u64_of("attempt")? as u32,
    }))
}

/// Serves any [`InvocationQueue`] backend over TCP.
pub struct QueueServer {
    inner: RpcServer,
}

impl QueueServer {
    pub fn serve(addr: &str, backend: Arc<dyn InvocationQueue>) -> Result<QueueServer> {
        QueueServer::serve_with(addr, backend, RpcConfig::default())
    }

    pub fn serve_with(
        addr: &str,
        backend: Arc<dyn InvocationQueue>,
        rpc: RpcConfig,
    ) -> Result<QueueServer> {
        let handler: DeferHandler = Arc::new(move |method, params, _blob| match method {
            "publish" => {
                let inv = Invocation::from_json(params.req("invocation")?)?;
                backend.publish(inv)?;
                Ok(Outcome::Ready(Json::obj(), None))
            }
            "publish_batch" => {
                let mut invs = Vec::new();
                for j in params.arr_of("invocations")? {
                    invs.push(Invocation::from_json(j)?);
                }
                backend.publish_batch(invs)?;
                Ok(Outcome::Ready(Json::obj(), None))
            }
            "take" => {
                let filter = TakeFilter::from_json(params.req("filter")?)?;
                Ok(Outcome::Ready(lease_to_json(backend.take(&filter)?), None))
            }
            "take_batch" => {
                let filter = TakeFilter::from_json(params.req("filter")?)?;
                let max = params.usize_of("max")?;
                let leases: Vec<Json> = backend
                    .take_batch(&filter, max)?
                    .into_iter()
                    .map(|l| lease_to_json(Some(l)))
                    .collect();
                Ok(Outcome::Ready(Json::obj().set("leases", Json::Arr(leases)), None))
            }
            "take_batch_grouped" => {
                let filter = TakeFilter::from_json(params.req("filter")?)?;
                let max = params.usize_of("max")?;
                let leases: Vec<Json> = backend
                    .take_batch_grouped(&filter, max)?
                    .into_iter()
                    .map(|l| lease_to_json(Some(l)))
                    .collect();
                Ok(Outcome::Ready(Json::obj().set("leases", Json::Arr(leases)), None))
            }
            "take_timeout" => {
                // Server-side long poll, reactor edition: probe once,
                // and if the queue is dry park the request as a reactor
                // registration.  An idle node manager now costs a waiter
                // entry, not a blocked thread — the property that lets
                // one queue server carry hundreds of pollers on a
                // handful of OS threads.
                let filter = TakeFilter::from_json(params.req("filter")?)?;
                let ms = params
                    .u64_of("timeout_ms")
                    .unwrap_or(0)
                    .min(LONG_POLL_CHUNK.as_millis() as u64);
                if let Some(lease) = backend.take(&filter)? {
                    return Ok(Outcome::Ready(lease_to_json(Some(lease)), None));
                }
                if ms == 0 {
                    // non-blocking probe: answer empty now
                    return Ok(Outcome::Ready(Json::Null, None));
                }
                let deadline = Instant::now() + Duration::from_millis(ms);
                let backend = backend.clone();
                Ok(Outcome::Park(Park::new(deadline, move || {
                    Ok(backend.take(&filter)?.map(|l| (lease_to_json(Some(l)), None)))
                })))
            }
            "ack" => {
                backend.ack(params.str_of("id")?)?;
                Ok(Outcome::Ready(Json::obj(), None))
            }
            "ack_batch" => {
                let ids: Vec<String> = params
                    .arr_of("ids")?
                    .iter()
                    .filter_map(|j| j.as_str().map(String::from))
                    .collect();
                backend.ack_batch(&ids)?;
                Ok(Outcome::Ready(Json::obj(), None))
            }
            "release" => {
                backend.release(params.str_of("id")?)?;
                Ok(Outcome::Ready(Json::obj(), None))
            }
            "reap" => Ok(Outcome::Ready(
                Json::obj().set("reaped", backend.reap_expired()?),
                None,
            )),
            "stats" => {
                let s = backend.stats()?;
                let classes: Vec<Json> =
                    s.classes.iter().map(|c| c.to_json()).collect();
                let mut out = Json::obj()
                    .set("queued", s.queued)
                    .set("in_flight", s.in_flight)
                    .set("acked", s.acked)
                    .set("dead", s.dead)
                    .set("classes", Json::Arr(classes));
                // Omitted entirely for single-shard backends: pre-shard
                // peers see the exact wire shape they always did.
                if !s.shards.is_empty() {
                    let shards: Vec<Json> =
                        s.shards.iter().map(|x| x.to_json()).collect();
                    out = out.set("shards", Json::Arr(shards));
                }
                Ok(Outcome::Ready(out, None))
            }
            other => Err(anyhow!("unknown queue method {other}")),
        });
        Ok(QueueServer { inner: RpcServer::serve_deferrable(addr, handler, rpc)? })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    pub fn shutdown(&mut self) {
        self.inner.shutdown();
    }
}

/// TCP client implementing [`InvocationQueue`].
pub struct QueueClient {
    rpc: RpcClient,
}

impl QueueClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs + std::fmt::Debug) -> Result<QueueClient> {
        // Node managers are long-lived; ride out a queue-server restart
        // by redialing (and retrying idempotent polls once) instead of
        // wedging on a broken channel.
        let cfg = ClientConfig { reconnect: true, ..ClientConfig::default() };
        Ok(QueueClient { rpc: RpcClient::connect_with(addr, cfg)? })
    }

    /// RPC round trips issued so far (batching assertions, diagnostics).
    pub fn rpc_calls(&self) -> u64 {
        self.rpc.calls_issued()
    }
}

impl InvocationQueue for QueueClient {
    fn publish(&self, inv: Invocation) -> Result<()> {
        self.rpc
            .call("publish", Json::obj().set("invocation", inv.to_json()))?;
        Ok(())
    }

    /// N publishes, one RPC.
    fn publish_batch(&self, invs: Vec<Invocation>) -> Result<()> {
        let arr = invs.iter().map(|i| i.to_json()).collect();
        self.rpc.call(
            "publish_batch",
            Json::obj().set("invocations", Json::Arr(arr)),
        )?;
        Ok(())
    }

    fn take(&self, filter: &TakeFilter) -> Result<Option<Lease>> {
        // Takes are idempotent at the protocol level: a lease lost to a
        // mid-call crash is re-delivered by lease expiry, so the retry
        // can only cost a duplicate attempt, never a lost invocation.
        let out = self
            .rpc
            .call_idem("take", Json::obj().set("filter", filter.to_json()))?;
        lease_from_json(&out)
    }

    /// Up to `max` leases, one RPC — lets a node manager fill every free
    /// slot per round trip instead of paying one RPC per lease.
    fn take_batch(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        let out = self.rpc.call_idem(
            "take_batch",
            Json::obj().set("filter", filter.to_json()).set("max", max),
        )?;
        let mut leases = Vec::new();
        for j in out.arr_of("leases")? {
            if let Some(lease) = lease_from_json(j)? {
                leases.push(lease);
            }
        }
        Ok(leases)
    }

    /// One same-class chunk, one RPC — the server picks the lane (warm
    /// first, deepest under `prefer_deep`) and drains it under one lock.
    fn take_batch_grouped(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        let out = self.rpc.call_idem(
            "take_batch_grouped",
            Json::obj().set("filter", filter.to_json()).set("max", max),
        )?;
        let mut leases = Vec::new();
        for j in out.arr_of("leases")? {
            if let Some(lease) = lease_from_json(j)? {
                leases.push(lease);
            }
        }
        Ok(leases)
    }

    /// Remote long poll: chunked server-side blocking replaces the old
    /// single non-blocking probe, so idle dispatch latency over TCP is
    /// one notification instead of one poll interval.
    fn take_timeout(
        &self,
        filter: &TakeFilter,
        wall_timeout: Duration,
    ) -> Result<Option<Lease>> {
        poll_chunked(wall_timeout, |chunk_ms| {
            let out = self.rpc.call_idem(
                "take_timeout",
                Json::obj()
                    .set("filter", filter.to_json())
                    .set("timeout_ms", chunk_ms),
            )?;
            lease_from_json(&out)
        })
    }

    fn ack(&self, invocation_id: &str) -> Result<()> {
        self.rpc.call("ack", Json::obj().set("id", invocation_id))?;
        Ok(())
    }

    /// N acks, one RPC.
    fn ack_batch(&self, invocation_ids: &[String]) -> Result<()> {
        let arr = invocation_ids
            .iter()
            .map(|id| Json::from(id.as_str()))
            .collect();
        self.rpc
            .call("ack_batch", Json::obj().set("ids", Json::Arr(arr)))?;
        Ok(())
    }

    fn release(&self, invocation_id: &str) -> Result<()> {
        self.rpc.call("release", Json::obj().set("id", invocation_id))?;
        Ok(())
    }

    fn reap_expired(&self) -> Result<usize> {
        let out = self.rpc.call_idem("reap", Json::obj())?;
        Ok(out.usize_of("reaped")?)
    }

    fn stats(&self) -> Result<QueueStats> {
        let out = self.rpc.call_idem("stats", Json::obj())?;
        // `classes` parses leniently (absent → empty): the scalar gauges
        // predate the per-class probe.
        let classes = match out.get("classes").and_then(|j| j.as_arr()) {
            Some(arr) => arr
                .iter()
                .filter_map(|j| super::ClassStats::from_json(j).ok())
                .collect(),
            None => Vec::new(),
        };
        // `shards` is equally lenient: absent = single-shard peer.
        let shards = match out.get("shards").and_then(|j| j.as_arr()) {
            Some(arr) => arr
                .iter()
                .filter_map(|j| super::ShardStats::from_json(j).ok())
                .collect(),
            None => Vec::new(),
        };
        Ok(QueueStats {
            queued: out.usize_of("queued")?,
            in_flight: out.usize_of("in_flight")?,
            acked: out.usize_of("acked")?,
            dead: out.usize_of("dead")?,
            classes,
            shards,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::EventSpec;
    use crate::queue::MemQueue;
    use crate::util::clock::TestClock;
    use crate::util::SimTime;

    fn setup() -> (QueueServer, QueueClient) {
        let backend = MemQueue::new(TestClock::new());
        let server = QueueServer::serve("127.0.0.1:0", backend).unwrap();
        let client = QueueClient::connect(server.addr()).unwrap();
        (server, client)
    }

    fn inv(id: &str, runtime: &str) -> Invocation {
        Invocation::new(id, EventSpec::new(runtime, "datasets/d"), SimTime(7))
    }

    #[test]
    fn publish_take_ack_over_tcp() {
        let (_s, q) = setup();
        q.publish(inv("1", "tinyyolo")).unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "1");
        assert_eq!(lease.invocation.spec.runtime, "tinyyolo");
        assert_eq!(lease.attempt, 1);
        assert_eq!(
            lease.invocation.stamps.r_start,
            Some(SimTime(7)),
            "timestamps survive the wire"
        );
        q.ack("1").unwrap();
        assert_eq!(q.stats().unwrap().acked, 1);
    }

    #[test]
    fn shard_sections_survive_the_wire_and_default_to_empty() {
        // A sharded backend behind the same RPC server: the per-shard
        // breakdown rides the stats payload.
        let backend = crate::queue::ShardedQueue::new(TestClock::new(), 4);
        let server = QueueServer::serve("127.0.0.1:0", backend).unwrap();
        let q = QueueClient::connect(server.addr()).unwrap();
        q.publish(inv("1", "tinyyolo")).unwrap();
        q.publish(inv("2", "bert")).unwrap();
        let s = q.stats().unwrap();
        assert_eq!(s.queued, 2);
        assert_eq!(s.shards.len(), 4, "{:?}", s.shards);
        assert_eq!(s.shards.iter().map(|x| x.queued).sum::<usize>(), 2);
        assert!(s.shards.iter().any(|x| x.classes.contains(&"bert".into())));

        // A single-shard backend omits the section; pre-shard clients
        // (and this one) parse the payload unchanged.
        let (_s2, q2) = setup();
        q2.publish(inv("1", "tinyyolo")).unwrap();
        let s2 = q2.stats().unwrap();
        assert_eq!(s2.queued, 1);
        assert!(s2.shards.is_empty(), "absent shards section = single-shard");
    }

    #[test]
    fn shard_stats_json_is_lenient_to_unknown_and_missing_fields() {
        let full = crate::queue::ShardStats {
            shard: "shard-3".into(),
            queued: 5,
            in_flight: 2,
            acked: 9,
            dead: 1,
            classes: vec!["bert".into(), "tinyyolo".into()],
        };
        let back = crate::queue::ShardStats::from_json(&full.to_json()).unwrap();
        assert_eq!(back, full);
        // A newer peer's extra keys are ignored; optional gauges default.
        let sparse = Json::obj()
            .set("shard", "shard-0")
            .set("queued", 3usize)
            .set("zzz_future_field", "ignored");
        let back = crate::queue::ShardStats::from_json(&sparse).unwrap();
        assert_eq!(back.shard, "shard-0");
        assert_eq!(back.queued, 3);
        assert_eq!((back.in_flight, back.acked, back.dead), (0, 0, 0));
        assert!(back.classes.is_empty());
    }

    #[test]
    fn per_class_stats_survive_the_wire() {
        let (_s, q) = setup();
        q.publish(inv("1", "tinyyolo")).unwrap();
        q.publish(inv("2", "tinyyolo")).unwrap();
        q.publish(inv("3", "bert")).unwrap();
        let s = q.stats().unwrap();
        assert_eq!(s.classes.len(), 2, "{:?}", s.classes);
        assert_eq!(s.classes[0].runtime, "bert");
        assert_eq!(s.classes[0].queued, 1);
        assert_eq!(s.classes[1].runtime, "tinyyolo");
        assert_eq!(s.classes[1].queued, 2);
    }

    #[test]
    fn empty_take_returns_none() {
        let (_s, q) = setup();
        assert!(q.take(&TakeFilter::default()).unwrap().is_none());
    }

    #[test]
    fn warm_preference_over_tcp() {
        let (_s, q) = setup();
        q.publish(inv("cold", "a")).unwrap();
        q.publish(inv("warm", "b")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_warm(vec!["b".into()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "warm");
        assert!(lease.warm_hit);
    }

    #[test]
    fn errors_propagate_over_tcp() {
        let (_s, q) = setup();
        assert!(q.ack("missing").is_err());
        q.publish(inv("1", "a")).unwrap();
        assert!(q.publish(inv("1", "a")).is_err(), "duplicate id");
    }

    #[test]
    fn long_poll_returns_promptly_when_work_arrives_mid_wait() {
        let (s, q) = setup();
        let publisher = QueueClient::connect(s.addr()).unwrap();
        let t0 = std::time::Instant::now();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            publisher.publish(inv("late", "a")).unwrap();
        });
        // Without the server-side long poll this single call would probe
        // once, find nothing, and return None immediately.
        let lease = q
            .take_timeout(&TakeFilter::default(), Duration::from_secs(5))
            .unwrap()
            .expect("woken by the publish, not the poll interval");
        assert_eq!(lease.invocation.id, "late");
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(100), "{waited:?}");
        assert!(waited < Duration::from_secs(2), "{waited:?}");
        handle.join().unwrap();
    }

    #[test]
    fn long_poll_times_out_empty() {
        let (_s, q) = setup();
        let t0 = std::time::Instant::now();
        let got = q
            .take_timeout(&TakeFilter::default(), Duration::from_millis(200))
            .unwrap();
        assert!(got.is_none());
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(150), "{waited:?}");
        assert!(waited < Duration::from_secs(3), "{waited:?}");
    }

    #[test]
    fn long_poll_zero_timeout_is_a_probe() {
        let (_s, q) = setup();
        q.publish(inv("1", "a")).unwrap();
        let lease = q
            .take_timeout(&TakeFilter::default(), Duration::ZERO)
            .unwrap()
            .expect("immediate work still delivered");
        assert_eq!(lease.invocation.id, "1");
        assert!(q
            .take_timeout(&TakeFilter::default(), Duration::ZERO)
            .unwrap()
            .is_none());
    }

    #[test]
    fn parked_long_polls_release_the_worker_pool() {
        // Two concurrent long-polls against a server with ONE worker:
        // if parking held the worker, the second poll (and the publish
        // that wakes them) could never be served.
        let backend = MemQueue::new(TestClock::new());
        let rpc = RpcConfig { workers: 1, ..RpcConfig::default() };
        let server = QueueServer::serve_with("127.0.0.1:0", backend, rpc).unwrap();
        let addr = server.addr();
        let mut pollers = Vec::new();
        for _ in 0..2 {
            pollers.push(std::thread::spawn(move || {
                let c = QueueClient::connect(addr).unwrap();
                c.take_timeout(&TakeFilter::default(), Duration::from_secs(10)).unwrap()
            }));
        }
        std::thread::sleep(Duration::from_millis(150));
        let publisher = QueueClient::connect(addr).unwrap();
        publisher.publish(inv("wake-1", "a")).unwrap();
        publisher.publish(inv("wake-2", "a")).unwrap();
        let got: Vec<_> = pollers.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(
            got.iter().all(|l| l.is_some()),
            "both parked pollers woke on one worker: {got:?}"
        );
    }

    #[test]
    fn batch_ops_are_one_rpc_each() {
        let (_s, q) = setup();
        let before = q.rpc_calls();
        q.publish_batch((0..16).map(|i| inv(&format!("i{i}"), "a")).collect())
            .unwrap();
        assert_eq!(q.rpc_calls() - before, 1, "publish_batch = one RPC");

        let before = q.rpc_calls();
        let leases = q
            .take_batch(&TakeFilter::supporting(vec!["a".into()]), 16)
            .unwrap();
        assert_eq!(leases.len(), 16);
        assert_eq!(q.rpc_calls() - before, 1, "take_batch = one RPC");
        // FIFO order survives the wire
        let ids: Vec<&str> = leases.iter().map(|l| l.invocation.id.as_str()).collect();
        assert_eq!(ids[0], "i0");
        assert_eq!(ids[15], "i15");

        let before = q.rpc_calls();
        let ids: Vec<String> = leases.into_iter().map(|l| l.invocation.id).collect();
        q.ack_batch(&ids).unwrap();
        assert_eq!(q.rpc_calls() - before, 1, "ack_batch = one RPC");
        assert_eq!(q.stats().unwrap().acked, 16);
    }

    #[test]
    fn grouped_take_is_one_rpc_and_prefer_deep_survives_the_wire() {
        let (_s, q) = setup();
        q.publish(inv("a1", "a")).unwrap();
        for i in 0..5 {
            q.publish(inv(&format!("b{i}"), "b")).unwrap();
        }
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()]).preferring_deep(true);
        let before = q.rpc_calls();
        let leases = q.take_batch_grouped(&f, 8).unwrap();
        assert_eq!(q.rpc_calls() - before, 1, "take_batch_grouped = one RPC");
        let ids: Vec<&str> = leases.iter().map(|l| l.invocation.id.as_str()).collect();
        assert_eq!(ids, vec!["b0", "b1", "b2", "b3", "b4"], "deep lane chosen server-side");
    }

    #[test]
    fn multiple_node_clients_share_queue() {
        let backend = MemQueue::new(TestClock::new());
        let server = QueueServer::serve("127.0.0.1:0", backend).unwrap();
        let addr = server.addr();
        let publisher = QueueClient::connect(addr).unwrap();
        for i in 0..60 {
            publisher.publish(inv(&format!("i{i}"), "a")).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                let c = QueueClient::connect(addr).unwrap();
                let mut n = 0;
                while let Some(lease) = c.take(&TakeFilter::default()).unwrap() {
                    c.ack(&lease.invocation.id).unwrap();
                    n += 1;
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 60);
    }
}
