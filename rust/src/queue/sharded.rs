//! M-way sharded invocation queue: independent [`MemQueue`] shards with
//! rendezvous-hashed class lanes (DESIGN.md §13).
//!
//! One `MemQueue` mutex serializes every publish/take/ack in the fleet —
//! fine for one node manager, a ceiling for many.  [`ShardedQueue`]
//! splits the queue into M fully independent shards (each with its own
//! lock, condvar generation counter, and lease-reap heap) and routes
//! every runtime class to exactly one shard via the rendezvous-hashed
//! [`Membership`] registry.  Because a class lives wholly in one shard,
//! the invariants that matter ride along unchanged:
//!
//! * **per-class FIFO** and the QoS `burst:1` interleave are whatever the
//!   owning `MemQueue` shard does — byte-identical to the single-shard
//!   engine (property-tested against the PR 2 scan model in
//!   `queue::reference`);
//! * **warm-first** holds globally: a take's warm classes name their
//!   shards, and the warm pass probes exactly those shards (warm-only)
//!   before any cold work is considered;
//! * cross-*class* global arrival order is **not** preserved across
//!   shards (each shard numbers its own sequence space) — the same
//!   relaxation every partitioned queue makes.
//!
//! Shard selection is lock-free: the membership set is immutable after
//! construction, so `class → shard` is a pure hash with no shared state
//! touched until the single owning shard's lock.
//!
//! **Cross-shard long-poll.**  A `take_timeout` waiter must not miss work
//! landing on *any* shard while it parks.  The queue keeps one shared
//! generation counter: every work arrival (publish, release, reap
//! requeue) bumps it *after* the shard insert and notifies.  A waiter
//! snapshots the generation **before** probing, probes all candidate
//! shards, and only parks while the generation is unchanged — so a
//! publish that lands between probe and park flips the generation first
//! and the wait loop falls through to re-probe.  No registration can be
//! lost (proof sketch in DESIGN.md §13).

use super::{InvocationQueue, Lease, MemQueue, QueueConfig, QueueStats, ShardStats, TakeFilter};
use crate::coordinator::membership::Membership;
use crate::events::Invocation;
use crate::util::Clock;
use anyhow::{bail, Result};
use std::collections::{BTreeSet, HashSet};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// An [`InvocationQueue`] over M independent [`MemQueue`] shards.
pub struct ShardedQueue {
    shards: Vec<Arc<MemQueue>>,
    /// Shard membership (`shard-0 .. shard-{M-1}`), fixed at construction;
    /// `class → shard` routing is a pure function of it.
    membership: Membership,
    /// Work-arrival generation across *all* shards — the cross-shard
    /// long-poll wakeup channel (see module docs).
    generation: Mutex<u64>,
    available: Condvar,
}

impl ShardedQueue {
    /// `n` shards with default [`QueueConfig`] (`n = 0` is clamped to 1).
    pub fn new(clock: Arc<dyn Clock>, n: usize) -> Arc<ShardedQueue> {
        ShardedQueue::with_config(clock, QueueConfig::default(), n)
    }

    /// `n` shards sharing one [`QueueConfig`] (visibility, max attempts,
    /// and the QoS burst rule apply identically within every shard).
    pub fn with_config(
        clock: Arc<dyn Clock>,
        config: QueueConfig,
        n: usize,
    ) -> Arc<ShardedQueue> {
        let membership = Membership::shards(n);
        let shards = (0..membership.len())
            .map(|_| MemQueue::with_config(clock.clone(), config.clone()))
            .collect();
        Arc::new(ShardedQueue {
            shards,
            membership,
            generation: Mutex::new(0),
            available: Condvar::new(),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard member names, aligned with shard indices.
    pub fn shard_names(&self) -> &[String] {
        self.membership.members()
    }

    /// The shard owning `runtime` — lock-free (pure rendezvous hash over
    /// the immutable membership).
    pub fn shard_for(&self, runtime: &str) -> usize {
        self.membership.index_of(runtime).unwrap_or(0)
    }

    /// Queued runtime classes across all shards (shard-major order,
    /// seq-ordered within each shard) — diagnostics and the reference
    /// rig's per-class projections.
    pub fn queued_runtimes(&self) -> Vec<String> {
        self.shards.iter().flat_map(|s| s.queued_runtimes()).collect()
    }

    /// Dead-lettered invocations across all shards.
    pub fn dead_letters(&self) -> Vec<Invocation> {
        self.shards.iter().flat_map(|s| s.dead_letters()).collect()
    }

    /// Per-shard gauge sections (the `shards` stats payload).
    fn gather_shard_stats(&self) -> Result<Vec<ShardStats>> {
        let mut out = Vec::with_capacity(self.shards.len());
        for (i, shard) in self.shards.iter().enumerate() {
            let s = shard.stats()?;
            let classes: BTreeSet<String> = shard.queued_runtimes().into_iter().collect();
            out.push(ShardStats {
                shard: self.membership.members()[i].clone(),
                queued: s.queued,
                in_flight: s.in_flight,
                acked: s.acked,
                dead: s.dead,
                classes: classes.into_iter().collect(),
            });
        }
        Ok(out)
    }

    /// Work arrived somewhere: flip the shared generation and wake every
    /// parked long-poll.  Always *after* the owning shard's insert, so a
    /// woken waiter's re-probe finds the work.
    fn bump(&self) {
        *self.generation.lock().expect("poisoned") += 1;
        self.available.notify_all();
    }

    /// Sorted, deduplicated shard indices owning any class in `classes`.
    fn shards_of(&self, classes: &HashSet<String>) -> Vec<usize> {
        let set: BTreeSet<usize> =
            classes.iter().map(|c| self.shard_for(c)).collect();
        set.into_iter().collect()
    }

    /// Shards a cold take under `filter` must consider: the owners of the
    /// named classes, or every shard for a match-any filter.
    fn cold_shards(&self, filter: &TakeFilter) -> Vec<usize> {
        if filter.runtimes.is_empty() {
            (0..self.shards.len()).collect()
        } else {
            self.shards_of(&filter.runtimes)
        }
    }
}

impl InvocationQueue for ShardedQueue {
    fn publish(&self, inv: Invocation) -> Result<()> {
        let shard = self.shard_for(&inv.spec.runtime);
        self.shards[shard].publish(inv)?;
        self.bump();
        Ok(())
    }

    /// Split by owning shard, one `publish_batch` per shard (per-class
    /// order within the batch is preserved — a class maps to one shard
    /// and the per-shard sub-batches keep batch order).  In-batch
    /// duplicate ids are rejected before anything publishes; a duplicate
    /// against an *already live* id fails that shard's sub-batch
    /// all-or-nothing after earlier shards have published (ids are
    /// coordinator-issued and globally unique in every real deployment).
    fn publish_batch(&self, invs: Vec<Invocation>) -> Result<()> {
        let mut fresh: HashSet<String> = HashSet::with_capacity(invs.len());
        for inv in &invs {
            if !fresh.insert(inv.id.clone()) {
                bail!("duplicate invocation id {} in batch", inv.id);
            }
        }
        let mut per_shard: Vec<Vec<Invocation>> = vec![Vec::new(); self.shards.len()];
        for inv in invs {
            per_shard[self.shard_for(&inv.spec.runtime)].push(inv);
        }
        let mut published_any = false;
        for (shard, batch) in per_shard.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            if let Err(e) = self.shards[shard].publish_batch(batch) {
                if published_any {
                    self.bump();
                }
                return Err(e);
            }
            published_any = true;
        }
        if published_any {
            self.bump();
        }
        Ok(())
    }

    fn take(&self, filter: &TakeFilter) -> Result<Option<Lease>> {
        // Warm pass: warm classes name their shards; probing those
        // shards warm-only preserves global warm-over-cold precedence.
        if !filter.warm.is_empty() {
            let warm_probe = TakeFilter { warm_only: true, ..filter.clone() };
            for shard in self.shards_of(&filter.warm) {
                if let Some(lease) = self.shards[shard].take(&warm_probe)? {
                    return Ok(Some(lease));
                }
            }
        }
        if filter.warm_only {
            return Ok(None);
        }
        for shard in self.cold_shards(filter) {
            if let Some(lease) = self.shards[shard].take(filter)? {
                return Ok(Some(lease));
            }
        }
        Ok(None)
    }

    /// Equivalent to `max` consecutive takes (warm shards drain before
    /// cold ones, shards in index order), but pays O(shards) lock
    /// acquisitions instead of O(leases).
    fn take_batch(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        let mut out = Vec::new();
        if !filter.warm.is_empty() {
            let warm_probe = TakeFilter { warm_only: true, ..filter.clone() };
            for shard in self.shards_of(&filter.warm) {
                if out.len() >= max {
                    return Ok(out);
                }
                out.extend(self.shards[shard].take_batch(&warm_probe, max - out.len())?);
            }
        }
        if filter.warm_only {
            return Ok(out);
        }
        for shard in self.cold_shards(filter) {
            if out.len() >= max {
                break;
            }
            out.extend(self.shards[shard].take_batch(filter, max - out.len())?);
        }
        Ok(out)
    }

    /// Lock-free shard selection, then one single-shard grouped drain
    /// under that shard's lock ([`MemQueue::take_batch_grouped`] picks
    /// the lane and drains it in one hold).
    fn take_batch_grouped(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        if max == 0 {
            return Ok(Vec::new());
        }
        if !filter.warm.is_empty() {
            let warm_probe = TakeFilter { warm_only: true, ..filter.clone() };
            for shard in self.shards_of(&filter.warm) {
                let chunk = self.shards[shard].take_batch_grouped(&warm_probe, max)?;
                if !chunk.is_empty() {
                    return Ok(chunk);
                }
            }
        }
        if filter.warm_only {
            return Ok(Vec::new());
        }
        for shard in self.cold_shards(filter) {
            let chunk = self.shards[shard].take_batch_grouped(filter, max)?;
            if !chunk.is_empty() {
                return Ok(chunk);
            }
        }
        Ok(Vec::new())
    }

    /// An ack carries only the invocation id (class unknown), so it is
    /// offered to each shard; exactly one holds the lease.  O(M) lock
    /// acquisitions with M small and each miss O(1).
    fn ack(&self, invocation_id: &str) -> Result<()> {
        let mut last = None;
        for shard in &self.shards {
            match shard.ack(invocation_id) {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            anyhow::anyhow!("ack for unknown or expired lease: {invocation_id}")
        }))
    }

    fn release(&self, invocation_id: &str) -> Result<()> {
        let mut last = None;
        for shard in &self.shards {
            match shard.release(invocation_id) {
                Ok(()) => {
                    self.bump();
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last
            .unwrap_or_else(|| anyhow::anyhow!("release for unknown lease: {invocation_id}")))
    }

    fn reap_expired(&self) -> Result<usize> {
        let mut n = 0;
        for shard in &self.shards {
            n += shard.reap_expired()?;
        }
        if n > 0 {
            self.bump();
        }
        Ok(n)
    }

    /// Merged gauges: counters sum, per-class entries concatenate (class
    /// sets are disjoint across shards) and re-sort by runtime, and the
    /// per-shard breakdown rides in [`QueueStats::shards`].
    fn stats(&self) -> Result<QueueStats> {
        let mut merged = QueueStats::default();
        for shard in &self.shards {
            let s = shard.stats()?;
            merged.queued += s.queued;
            merged.in_flight += s.in_flight;
            merged.acked += s.acked;
            merged.dead += s.dead;
            merged.classes.extend(s.classes);
        }
        merged.classes.sort_by(|a, b| a.runtime.cmp(&b.runtime));
        merged.shards = self.gather_shard_stats()?;
        Ok(merged)
    }

    /// Cross-shard long poll that cannot lose a registration: snapshot
    /// the shared generation **before** probing, probe every candidate
    /// shard, and park only while the generation is unchanged.  Work
    /// landing on any shard after the snapshot bumps the generation, so
    /// either the probe saw it or the wait falls through immediately.
    fn take_timeout(
        &self,
        filter: &TakeFilter,
        wall_timeout: Duration,
    ) -> Result<Option<Lease>> {
        let deadline = Instant::now() + wall_timeout;
        loop {
            let gen_before = *self.generation.lock().expect("poisoned");
            if let Some(lease) = self.take(filter)? {
                return Ok(Some(lease));
            }
            let mut gen = self.generation.lock().expect("poisoned");
            while *gen == gen_before {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    return Ok(None);
                }
                let (guard, _) = self
                    .available
                    .wait_timeout(gen, left)
                    .expect("poisoned");
                gen = guard;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{EventSpec, Priority};
    use crate::util::clock::TestClock;
    use crate::util::SimTime;

    fn inv(id: &str, runtime: &str) -> Invocation {
        Invocation::new(id, EventSpec::new(runtime, "datasets/d"), SimTime(0))
    }

    fn inv_pri(id: &str, runtime: &str, p: Priority) -> Invocation {
        Invocation::new(
            id,
            EventSpec::new(runtime, "datasets/d").with_priority(p),
            SimTime(0),
        )
    }

    #[test]
    fn classes_partition_and_fifo_within_class() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        let classes = ["alpha", "beta", "gamma", "delta", "epsilon"];
        for i in 0..20 {
            let class = classes[i % classes.len()];
            q.publish(inv(&format!("{class}-{i}"), class)).unwrap();
        }
        // Every class routes to exactly one shard, and per-class delivery
        // is FIFO no matter which shard owns it.
        for class in classes {
            let f = TakeFilter::supporting(vec![class.to_string()]);
            let mut prev = None;
            while let Some(lease) = q.take(&f).unwrap() {
                assert_eq!(lease.invocation.spec.runtime, class);
                let n: usize = lease
                    .invocation
                    .id
                    .rsplit('-')
                    .next()
                    .unwrap()
                    .parse()
                    .unwrap();
                if let Some(p) = prev {
                    assert!(n > p, "per-class FIFO broken: {n} after {p}");
                }
                prev = Some(n);
                q.ack(&lease.invocation.id).unwrap();
            }
        }
        let s = q.stats().unwrap();
        assert_eq!((s.queued, s.in_flight, s.acked), (0, 0, 20));
    }

    #[test]
    fn match_any_take_drains_every_shard() {
        let q = ShardedQueue::new(TestClock::new(), 8);
        for i in 0..40 {
            q.publish(inv(&format!("i{i}"), &format!("class-{}", i % 10))).unwrap();
        }
        let mut got = HashSet::new();
        while let Some(lease) = q.take(&TakeFilter::default()).unwrap() {
            got.insert(lease.invocation.id.clone());
            q.ack(&lease.invocation.id).unwrap();
        }
        assert_eq!(got.len(), 40, "match-any must reach every shard");
    }

    #[test]
    fn warm_preference_wins_across_shards() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        // Find two classes owned by different shards.
        let (mut a, mut b) = ("c0".to_string(), String::new());
        for i in 1..64 {
            let c = format!("c{i}");
            if q.shard_for(&c) != q.shard_for(&a) {
                b = c;
                break;
            }
        }
        assert!(!b.is_empty(), "no second shard found");
        q.publish(inv("cold-first", &a)).unwrap();
        q.publish(inv("warm-later", &b)).unwrap();
        let f = TakeFilter::supporting(vec![a.clone(), b.clone()])
            .with_warm(vec![b.clone()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "warm-later", "warm beats older cold work");
        assert!(lease.warm_hit);
        // Warm drained: the cold invocation is next.
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "cold-first");
        assert!(!lease.warm_hit);
    }

    #[test]
    fn warm_only_filter_never_returns_cold() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        q.publish(inv("1", "a")).unwrap();
        assert!(q.take(&TakeFilter::warm_reuse("a")).unwrap().is_none());
        assert!(q.take(&TakeFilter::warm_reuse("b")).unwrap().is_none());
    }

    #[test]
    fn grouped_take_drains_one_class_from_one_shard() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        for i in 0..3 {
            q.publish(inv(&format!("a{i}"), "aaa")).unwrap();
        }
        for i in 0..2 {
            q.publish(inv(&format!("b{i}"), "bbb")).unwrap();
        }
        let chunk = q.take_batch_grouped(&TakeFilter::default(), 10).unwrap();
        assert!(!chunk.is_empty());
        let class = chunk[0].invocation.spec.runtime.clone();
        assert!(
            chunk.iter().all(|l| l.invocation.spec.runtime == class),
            "grouped chunk must be single-class"
        );
        let counts = if class == "aaa" { 3 } else { 2 };
        assert_eq!(chunk.len(), counts, "whole lane drained in one call");
    }

    #[test]
    fn take_batch_equals_consecutive_takes() {
        let mk = || {
            let q = ShardedQueue::new(TestClock::new(), 4);
            for i in 0..30 {
                q.publish(inv(&format!("i{i}"), &format!("class-{}", i % 6))).unwrap();
            }
            q
        };
        let f = TakeFilter::supporting((0..6).map(|c| format!("class-{c}")))
            .with_warm(vec!["class-3".into()]);
        let batched: Vec<String> = mk()
            .take_batch(&f, 30)
            .unwrap()
            .into_iter()
            .map(|l| l.invocation.id)
            .collect();
        let q = mk();
        let mut looped = Vec::new();
        while let Some(lease) = q.take(&f).unwrap() {
            looped.push(lease.invocation.id);
        }
        assert_eq!(batched, looped);
    }

    #[test]
    fn publish_batch_splits_by_shard_preserving_class_order() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        let invs: Vec<Invocation> = (0..12)
            .map(|i| inv(&format!("i{i}"), &format!("class-{}", i % 3)))
            .collect();
        q.publish_batch(invs).unwrap();
        assert_eq!(q.stats().unwrap().queued, 12);
        for class in ["class-0", "class-1", "class-2"] {
            let f = TakeFilter::supporting(vec![class.to_string()]);
            let mut prev = None;
            while let Some(lease) = q.take(&f).unwrap() {
                let n: usize =
                    lease.invocation.id.strip_prefix('i').unwrap().parse().unwrap();
                if let Some(p) = prev {
                    assert!(n > p, "batch order within class broken");
                }
                prev = Some(n);
            }
        }
    }

    #[test]
    fn publish_batch_rejects_in_batch_duplicates_before_any_publish() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        let err = q
            .publish_batch(vec![inv("dup", "a"), inv("x", "b"), inv("dup", "c")])
            .unwrap_err();
        assert!(err.to_string().contains("duplicate invocation id dup"), "{err:#}");
        assert_eq!(q.stats().unwrap().queued, 0, "nothing partially published");
    }

    #[test]
    fn ack_and_release_route_to_the_owning_shard() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        q.publish(inv("1", "aaa")).unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.attempt, 1);
        q.release("1").unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.attempt, 1, "release does not burn an attempt");
        q.ack("1").unwrap();
        assert!(q.ack("1").is_err(), "double ack");
        assert!(q.ack("ghost").is_err());
        assert!(q.release("ghost").is_err());
        assert_eq!(q.stats().unwrap().acked, 1);
    }

    #[test]
    fn reap_sums_across_shards_and_dead_letters_merge() {
        let clock = TestClock::new();
        let q = ShardedQueue::with_config(
            clock.clone(),
            QueueConfig {
                visibility: Duration::from_millis(100),
                max_attempts: 1,
                ..QueueConfig::default()
            },
            4,
        );
        for i in 0..6 {
            q.publish(inv(&format!("i{i}"), &format!("class-{i}"))).unwrap();
        }
        while q.take(&TakeFilter::default()).unwrap().is_some() {}
        clock.advance(Duration::from_millis(200));
        assert_eq!(q.reap_expired().unwrap(), 6, "expiries summed across shards");
        assert_eq!(q.dead_letters().len(), 6, "max_attempts=1 dead-letters all");
        assert_eq!(q.stats().unwrap().dead, 6);
    }

    #[test]
    fn merged_stats_carry_per_shard_sections_that_sum_to_totals() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        for i in 0..24 {
            q.publish(inv(&format!("i{i}"), &format!("class-{}", i % 8))).unwrap();
        }
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        let s = q.stats().unwrap();
        assert_eq!(s.queued, 23);
        assert_eq!(s.in_flight, 1);
        assert_eq!(s.shards.len(), 4);
        assert_eq!(s.shards.iter().map(|x| x.queued).sum::<usize>(), 23);
        assert_eq!(s.shards.iter().map(|x| x.in_flight).sum::<usize>(), 1);
        // Shard names align with the membership registry, and every
        // queued class appears in exactly one shard's class list.
        let names: Vec<&str> = s.shards.iter().map(|x| x.shard.as_str()).collect();
        assert_eq!(names, vec!["shard-0", "shard-1", "shard-2", "shard-3"]);
        let mut seen = HashSet::new();
        for shard in &s.shards {
            for class in &shard.classes {
                assert!(seen.insert(class.clone()), "{class} in two shards");
            }
        }
        // Classes merged and sorted for the fleet view.
        let merged: Vec<&str> = s.classes.iter().map(|c| c.runtime.as_str()).collect();
        let mut sorted = merged.clone();
        sorted.sort();
        assert_eq!(merged, sorted);
        assert_eq!(s.classes.iter().map(|c| c.queued).sum::<usize>(), 23);
        drop(lease);
    }

    #[test]
    fn qos_burst_rule_holds_within_every_shard() {
        // burst=1: strict interleave interactive/batch within a class.
        let q = ShardedQueue::with_config(
            TestClock::new(),
            QueueConfig { interactive_burst: 1, ..QueueConfig::default() },
            4,
        );
        for i in 0..3 {
            q.publish(inv_pri(&format!("b{i}"), "cls", Priority::Batch)).unwrap();
        }
        for i in 0..3 {
            q.publish(inv_pri(&format!("i{i}"), "cls", Priority::Interactive))
                .unwrap();
        }
        let f = TakeFilter::supporting(vec!["cls".into()]);
        let order: Vec<String> = std::iter::from_fn(|| {
            q.take(&f).unwrap().map(|l| l.invocation.id)
        })
        .collect();
        assert_eq!(order, vec!["i0", "b0", "i1", "b1", "i2", "b2"]);
    }

    #[test]
    fn take_timeout_wakes_when_work_lands_on_another_shard() {
        // The lost-wakeup regression: the waiter's filter names a class
        // on one shard; a publish to a *different* class (and shard)
        // first must wake + re-park it without losing the registration,
        // and the matching publish must then deliver promptly.
        let q = ShardedQueue::new(TestClock::new(), 8);
        let (want, mut other) = ("w0".to_string(), String::new());
        for i in 1..64 {
            let c = format!("w{i}");
            if q.shard_for(&c) != q.shard_for(&want) {
                other = c;
                break;
            }
        }
        assert!(!other.is_empty());
        let q2 = q.clone();
        let want2 = want.clone();
        let t0 = Instant::now();
        let publisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            q2.publish(inv("decoy", &other)).unwrap(); // wrong shard: re-park
            std::thread::sleep(Duration::from_millis(60));
            q2.publish(inv("target", &want2)).unwrap();
        });
        let lease = q
            .take_timeout(
                &TakeFilter::supporting(vec![want.clone()]),
                Duration::from_secs(10),
            )
            .unwrap()
            .expect("woken by the cross-shard publish, not the timeout");
        assert_eq!(lease.invocation.id, "target");
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(100), "{waited:?}");
        assert!(waited < Duration::from_secs(5), "{waited:?}");
        publisher.join().unwrap();
    }

    #[test]
    fn take_timeout_match_any_wakes_from_any_shard() {
        let q = ShardedQueue::new(TestClock::new(), 8);
        let q2 = q.clone();
        let publisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(80));
            q2.publish(inv("late", "some-class")).unwrap();
        });
        let lease = q
            .take_timeout(&TakeFilter::default(), Duration::from_secs(10))
            .unwrap()
            .expect("match-any waiter must see work on any shard");
        assert_eq!(lease.invocation.id, "late");
        publisher.join().unwrap();
    }

    #[test]
    fn take_timeout_times_out_and_zero_is_a_probe() {
        let q = ShardedQueue::new(TestClock::new(), 4);
        let t0 = Instant::now();
        assert!(q
            .take_timeout(&TakeFilter::default(), Duration::from_millis(120))
            .unwrap()
            .is_none());
        assert!(t0.elapsed() >= Duration::from_millis(100));
        q.publish(inv("1", "a")).unwrap();
        assert!(q
            .take_timeout(&TakeFilter::default(), Duration::ZERO)
            .unwrap()
            .is_some());
        assert!(q
            .take_timeout(&TakeFilter::default(), Duration::ZERO)
            .unwrap()
            .is_none());
    }

    #[test]
    fn concurrent_takers_conserve_invocations_across_shards() {
        let q = ShardedQueue::new(TestClock::new(), 8);
        let n = 400;
        for i in 0..n {
            q.publish(inv(&format!("i{i}"), &format!("class-{}", i % 16))).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut taken = 0;
                while let Some(lease) = q.take(&TakeFilter::default()).unwrap() {
                    q.ack(&lease.invocation.id).unwrap();
                    taken += 1;
                }
                taken
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, n, "every invocation delivered exactly once");
        assert_eq!(q.stats().unwrap().acked, n);
    }

    #[test]
    fn single_shard_degenerates_to_memqueue_behavior() {
        let q = ShardedQueue::new(TestClock::new(), 1);
        assert_eq!(q.shard_count(), 1);
        for i in 0..5 {
            q.publish(inv(&format!("i{i}"), &format!("c{i}"))).unwrap();
        }
        // One shard: global FIFO across classes holds like MemQueue.
        for i in 0..5 {
            let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
            assert_eq!(lease.invocation.id, format!("i{i}"));
        }
    }
}
