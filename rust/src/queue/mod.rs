//! The shared invocation queue — the role Bedrock plays in the paper.
//!
//! Paper §IV-C/D: nodes *"fetch all [their] work from a single shared
//! message queue"* which must let a node manager **scan the queue before
//! taking invocations** so it can (1) take any invocation from the set of
//! workloads it can run, and (2) on instance completion, query whether the
//! queue holds invocations *"that have the same configuration so that the
//! worker node can reuse an existing runtime instance"* (warm reuse).
//!
//! [`TakeFilter`] encodes exactly those two queries.  Delivery is
//! at-least-once: a take leases the invocation for a visibility window;
//! un-acked leases are re-queued by [`InvocationQueue::reap_expired`] and
//! dead-lettered after `max_attempts`.  Workers acknowledge only — they
//! never re-publish — so nodes can join and leave at any time (the paper's
//! dynamic-membership property).

pub mod mem;
#[cfg(test)]
mod reference;
pub mod remote;
pub mod sharded;

pub use mem::{MemQueue, QueueConfig};
pub use remote::{QueueClient, QueueServer};
pub use sharded::ShardedQueue;

use crate::events::{Invocation, Priority};
use crate::json::Json;
use anyhow::Result;
use std::collections::HashSet;

/// The node-side take query (paper's queue-scan contract).
///
/// Membership sets are [`HashSet`]s: `accepts_warm`/`accepts_cold` are
/// the innermost test of the queue's indexed `take`, and the indexed
/// engine iterates these sets directly (one min-seq comparison per
/// member), so both the probe and the iteration are O(1) per runtime.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TakeFilter {
    /// Runtimes this node can execute (union over its accelerators).
    /// Empty = match any (used by diagnostics/drain tooling).
    pub runtimes: HashSet<String>,
    /// Runtimes with a warm instance on this node: matched **first**,
    /// regardless of queue position (cold-start avoidance).
    pub warm: HashSet<String>,
    /// Only take a warm match (the completion-time reuse query §IV-D).
    pub warm_only: bool,
    /// Batch-aware lane preference: grouped takes
    /// ([`InvocationQueue::take_batch_grouped`]) should pick the
    /// **deepest** matching lane instead of the globally oldest front, so
    /// a micro-batching node coalesces the most same-variant work per
    /// device dispatch.  Warm preference still wins first; plain `take`
    /// and `take_batch` ignore the flag (FIFO fairness is theirs).
    pub prefer_deep: bool,
    /// Restrict the take to one QoS lane (`None` = either).  With `None`
    /// the queue's weighted-take rule decides which lane of a class pops
    /// (see `queue::mem`); with `Some` the other lane is invisible —
    /// drain tooling and priority-pinned schedulers use this.
    pub priority: Option<Priority>,
    /// Dataset keys the taking node already holds in its local content
    /// cache (the `scheduler::CacheAffinity` hot-set, DESIGN.md §15).
    /// Ranked **after** warm-instance preference and **before** FIFO
    /// order: among cold candidates, an invocation whose dataset is in
    /// this set is delivered first, so compute moves to hot data instead
    /// of re-fetching.  Empty = no preference (exact legacy behavior).
    /// Purely a *preference* — a hot entry never excludes cold work and
    /// a stale entry merely costs a backing fetch.
    pub hot_datasets: HashSet<String>,
}

impl TakeFilter {
    pub fn supporting(runtimes: impl IntoIterator<Item = String>) -> TakeFilter {
        TakeFilter { runtimes: runtimes.into_iter().collect(), ..TakeFilter::default() }
    }

    pub fn with_warm(mut self, warm: impl IntoIterator<Item = String>) -> TakeFilter {
        self.warm = warm.into_iter().collect();
        self
    }

    /// The paper's "same configuration" reuse query.
    pub fn warm_reuse(runtime: &str) -> TakeFilter {
        TakeFilter {
            runtimes: HashSet::new(),
            warm: HashSet::from([runtime.to_string()]),
            warm_only: true,
            ..TakeFilter::default()
        }
    }

    /// Set the batch-aware deep-lane preference (see `prefer_deep`).
    pub fn preferring_deep(mut self, on: bool) -> TakeFilter {
        self.prefer_deep = on;
        self
    }

    /// Restrict (or un-restrict) the take to one QoS lane.
    pub fn for_priority(mut self, priority: Option<Priority>) -> TakeFilter {
        self.priority = priority;
        self
    }

    /// Set the cache-affinity hot-set (see `hot_datasets`).
    pub fn with_hot_datasets(
        mut self,
        hot: impl IntoIterator<Item = String>,
    ) -> TakeFilter {
        self.hot_datasets = hot.into_iter().collect();
        self
    }

    /// Whether `dataset` enjoys the hot-data preference.
    pub fn is_hot(&self, dataset: &str) -> bool {
        self.hot_datasets.contains(dataset)
    }

    /// Follow-up filter for deepening a same-class chunk: only `runtime`,
    /// classified warm iff the originating take was.  The single source
    /// of the warm/cold split rule for grouped continuation takes (used
    /// by [`InvocationQueue::take_batch_grouped`]'s default and the node
    /// manager's first-chunk deepening).
    pub fn same_class(runtime: &str, warm: bool) -> TakeFilter {
        TakeFilter {
            runtimes: HashSet::from([runtime.to_string()]),
            warm: if warm {
                HashSet::from([runtime.to_string()])
            } else {
                HashSet::new()
            },
            ..TakeFilter::default()
        }
    }

    pub fn accepts_cold(&self, runtime: &str) -> bool {
        !self.warm_only && (self.runtimes.is_empty() || self.runtimes.contains(runtime))
    }

    pub fn accepts_warm(&self, runtime: &str) -> bool {
        self.warm.contains(runtime)
    }

    /// Whether this filter may deliver an invocation of `priority`.
    pub fn accepts_priority(&self, priority: Priority) -> bool {
        self.priority.map(|p| p == priority).unwrap_or(true)
    }

    pub fn to_json(&self) -> Json {
        // Sorted for a deterministic wire encoding (HashSet iteration
        // order is arbitrary).
        let arr = |v: &HashSet<String>| {
            let mut items: Vec<&String> = v.iter().collect();
            items.sort();
            Json::Arr(items.into_iter().map(|s| Json::from(s.as_str())).collect())
        };
        let mut j = Json::obj()
            .set("runtimes", arr(&self.runtimes))
            .set("warm", arr(&self.warm))
            .set("warm_only", self.warm_only)
            .set("prefer_deep", self.prefer_deep);
        if let Some(p) = self.priority {
            // Omitted when unrestricted: pre-priority peers see exactly
            // the wire shape they always did.
            j = j.set("priority", p.as_str());
        }
        if !self.hot_datasets.is_empty() {
            // Omitted when empty: pre-affinity peers see the legacy wire
            // shape, and an affinity-off filter encodes byte-identically
            // to one that predates the field.
            j = j.set("hot_datasets", arr(&self.hot_datasets));
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<TakeFilter> {
        let strs = |key: &str| -> HashSet<String> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        Ok(TakeFilter {
            runtimes: strs("runtimes"),
            warm: strs("warm"),
            warm_only: j.get("warm_only").and_then(|b| b.as_bool()).unwrap_or(false),
            // Lenient: the flag postdates the wire format; absent = off.
            prefer_deep: j
                .get("prefer_deep")
                .and_then(|b| b.as_bool())
                .unwrap_or(false),
            // Lenient: absent or unrecognized = unrestricted.
            priority: j
                .get("priority")
                .and_then(|v| v.as_str())
                .and_then(|s| Priority::parse(s).ok()),
            // Lenient: pre-affinity peers never send it; absent = no
            // hot-data preference.
            hot_datasets: strs("hot_datasets"),
        })
    }
}

/// A leased invocation: the queue hands it to exactly one node until the
/// lease expires or is acked.
#[derive(Debug, Clone)]
pub struct Lease {
    pub invocation: Invocation,
    /// Whether the take matched via the warm set (drives the node's
    /// instance-selection and the warm-start metrics).
    pub warm_hit: bool,
    /// Delivery attempt number (1 = first delivery).
    pub attempt: u32,
}

/// Per-runtime-class gauge: the queue depth of one lane and the age of
/// its frontmost (oldest) invocation.  These are the autoscaler's two
/// primary pressure signals — a class whose lane is deep or whose head
/// has waited too long needs capacity regardless of global depth.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ClassStats {
    pub runtime: String,
    /// Invocations queued (not leased) in this class's lane.
    pub queued: usize,
    /// Sim-time age of the lane front (now − `RStart`), milliseconds.
    pub oldest_waiting_ms: u64,
    /// Of `queued`, how many ride the interactive QoS lane.  The
    /// autoscaler's per-priority watermarks key off this: interactive
    /// backlog must drive scale-out before raw batch depth does.
    pub interactive_queued: usize,
    /// Age of the oldest **interactive** invocation in this class,
    /// milliseconds (0 when none are queued).
    pub interactive_oldest_ms: u64,
}

impl ClassStats {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("runtime", self.runtime.as_str())
            .set("queued", self.queued)
            .set("oldest_waiting_ms", self.oldest_waiting_ms)
            .set("interactive_queued", self.interactive_queued)
            .set("interactive_oldest_ms", self.interactive_oldest_ms)
    }

    pub fn from_json(j: &Json) -> Result<ClassStats> {
        Ok(ClassStats {
            runtime: j.str_of("runtime")?.to_string(),
            queued: j.usize_of("queued")?,
            oldest_waiting_ms: j.u64_of("oldest_waiting_ms").unwrap_or(0),
            // Lenient: pre-priority peers don't send the QoS split.
            interactive_queued: j.usize_of("interactive_queued").unwrap_or(0),
            interactive_oldest_ms: j.u64_of("interactive_oldest_ms").unwrap_or(0),
        })
    }
}

/// Per-shard gauge section of a sharded queue's stats (DESIGN.md §13).
/// Single-shard backends leave the section out entirely; it is lenient
/// on the wire in both directions (unknown fields ignored, absent
/// section = single-shard engine).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ShardStats {
    /// Shard member name from the rendezvous registry (`shard-0`, ...).
    pub shard: String,
    pub queued: usize,
    pub in_flight: usize,
    pub acked: usize,
    pub dead: usize,
    /// Runtime classes currently queued on this shard, sorted.  Shards
    /// partition the classes, so across a snapshot each class appears in
    /// at most one shard's list.
    pub classes: Vec<String>,
}

impl ShardStats {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("shard", self.shard.as_str())
            .set("queued", self.queued)
            .set("in_flight", self.in_flight)
            .set("acked", self.acked)
            .set("dead", self.dead)
            .set(
                "classes",
                Json::Arr(self.classes.iter().map(|c| Json::from(c.as_str())).collect()),
            )
    }

    pub fn from_json(j: &Json) -> Result<ShardStats> {
        Ok(ShardStats {
            shard: j.str_of("shard")?.to_string(),
            queued: j.usize_of("queued")?,
            in_flight: j.usize_of("in_flight").unwrap_or(0),
            acked: j.usize_of("acked").unwrap_or(0),
            dead: j.usize_of("dead").unwrap_or(0),
            // Lenient: a peer that doesn't enumerate classes still merges.
            classes: j
                .get("classes")
                .and_then(|c| c.as_arr())
                .map(|arr| {
                    arr.iter()
                        .filter_map(|s| s.as_str().map(String::from))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }
}

/// Queue gauge snapshot (the paper samples `#queued` periodically).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct QueueStats {
    pub queued: usize,
    pub in_flight: usize,
    pub acked: usize,
    pub dead: usize,
    /// Per-runtime-class depth/age, sorted by runtime name (deterministic
    /// for wire encoding and decision-log reproducibility).  Backends
    /// that cannot compute it cheaply may leave it empty.
    pub classes: Vec<ClassStats>,
    /// Per-shard breakdown — empty for single-shard backends (the wire
    /// omits the section entirely, and pre-shard peers parse unchanged).
    pub shards: Vec<ShardStats>,
}

/// The shared invocation queue interface (in-memory and TCP deployments).
pub trait InvocationQueue: Send + Sync {
    /// Publish a new invocation (client → queue).
    fn publish(&self, inv: Invocation) -> Result<()>;

    /// Publish many invocations — one RPC on remote transports, one lock
    /// hold in-memory.  [`MemQueue`] makes this all-or-nothing on
    /// duplicate ids; the default falls back to per-invocation publish.
    fn publish_batch(&self, invs: Vec<Invocation>) -> Result<()> {
        for inv in invs {
            self.publish(inv)?;
        }
        Ok(())
    }

    /// Scan-and-take under `filter`. Returns a lease or `None` when no
    /// visible invocation matches.  Warm matches win over queue order;
    /// within a class, FIFO.
    fn take(&self, filter: &TakeFilter) -> Result<Option<Lease>>;

    /// Take up to `max` leases under `filter` in one call — delivery
    /// order is exactly that of `max` consecutive [`take`](Self::take)s.
    /// One RPC on remote transports, so a node manager can fill all of
    /// its free accelerator slots per round trip.
    fn take_batch(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.take(filter)? {
                Some(lease) => out.push(lease),
                None => break,
            }
        }
        Ok(out)
    }

    /// Take up to `max` leases **all of one runtime class** in one call —
    /// the micro-batching node's query: a chunk of same-variant work that
    /// one device dispatch can serve.  Class choice honors the filter's
    /// warm preference first; with [`TakeFilter::prefer_deep`] backends
    /// pick the deepest matching lane (max coalescing), otherwise the
    /// lane of the globally oldest matching invocation.  Within the
    /// class, delivery is FIFO.  The default composes `take` + a
    /// same-class `take_batch` (correct everywhere, two round trips
    /// remotely); [`MemQueue`] answers in one lock hold and the queue RPC
    /// service exposes it as a single round trip.
    fn take_batch_grouped(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        if max == 0 {
            return Ok(Vec::new());
        }
        let Some(first) = self.take(filter)? else {
            return Ok(Vec::new());
        };
        let runtime = first.invocation.spec.runtime.clone();
        let same = TakeFilter::same_class(&runtime, filter.accepts_warm(&runtime))
            .for_priority(filter.priority);
        let mut out = vec![first];
        // `first` is already leased: a failed follow-up take must not
        // drop it (it would sit invisible until the visibility timeout),
        // so degrade to a chunk of one instead of propagating.
        match self.take_batch(&same, max - 1) {
            Ok(more) => out.extend(more),
            Err(e) => log::warn!("take_batch_grouped follow-up failed: {e:#}"),
        }
        Ok(out)
    }

    /// Acknowledge completion (success or permanent failure) of a leased
    /// invocation — removes it from the queue entirely.
    fn ack(&self, invocation_id: &str) -> Result<()>;

    /// Acknowledge many leases in one call (one RPC remotely).  Every id
    /// is attempted; the first failure is returned after all are tried.
    fn ack_batch(&self, invocation_ids: &[String]) -> Result<()> {
        let mut first_err = None;
        for id in invocation_ids {
            if let Err(e) = self.ack(id) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Return a leased invocation to the queue (node shutting down,
    /// artifact missing, ...). Does not count against max_attempts.
    fn release(&self, invocation_id: &str) -> Result<()>;

    /// Re-queue expired leases; returns how many were re-queued or
    /// dead-lettered. Driven by the coordinator's housekeeping tick.
    fn reap_expired(&self) -> Result<usize>;

    /// Gauge snapshot.
    fn stats(&self) -> Result<QueueStats>;

    /// Blocking take: wait up to `wall_timeout` (wall-clock) for a
    /// matching invocation.  Default = one non-blocking probe;
    /// [`MemQueue`] overrides with a condvar and [`QueueClient`] with a
    /// server-side long poll, so idle dispatch latency is
    /// notification-bound instead of poll-interval-bound — in-process
    /// and over TCP alike (EXPERIMENTS.md §Perf).
    fn take_timeout(
        &self,
        filter: &TakeFilter,
        wall_timeout: std::time::Duration,
    ) -> Result<Option<Lease>> {
        let _ = wall_timeout;
        self.take(filter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_cold_matching() {
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()]);
        assert!(f.accepts_cold("a"));
        assert!(!f.accepts_cold("z"));
        assert!(!f.accepts_warm("a"));
    }

    #[test]
    fn warm_reuse_filter_rejects_cold() {
        let f = TakeFilter::warm_reuse("a");
        assert!(f.accepts_warm("a"));
        assert!(!f.accepts_cold("a"));
        assert!(!f.accepts_cold("b"));
    }

    #[test]
    fn empty_runtimes_matches_any_cold() {
        let f = TakeFilter::default();
        assert!(f.accepts_cold("anything"));
    }

    #[test]
    fn filter_json_roundtrip() {
        let f = TakeFilter::supporting(vec!["x".into()])
            .with_warm(vec!["x".into(), "y".into()]);
        let back = TakeFilter::from_json(&f.to_json()).unwrap();
        assert_eq!(back, f);
        // ...including the batch-aware lane preference
        let deep = f.preferring_deep(true);
        let back = TakeFilter::from_json(&deep.to_json()).unwrap();
        assert!(back.prefer_deep);
        assert_eq!(back, deep);
    }

    #[test]
    fn prefer_deep_parses_leniently_when_absent() {
        // Wire payloads predating the flag must parse to off, not error.
        let mut j = TakeFilter::default().to_json();
        j = j.set("prefer_deep", crate::json::Json::Null);
        assert!(!TakeFilter::from_json(&j).unwrap().prefer_deep);
    }

    #[test]
    fn priority_filter_roundtrip_and_matching() {
        let f = TakeFilter::supporting(vec!["a".into()])
            .for_priority(Some(Priority::Interactive));
        assert!(f.accepts_priority(Priority::Interactive));
        assert!(!f.accepts_priority(Priority::Batch));
        let back = TakeFilter::from_json(&f.to_json()).unwrap();
        assert_eq!(back, f);

        // Unrestricted filters match either lane and omit the field on
        // the wire (pre-priority peers see the legacy shape).
        let any = TakeFilter::default();
        assert!(any.accepts_priority(Priority::Interactive));
        assert!(any.accepts_priority(Priority::Batch));
        assert!(any.to_json().get("priority").is_none());
        assert_eq!(TakeFilter::from_json(&any.to_json()).unwrap().priority, None);

        // Unknown lane names from newer peers degrade to unrestricted.
        let j = any.to_json().set("priority", "realtime-v2");
        assert_eq!(TakeFilter::from_json(&j).unwrap().priority, None);
    }

    #[test]
    fn hot_datasets_roundtrip_and_wire_leniency() {
        let f = TakeFilter::supporting(vec!["a".into()])
            .with_hot_datasets(vec!["datasets/x".into(), "datasets/y".into()]);
        assert!(f.is_hot("datasets/x"));
        assert!(!f.is_hot("datasets/z"));
        let back = TakeFilter::from_json(&f.to_json()).unwrap();
        assert_eq!(back, f);

        // Empty hot-set is omitted on the wire: pre-affinity peers see
        // the exact legacy shape, and old payloads (field absent) parse
        // to "no preference".
        let off = TakeFilter::supporting(vec!["a".into()]);
        assert!(off.to_json().get("hot_datasets").is_none());
        let back = TakeFilter::from_json(&off.to_json()).unwrap();
        assert!(back.hot_datasets.is_empty());

        // An old peer that re-encodes and drops the field yields a
        // filter with no preference — never an error.
        let mut j = f.to_json();
        j = j.set("hot_datasets", crate::json::Json::Null);
        assert!(TakeFilter::from_json(&j).unwrap().hot_datasets.is_empty());
    }

    #[test]
    fn hot_datasets_encode_sorted_for_deterministic_wire() {
        let f = TakeFilter::default()
            .with_hot_datasets(vec!["datasets/b".into(), "datasets/a".into()]);
        let arr = f.to_json();
        let hot = arr.get("hot_datasets").and_then(|v| v.as_arr()).unwrap();
        let keys: Vec<&str> = hot.iter().filter_map(|x| x.as_str()).collect();
        assert_eq!(keys, vec!["datasets/a", "datasets/b"]);
    }

    #[test]
    fn class_stats_qos_split_parses_leniently() {
        let full = ClassStats {
            runtime: "a".into(),
            queued: 7,
            oldest_waiting_ms: 40,
            interactive_queued: 3,
            interactive_oldest_ms: 12,
        };
        assert_eq!(ClassStats::from_json(&full.to_json()).unwrap(), full);
        // An old peer's payload has no QoS split: parse to zeroes.
        let legacy = crate::json::Json::obj()
            .set("runtime", "a")
            .set("queued", 7u64)
            .set("oldest_waiting_ms", 40u64);
        let back = ClassStats::from_json(&legacy).unwrap();
        assert_eq!((back.interactive_queued, back.interactive_oldest_ms), (0, 0));
        assert_eq!(back.queued, 7);
    }
}
