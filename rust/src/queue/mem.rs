//! In-process invocation queue engine.
//!
//! One `Mutex<Inner>` protects all state — contention is negligible at the
//! paper's scale (tens of invocations/second across a handful of node
//! managers; see `benches/micro_queue.rs` for the measured six-figure
//! op/s headroom).

use super::{InvocationQueue, Lease, QueueStats, TakeFilter};
use crate::events::Invocation;
use crate::util::{Clock, SimTime};
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Queue configuration.
#[derive(Debug, Clone)]
pub struct QueueConfig {
    /// Lease duration before an un-acked take is considered lost.
    pub visibility: Duration,
    /// Deliveries before an invocation is dead-lettered.
    pub max_attempts: u32,
}

impl Default for QueueConfig {
    fn default() -> QueueConfig {
        QueueConfig {
            // Sim time: generous vs the ~1.6 s service times of the paper's
            // workload, tight enough to recover from a node crash mid-run.
            visibility: Duration::from_secs(30),
            max_attempts: 3,
        }
    }
}

struct InFlight {
    invocation: Invocation,
    deadline: SimTime,
    attempt: u32,
}

#[derive(Default)]
struct Inner {
    queued: VecDeque<Invocation>,
    in_flight: HashMap<String, InFlight>,
    attempts: HashMap<String, u32>,
    dead: Vec<Invocation>,
    acked: usize,
    /// Ids currently queued or in flight — O(1) duplicate detection on
    /// publish (the scan-based check was O(n) per publish and collapsed
    /// deep-queue ingest to ~2.6k ops/s; see EXPERIMENTS.md §Perf).
    live_ids: HashSet<String>,
}

/// In-memory [`InvocationQueue`] engine.
pub struct MemQueue {
    inner: Mutex<Inner>,
    /// Signalled whenever work (re)appears — lets `take_timeout` block
    /// instead of poll (idle dispatch latency: ~poll-interval → ~0.1 ms).
    available: std::sync::Condvar,
    clock: Arc<dyn Clock>,
    config: QueueConfig,
}

impl MemQueue {
    pub fn new(clock: Arc<dyn Clock>) -> Arc<MemQueue> {
        MemQueue::with_config(clock, QueueConfig::default())
    }

    pub fn with_config(clock: Arc<dyn Clock>, config: QueueConfig) -> Arc<MemQueue> {
        Arc::new(MemQueue {
            inner: Mutex::new(Inner::default()),
            available: std::sync::Condvar::new(),
            clock,
            config,
        })
    }

    /// Dead-lettered invocations (diagnostics).
    pub fn dead_letters(&self) -> Vec<Invocation> {
        self.inner.lock().expect("queue poisoned").dead.clone()
    }

    /// Peek the queued runtimes in order (diagnostics / scheduler tests).
    pub fn queued_runtimes(&self) -> Vec<String> {
        self.inner
            .lock()
            .expect("queue poisoned")
            .queued
            .iter()
            .map(|i| i.spec.runtime.clone())
            .collect()
    }
}

impl InvocationQueue for MemQueue {
    fn publish(&self, inv: Invocation) -> Result<()> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        if !inner.live_ids.insert(inv.id.clone()) {
            bail!("duplicate invocation id {}", inv.id);
        }
        inner.queued.push_back(inv);
        drop(inner);
        self.available.notify_all();
        Ok(())
    }

    fn take(&self, filter: &TakeFilter) -> Result<Option<Lease>> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        // Scan pass 1: earliest invocation whose runtime is warm here.
        let warm_pos = inner
            .queued
            .iter()
            .position(|inv| filter.accepts_warm(&inv.spec.runtime));
        // Scan pass 2: earliest supported invocation at all.
        let pos = match warm_pos {
            Some(p) => Some((p, true)),
            None => inner
                .queued
                .iter()
                .position(|inv| filter.accepts_cold(&inv.spec.runtime))
                .map(|p| (p, false)),
        };
        let Some((pos, warm_hit)) = pos else {
            return Ok(None);
        };
        let invocation = inner.queued.remove(pos).expect("position valid");
        let attempt = {
            let a = inner.attempts.entry(invocation.id.clone()).or_insert(0);
            *a += 1;
            *a
        };
        let deadline = SimTime(
            self.clock.now().as_micros() + self.config.visibility.as_micros() as u64,
        );
        inner.in_flight.insert(
            invocation.id.clone(),
            InFlight { invocation: invocation.clone(), deadline, attempt },
        );
        Ok(Some(Lease { invocation, warm_hit, attempt }))
    }

    fn ack(&self, invocation_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        if inner.in_flight.remove(invocation_id).is_none() {
            bail!("ack for unknown or expired lease: {invocation_id}");
        }
        inner.attempts.remove(invocation_id);
        inner.live_ids.remove(invocation_id);
        inner.acked += 1;
        Ok(())
    }

    fn release(&self, invocation_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        let Some(inflight) = inner.in_flight.remove(invocation_id) else {
            bail!("release for unknown lease: {invocation_id}");
        };
        // A voluntary release does not burn an attempt.
        if let Some(a) = inner.attempts.get_mut(invocation_id) {
            *a = a.saturating_sub(1);
        }
        inner.queued.push_front(inflight.invocation);
        drop(inner);
        self.available.notify_all();
        Ok(())
    }

    fn reap_expired(&self) -> Result<usize> {
        let now = self.clock.now();
        let mut inner = self.inner.lock().expect("queue poisoned");
        let expired: Vec<String> = inner
            .in_flight
            .iter()
            .filter(|(_, f)| f.deadline <= now)
            .map(|(id, _)| id.clone())
            .collect();
        let n = expired.len();
        for id in expired {
            let f = inner.in_flight.remove(&id).expect("present");
            if f.attempt >= self.config.max_attempts {
                inner.live_ids.remove(&id);
                inner.dead.push(f.invocation);
            } else {
                // Lost leases go to the *front*: they are the oldest work.
                inner.queued.push_front(f.invocation);
            }
        }
        if n > 0 {
            drop(inner);
            self.available.notify_all();
        }
        Ok(n)
    }

    fn take_timeout(
        &self,
        filter: &TakeFilter,
        wall_timeout: Duration,
    ) -> Result<Option<Lease>> {
        let deadline = std::time::Instant::now() + wall_timeout;
        loop {
            if let Some(lease) = self.take(filter)? {
                return Ok(Some(lease));
            }
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                return Ok(None);
            }
            // Park until a publish/release/reap signals new work (or the
            // timeout elapses).  Spurious wakeups just loop.
            let guard = self.inner.lock().expect("queue poisoned");
            if !guard.queued.is_empty() {
                continue; // raced with a publisher between take() and lock
            }
            let _ = self
                .available
                .wait_timeout(guard, left.min(Duration::from_millis(50)))
                .expect("queue poisoned");
        }
    }

    fn stats(&self) -> Result<QueueStats> {
        let inner = self.inner.lock().expect("queue poisoned");
        Ok(QueueStats {
            queued: inner.queued.len(),
            in_flight: inner.in_flight.len(),
            acked: inner.acked,
            dead: inner.dead.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::EventSpec;
    use crate::util::clock::TestClock;

    fn inv(id: &str, runtime: &str) -> Invocation {
        Invocation::new(id, EventSpec::new(runtime, "datasets/d"), SimTime(0))
    }

    fn queue() -> (Arc<crate::util::clock::TestClock>, Arc<MemQueue>) {
        let clock = TestClock::new();
        let q = MemQueue::new(clock.clone());
        (clock, q)
    }

    #[test]
    fn fifo_within_class() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        q.publish(inv("2", "a")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into()]);
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "1");
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "2");
        assert!(q.take(&f).unwrap().is_none());
    }

    #[test]
    fn unsupported_runtime_not_taken() {
        let (_c, q) = queue();
        q.publish(inv("1", "zzz")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into()]);
        assert!(q.take(&f).unwrap().is_none());
        assert_eq!(q.stats().unwrap().queued, 1);
    }

    #[test]
    fn warm_scan_jumps_queue_order() {
        // Paper §IV-D: the node prefers invocations it is warm for, even if
        // they sit behind other work in the queue.
        let (_c, q) = queue();
        q.publish(inv("cold-1", "a")).unwrap();
        q.publish(inv("warm-1", "b")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_warm(vec!["b".into()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "warm-1");
        assert!(lease.warm_hit);
        // Next take falls back to the cold invocation.
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "cold-1");
        assert!(!lease.warm_hit);
    }

    #[test]
    fn warm_only_reuse_query() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        // completion-time reuse probe for runtime "b": nothing to reuse
        assert!(q.take(&TakeFilter::warm_reuse("b")).unwrap().is_none());
        // for runtime "a": match
        let lease = q.take(&TakeFilter::warm_reuse("a")).unwrap().unwrap();
        assert!(lease.warm_hit);
    }

    #[test]
    fn ack_completes_lease() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        q.ack(&lease.invocation.id).unwrap();
        let s = q.stats().unwrap();
        assert_eq!((s.queued, s.in_flight, s.acked), (0, 0, 1));
        assert!(q.ack("1").is_err(), "double ack rejected");
    }

    #[test]
    fn release_requeues_at_front_without_attempt_burn() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        q.publish(inv("2", "a")).unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.attempt, 1);
        q.release("1").unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "1", "released work re-delivered first");
        assert_eq!(lease.attempt, 1, "voluntary release burns no attempt");
    }

    #[test]
    fn visibility_timeout_requeues() {
        let (clock, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        let _lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(q.reap_expired().unwrap(), 0, "not expired yet");
        clock.advance(Duration::from_secs(31));
        assert_eq!(q.reap_expired().unwrap(), 1);
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.attempt, 2, "redelivery increments attempt");
    }

    #[test]
    fn dead_letter_after_max_attempts() {
        let clock = TestClock::new();
        let q = MemQueue::with_config(
            clock.clone(),
            QueueConfig { visibility: Duration::from_secs(1), max_attempts: 2 },
        );
        q.publish(inv("1", "a")).unwrap();
        for _ in 0..2 {
            q.take(&TakeFilter::default()).unwrap().unwrap();
            clock.advance(Duration::from_secs(2));
            q.reap_expired().unwrap();
        }
        assert!(q.take(&TakeFilter::default()).unwrap().is_none());
        assert_eq!(q.stats().unwrap().dead, 1);
        assert_eq!(q.dead_letters()[0].id, "1");
    }

    #[test]
    fn duplicate_publish_rejected() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        assert!(q.publish(inv("1", "a")).is_err());
    }

    #[test]
    fn concurrent_takers_no_double_delivery() {
        let (_c, q) = queue();
        for i in 0..200 {
            q.publish(inv(&format!("i{i}"), "a")).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(lease) = q.take(&TakeFilter::default()).unwrap() {
                    got.push(lease.invocation.id.clone());
                    q.ack(&lease.invocation.id).unwrap();
                }
                got
            }));
        }
        let mut all: Vec<String> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 200, "every invocation delivered exactly once");
        assert_eq!(q.stats().unwrap().acked, 200);
    }

    #[test]
    fn property_scan_never_delivers_unsupported() {
        use crate::prop;
        // Random publish/take interleavings: a node must only ever receive
        // runtimes from its filter, and warm hits only from its warm set.
        prop::check(
            "scan-respects-filter",
            60,
            |rng| {
                let runtimes: Vec<String> =
                    (0..rng.range(1, 4)).map(|i| format!("r{i}")).collect();
                let publishes: Vec<String> = (0..rng.range(0, 30))
                    .map(|_| format!("r{}", rng.below(6)))
                    .collect();
                let warm: Vec<String> =
                    (0..rng.below(3)).map(|i| format!("r{i}")).collect();
                (runtimes, publishes, warm)
            },
            |(runtimes, publishes, warm)| {
                let q = MemQueue::new(TestClock::new());
                for (i, r) in publishes.iter().enumerate() {
                    q.publish(inv(&format!("p{i}"), r)).unwrap();
                }
                let f = TakeFilter::supporting(runtimes.clone())
                    .with_warm(warm.clone());
                while let Ok(Some(lease)) = q.take(&f) {
                    let rt = &lease.invocation.spec.runtime;
                    if !runtimes.contains(rt) && !warm.contains(rt) {
                        return false;
                    }
                    if lease.warm_hit && !warm.contains(rt) {
                        return false;
                    }
                    q.ack(&lease.invocation.id).unwrap();
                }
                true
            },
        );
    }

    #[test]
    fn property_conservation() {
        use crate::prop;
        // queued + in_flight + acked + dead == published, at every step.
        prop::check(
            "queue-conservation",
            40,
            |rng| (0..rng.range(1, 40)).map(|_| rng.below(3)).collect::<Vec<u64>>(),
            |ops| {
                let clock = TestClock::new();
                let q = MemQueue::with_config(
                    clock.clone(),
                    QueueConfig { visibility: Duration::from_secs(1), max_attempts: 2 },
                );
                let mut published = 0usize;
                for (i, op) in ops.iter().enumerate() {
                    match op {
                        0 => {
                            q.publish(inv(&format!("c{i}"), "a")).unwrap();
                            published += 1;
                        }
                        1 => {
                            if let Some(l) = q.take(&TakeFilter::default()).unwrap() {
                                q.ack(&l.invocation.id).unwrap();
                            }
                        }
                        _ => {
                            let _ = q.take(&TakeFilter::default()).unwrap();
                            clock.advance(Duration::from_secs(2));
                            q.reap_expired().unwrap();
                        }
                    }
                    let s = q.stats().unwrap();
                    if s.queued + s.in_flight + s.acked + s.dead != published {
                        return false;
                    }
                }
                true
            },
        );
    }
}
