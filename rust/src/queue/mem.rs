//! In-process invocation queue engine, indexed by runtime class.
//!
//! One `Mutex<Inner>` protects all state — contention is negligible even
//! at deep queue depths because every operation is index-backed (see
//! `benches/micro_queue.rs`):
//!
//! * `queued` is a **per-runtime-class lane map**: each lane is a FIFO of
//!   `(seq, invocation)` where `seq` is a global monotonic sequence
//!   number.  A `take` compares the front seq of each candidate lane
//!   (O(|filter.warm| + |filter.runtimes|)) instead of scanning the
//!   whole queue; cross-class FIFO falls out of the seq tiebreak.
//! * `order` is a `BTreeMap<seq, class>` mirror of everything queued —
//!   the global FIFO head for match-any filters in O(log n), and ordered
//!   diagnostics.
//! * `deadlines` is a min-heap of `(deadline, id)` so `reap_expired` is
//!   O(expired · log n) instead of a full in-flight scan; entries for
//!   acked or re-leased invocations are pruned lazily on pop.
//! * `generation` counts work arrivals (publish / release / reap
//!   requeue) so `take_timeout` parks until *new* work shows up — a deep
//!   queue of non-matching invocations no longer busy-spins the caller.

use super::{InvocationQueue, Lease, QueueStats, TakeFilter};
use crate::events::{Invocation, Priority};
use crate::util::{Clock, SimTime};
use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Queue configuration.
#[derive(Debug, Clone)]
pub struct QueueConfig {
    /// Lease duration before an un-acked take is considered lost.
    pub visibility: Duration,
    /// Deliveries before an invocation is dead-lettered.
    pub max_attempts: u32,
    /// QoS weighted-take rule: how many consecutive interactive pops a
    /// class may make **while batch work waits in the same class** before
    /// one batch invocation is served (a `burst`:1 interleave — interactive
    /// precedence with guaranteed batch progress).  `0` disables the QoS
    /// lanes entirely: pure seq-FIFO within each class, the lanes-off
    /// ablation of `benches/micro_pipeline.rs`.
    pub interactive_burst: u32,
}

impl Default for QueueConfig {
    fn default() -> QueueConfig {
        QueueConfig {
            // Sim time: generous vs the ~1.6 s service times of the paper's
            // workload, tight enough to recover from a node crash mid-run.
            visibility: Duration::from_secs(30),
            max_attempts: 3,
            interactive_burst: 3,
        }
    }
}

struct InFlight {
    invocation: Invocation,
    deadline: SimTime,
    attempt: u32,
}

/// Midpoint of the sequence space: publishes count up from here, front
/// requeues (release / lease expiry) count down — "front of the queue"
/// is simply "smaller seq", with no renumbering ever needed.
const SEQ_BASE: u64 = 1 << 62;

/// One runtime class's FIFO, split into two QoS sub-queues.  Each
/// sub-queue is seq-ordered; the lane's logical front is the smaller of
/// the two front seqs.  The weighted-take rule ([`Lane::pop`]) decides
/// which sub-queue actually pops when both hold work.
#[derive(Default)]
struct Lane {
    interactive: VecDeque<(u64, Invocation)>,
    batch: VecDeque<(u64, Invocation)>,
    /// Consecutive interactive pops made while batch work waited in this
    /// lane — reset whenever a batch invocation is served.
    interactive_streak: u32,
}

impl Lane {
    fn len(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }

    fn is_empty(&self) -> bool {
        self.interactive.is_empty() && self.batch.is_empty()
    }

    /// `(front_seq, depth)` of the lane as seen through a priority
    /// restriction — `None` when nothing matches.  The unrestricted view
    /// fronts at the smaller sub-queue seq (global FIFO position) and
    /// counts both sub-queues.
    fn view(&self, priority: Option<Priority>) -> Option<(u64, usize)> {
        let front_of = |q: &VecDeque<(u64, Invocation)>| q.front().map(|(s, _)| *s);
        match priority {
            None => {
                let front = match (front_of(&self.interactive), front_of(&self.batch)) {
                    (Some(a), Some(b)) => a.min(b),
                    (Some(a), None) => a,
                    (None, Some(b)) => b,
                    (None, None) => return None,
                };
                Some((front, self.len()))
            }
            Some(Priority::Interactive) => front_of(&self.interactive)
                .map(|s| (s, self.interactive.len())),
            Some(Priority::Batch) => front_of(&self.batch).map(|s| (s, self.batch.len())),
        }
    }

    /// Route by the invocation's own priority; `front` pushes preserve
    /// sub-queue seq order because front seqs descend globally.
    fn push(&mut self, seq: u64, inv: Invocation, front: bool) {
        let sub = match inv.spec.priority {
            Priority::Interactive => &mut self.interactive,
            Priority::Batch => &mut self.batch,
        };
        if front {
            sub.push_front((seq, inv));
        } else {
            sub.push_back((seq, inv));
        }
    }

    /// The entry [`Lane::pop`] would deliver next, without mutating —
    /// the cache-affinity pick (DESIGN.md §15) probes this to test the
    /// *actually deliverable* invocation's dataset, so the hot check and
    /// the subsequent pop cannot disagree about which invocation moves.
    fn peek(&self, burst: u32, priority: Option<Priority>) -> Option<&(u64, Invocation)> {
        match priority {
            Some(Priority::Interactive) => self.interactive.front(),
            Some(Priority::Batch) => self.batch.front(),
            None => match (self.interactive.front(), self.batch.front()) {
                (None, None) => None,
                (Some(i), None) => Some(i),
                (None, Some(b)) => Some(b),
                (Some(i), Some(b)) => {
                    let take_batch = if burst == 0 {
                        b.0 < i.0
                    } else {
                        self.interactive_streak >= burst
                    };
                    if take_batch {
                        Some(b)
                    } else {
                        Some(i)
                    }
                }
            },
        }
    }

    /// The weighted-take rule.  A priority-pinned pop drains only its
    /// sub-queue (and leaves the streak alone).  Unrestricted pops serve
    /// interactive first — but after `burst` consecutive interactive
    /// pops with batch work waiting, one batch invocation is served, so
    /// batch progress is guaranteed at a `burst`:1 interleave.  With
    /// `burst == 0` the lanes are off: the older front seq wins (pure
    /// per-class FIFO, exactly the pre-QoS behavior).
    fn pop(&mut self, burst: u32, priority: Option<Priority>) -> Option<(u64, Invocation)> {
        match priority {
            Some(Priority::Interactive) => self.interactive.pop_front(),
            Some(Priority::Batch) => self.batch.pop_front(),
            None => match (self.interactive.is_empty(), self.batch.is_empty()) {
                (true, true) => None,
                (false, true) => self.interactive.pop_front(),
                (true, false) => {
                    self.interactive_streak = 0;
                    self.batch.pop_front()
                }
                (false, false) => {
                    let take_batch = if burst == 0 {
                        let fi = self.interactive.front().expect("checked").0;
                        let fb = self.batch.front().expect("checked").0;
                        fb < fi
                    } else {
                        self.interactive_streak >= burst
                    };
                    if take_batch {
                        self.interactive_streak = 0;
                        self.batch.pop_front()
                    } else {
                        self.interactive_streak += 1;
                        self.interactive.pop_front()
                    }
                }
            },
        }
    }
}

/// Whether an invocation's input data is in the filter's hot-set — the
/// primary dataset or any fan-in input counts.
fn invocation_is_hot(filter: &TakeFilter, inv: &Invocation) -> bool {
    filter.is_hot(&inv.spec.dataset)
        || inv.spec.datasets.iter().any(|d| filter.is_hot(d))
}

struct Inner {
    /// Per-runtime-class lanes (QoS-split FIFOs of `(seq, invocation)`).
    /// Lanes are removed when empty, so every present lane has a front.
    queued: HashMap<String, Lane>,
    /// Global FIFO mirror: seq → runtime class of every queued
    /// invocation.  `order.len()` is the queue depth.
    order: BTreeMap<u64, String>,
    /// Next seq for a back-of-queue publish (ascending from SEQ_BASE).
    next_seq: u64,
    /// Next seq for a front requeue (descending from SEQ_BASE).
    front_seq: u64,
    in_flight: HashMap<String, InFlight>,
    /// Lease deadlines, lazily pruned: an entry whose id is no longer in
    /// flight (acked) or whose deadline no longer matches (re-leased) is
    /// skipped on pop.
    deadlines: BinaryHeap<Reverse<(SimTime, String)>>,
    attempts: HashMap<String, u32>,
    dead: Vec<Invocation>,
    acked: usize,
    /// Ids currently queued or in flight — O(1) duplicate detection on
    /// publish (the scan-based check was O(n) per publish and collapsed
    /// deep-queue ingest to ~2.6k ops/s; see EXPERIMENTS.md §Perf).
    live_ids: HashSet<String>,
    /// Bumped whenever work (re)appears.  `take_timeout` waits for this
    /// to change instead of re-probing on "queue non-empty" — which
    /// busy-spun when the queue held only non-matching work.
    generation: u64,
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            queued: HashMap::new(),
            order: BTreeMap::new(),
            next_seq: SEQ_BASE,
            front_seq: SEQ_BASE,
            in_flight: HashMap::new(),
            deadlines: BinaryHeap::new(),
            attempts: HashMap::new(),
            dead: Vec::new(),
            acked: 0,
            live_ids: HashSet::new(),
            generation: 0,
        }
    }
}

impl Inner {
    fn enqueue_back(&mut self, inv: Invocation) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.insert(seq, inv, false);
    }

    fn enqueue_front(&mut self, inv: Invocation) {
        self.front_seq -= 1;
        let seq = self.front_seq;
        self.insert(seq, inv, true);
    }

    fn insert(&mut self, seq: u64, inv: Invocation, front: bool) {
        self.order.insert(seq, inv.spec.runtime.clone());
        let lane = self.queued.entry(inv.spec.runtime.clone()).or_default();
        lane.push(seq, inv, front);
        self.generation += 1;
    }

    /// One scan over the given classes' lane fronts — one probe per
    /// class, independent of queue depth.  The best lane is the one with
    /// the smallest front seq (plain FIFO), or — under `prefer_deep` —
    /// the **deepest** lane (ties broken by older front seq, the
    /// micro-batching preference).  Lanes are viewed through the
    /// filter's priority restriction: a lane holding only the other QoS
    /// class is invisible.  Shared by `take_locked`'s FIFO pick and the
    /// grouped takes, so the two selection paths cannot drift.
    fn best_lane<'a>(
        &self,
        classes: impl Iterator<Item = &'a String>,
        prefer_deep: bool,
        priority: Option<Priority>,
    ) -> Option<(u64, String)> {
        let mut best: Option<(u64, usize, &String)> = None;
        for rt in classes {
            if let Some(lane) = self.queued.get(rt) {
                let Some((front, depth)) = lane.view(priority) else {
                    continue;
                };
                let better = match &best {
                    None => true,
                    Some((bf, bd, _)) if prefer_deep => {
                        depth > *bd || (depth == *bd && front < *bf)
                    }
                    Some((bf, _, _)) => front < *bf,
                };
                if better {
                    best = Some((front, depth, rt));
                }
            }
        }
        best.map(|(front, _, rt)| (front, rt.clone()))
    }

    /// Smallest front seq among the given classes' lanes.
    fn min_front<'a>(
        &self,
        classes: impl Iterator<Item = &'a String>,
        priority: Option<Priority>,
    ) -> Option<(u64, String)> {
        self.best_lane(classes, false, priority)
    }

    /// Smallest front seq among lanes whose next deliverable invocation
    /// (exactly what [`Lane::pop`] would hand out, via [`Lane::peek`])
    /// reads a dataset from the filter's hot-set.  The cache-affinity
    /// tier of the take ranking: warm ▸ **hot** ▸ FIFO (DESIGN.md §15).
    /// One peek per candidate class — same O(|classes|) cost as
    /// [`Inner::min_front`]; a lane's *deeper* entries are not probed,
    /// so hot preference is a front-of-lane bias, never a queue scan.
    fn hot_front<'a>(
        &self,
        classes: impl Iterator<Item = &'a String>,
        filter: &TakeFilter,
        burst: u32,
        priority: Option<Priority>,
    ) -> Option<(u64, String)> {
        let mut best: Option<(u64, &String)> = None;
        for rt in classes {
            let Some(lane) = self.queued.get(rt) else { continue };
            let Some((seq, inv)) = lane.peek(burst, priority) else {
                continue;
            };
            if !invocation_is_hot(filter, inv) {
                continue;
            }
            if best.map(|(bs, _)| *seq < bs).unwrap_or(true) {
                best = Some((*seq, rt));
            }
        }
        best.map(|(seq, rt)| (seq, rt.clone()))
    }

    /// Lane choice for a grouped take (see [`Inner::best_lane`]).
    fn pick_lane<'a>(
        &self,
        classes: impl Iterator<Item = &'a String>,
        prefer_deep: bool,
        priority: Option<Priority>,
    ) -> Option<String> {
        self.best_lane(classes, prefer_deep, priority).map(|(_, rt)| rt)
    }
}

/// In-memory [`InvocationQueue`] engine.
pub struct MemQueue {
    inner: Mutex<Inner>,
    /// Signalled whenever work (re)appears — lets `take_timeout` block
    /// instead of poll (idle dispatch latency: ~poll-interval → ~0.1 ms).
    available: std::sync::Condvar,
    clock: Arc<dyn Clock>,
    config: QueueConfig,
}

impl MemQueue {
    pub fn new(clock: Arc<dyn Clock>) -> Arc<MemQueue> {
        MemQueue::with_config(clock, QueueConfig::default())
    }

    pub fn with_config(clock: Arc<dyn Clock>, config: QueueConfig) -> Arc<MemQueue> {
        Arc::new(MemQueue {
            inner: Mutex::new(Inner::default()),
            available: std::sync::Condvar::new(),
            clock,
            config,
        })
    }

    /// Dead-lettered invocations (diagnostics).
    pub fn dead_letters(&self) -> Vec<Invocation> {
        self.inner.lock().expect("queue poisoned").dead.clone()
    }

    /// Peek the queued runtimes in order (diagnostics / scheduler tests).
    pub fn queued_runtimes(&self) -> Vec<String> {
        self.inner
            .lock()
            .expect("queue poisoned")
            .order
            .values()
            .cloned()
            .collect()
    }

    /// The scan-and-take under an already-held lock: warm lanes first
    /// (earliest seq wins, §IV-D), then supported lanes, then — for the
    /// match-any diagnostics filter — the global FIFO head.  The pick
    /// chooses the **class**; within it, [`Lane::pop`]'s weighted rule
    /// chooses the QoS sub-queue, so the popped invocation may not be
    /// the lane's seq-front (interactive precedence).
    fn take_locked(&self, inner: &mut Inner, filter: &TakeFilter) -> Option<Lease> {
        let pri = filter.priority;
        let mut pick = inner
            .min_front(filter.warm.iter(), pri)
            .map(|(seq, rt)| (seq, rt, true));
        if pick.is_none() && !filter.warm_only && !filter.hot_datasets.is_empty() {
            // Cache-affinity tier (warm ▸ hot ▸ FIFO, DESIGN.md §15):
            // among cold candidates, a lane whose next deliverable
            // invocation reads a dataset this node already caches beats
            // global FIFO order.  Skipped entirely when the hot-set is
            // empty, so affinity-off takes are byte-identical to the
            // legacy warm-first behavior.
            let burst = self.config.interactive_burst;
            pick = if filter.runtimes.is_empty() {
                inner.hot_front(inner.queued.keys(), filter, burst, pri)
            } else {
                inner.hot_front(filter.runtimes.iter(), filter, burst, pri)
            }
            .map(|(seq, rt)| (seq, rt, false));
        }
        if pick.is_none() && !filter.warm_only {
            pick = if filter.runtimes.is_empty() {
                match pri {
                    // Global FIFO head straight off the order mirror.
                    None => inner
                        .order
                        .iter()
                        .next()
                        .map(|(&seq, rt)| (seq, rt.clone(), false)),
                    // Priority-pinned match-any: the mirror doesn't know
                    // QoS, so probe every lane front (O(|classes|)).
                    Some(_) => inner
                        .min_front(inner.queued.keys(), pri)
                        .map(|(seq, rt)| (seq, rt, false)),
                }
            } else {
                inner
                    .min_front(filter.runtimes.iter(), pri)
                    .map(|(seq, rt)| (seq, rt, false))
            };
        }
        let (_front_seq, rt, warm_hit) = pick?;
        let lane = inner.queued.get_mut(&rt).expect("picked lane exists");
        let (seq, invocation) = lane
            .pop(self.config.interactive_burst, pri)
            .expect("picked lane has a matching invocation");
        if lane.is_empty() {
            inner.queued.remove(&rt);
        }
        inner.order.remove(&seq);
        let attempt = {
            let a = inner.attempts.entry(invocation.id.clone()).or_insert(0);
            *a += 1;
            *a
        };
        let deadline = SimTime(
            self.clock.now().as_micros() + self.config.visibility.as_micros() as u64,
        );
        inner
            .deadlines
            .push(Reverse((deadline, invocation.id.clone())));
        inner.in_flight.insert(
            invocation.id.clone(),
            InFlight { invocation: invocation.clone(), deadline, attempt },
        );
        Some(Lease { invocation, warm_hit, attempt })
    }

    fn publish_locked(inner: &mut Inner, inv: Invocation) -> Result<()> {
        if !inner.live_ids.insert(inv.id.clone()) {
            bail!("duplicate invocation id {}", inv.id);
        }
        inner.enqueue_back(inv);
        Ok(())
    }
}

impl InvocationQueue for MemQueue {
    fn publish(&self, inv: Invocation) -> Result<()> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        Self::publish_locked(&mut inner, inv)?;
        drop(inner);
        self.available.notify_all();
        Ok(())
    }

    fn publish_batch(&self, invs: Vec<Invocation>) -> Result<()> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        // All-or-nothing: validate the whole batch against live ids (and
        // against itself) before inserting anything.
        let mut fresh = HashSet::new();
        for inv in &invs {
            if inner.live_ids.contains(&inv.id) || !fresh.insert(inv.id.as_str()) {
                bail!("duplicate invocation id {} in batch", inv.id);
            }
        }
        for inv in invs {
            Self::publish_locked(&mut inner, inv).expect("batch pre-validated");
        }
        drop(inner);
        self.available.notify_all();
        Ok(())
    }

    fn take(&self, filter: &TakeFilter) -> Result<Option<Lease>> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        Ok(self.take_locked(&mut inner, filter))
    }

    fn take_batch(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        let mut out = Vec::new();
        while out.len() < max {
            match self.take_locked(&mut inner, filter) {
                Some(lease) => out.push(lease),
                None => break,
            }
        }
        Ok(out)
    }

    /// One lock hold: pick the lane (warm classes first; deepest when the
    /// filter prefers deep, oldest-front otherwise) and drain up to `max`
    /// leases from it in FIFO order.
    fn take_batch_grouped(&self, filter: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        if max == 0 {
            return Ok(Vec::new());
        }
        let pri = filter.priority;
        let pick = inner
            .pick_lane(filter.warm.iter(), filter.prefer_deep, pri)
            .map(|rt| (rt, true))
            .or_else(|| {
                // Cache-affinity tier, mirroring `take_locked`: a hot
                // lane front beats both depth and FIFO among cold
                // candidates (oldest hot front wins — the grouped take
                // then drains that class, coalescing the hot data).
                if filter.warm_only || filter.hot_datasets.is_empty() {
                    return None;
                }
                let burst = self.config.interactive_burst;
                if filter.runtimes.is_empty() {
                    inner.hot_front(inner.queued.keys(), filter, burst, pri)
                } else {
                    inner.hot_front(filter.runtimes.iter(), filter, burst, pri)
                }
                .map(|(_, rt)| (rt, false))
            })
            .or_else(|| {
                if filter.warm_only {
                    None
                } else if filter.runtimes.is_empty() {
                    inner
                        .pick_lane(inner.queued.keys(), filter.prefer_deep, pri)
                        .map(|rt| (rt, false))
                } else {
                    inner
                        .pick_lane(filter.runtimes.iter(), filter.prefer_deep, pri)
                        .map(|rt| (rt, false))
                }
            });
        let Some((rt, warm_hit)) = pick else {
            return Ok(Vec::new());
        };
        // Single-class filter whose warm/cold split reproduces the pick,
        // so each lease carries the right `warm_hit`.
        let lane_filter = TakeFilter {
            runtimes: HashSet::from([rt.clone()]),
            warm: if warm_hit { HashSet::from([rt]) } else { HashSet::new() },
            warm_only: warm_hit,
            prefer_deep: false,
            priority: pri,
            // The class is already pinned; continuation takes within it
            // are plain FIFO, hot or not.
            hot_datasets: HashSet::new(),
        };
        let mut out = Vec::new();
        while out.len() < max {
            match self.take_locked(&mut inner, &lane_filter) {
                Some(lease) => out.push(lease),
                None => break,
            }
        }
        Ok(out)
    }

    fn ack(&self, invocation_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        if inner.in_flight.remove(invocation_id).is_none() {
            bail!("ack for unknown or expired lease: {invocation_id}");
        }
        // The deadline-heap entry is pruned lazily by reap_expired.
        inner.attempts.remove(invocation_id);
        inner.live_ids.remove(invocation_id);
        inner.acked += 1;
        Ok(())
    }

    fn release(&self, invocation_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        let Some(inflight) = inner.in_flight.remove(invocation_id) else {
            bail!("release for unknown lease: {invocation_id}");
        };
        // A voluntary release does not burn an attempt.
        if let Some(a) = inner.attempts.get_mut(invocation_id) {
            *a = a.saturating_sub(1);
        }
        inner.enqueue_front(inflight.invocation);
        drop(inner);
        self.available.notify_all();
        Ok(())
    }

    fn reap_expired(&self) -> Result<usize> {
        let now = self.clock.now();
        let mut inner = self.inner.lock().expect("queue poisoned");
        let mut n = 0;
        loop {
            match inner.deadlines.peek() {
                Some(Reverse((deadline, _))) if *deadline <= now => {}
                _ => break,
            }
            let Reverse((deadline, id)) = inner.deadlines.pop().expect("just peeked");
            match inner.in_flight.get(&id) {
                // Stale entries: the lease was acked, or re-leased with a
                // later deadline (that lease has its own heap entry).
                None => continue,
                Some(f) if f.deadline != deadline => continue,
                Some(_) => {}
            }
            let f = inner.in_flight.remove(&id).expect("just checked");
            n += 1;
            if f.attempt >= self.config.max_attempts {
                inner.live_ids.remove(&id);
                inner.dead.push(f.invocation);
            } else {
                // Lost leases go to the *front*: they are the oldest work.
                inner.enqueue_front(f.invocation);
            }
        }
        if n > 0 {
            drop(inner);
            self.available.notify_all();
        }
        Ok(n)
    }

    fn take_timeout(
        &self,
        filter: &TakeFilter,
        wall_timeout: Duration,
    ) -> Result<Option<Lease>> {
        let deadline = std::time::Instant::now() + wall_timeout;
        let mut inner = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(lease) = self.take_locked(&mut inner, filter) {
                return Ok(Some(lease));
            }
            // Park until new work arrives (publish/release/reap bump the
            // generation) or the timeout elapses.  The probe above and
            // the wait below happen under one continuous lock hold, so a
            // publish cannot slip between them; spurious wakeups re-wait
            // unless the generation moved.
            let gen = inner.generation;
            while inner.generation == gen {
                let left = deadline.saturating_duration_since(std::time::Instant::now());
                if left.is_zero() {
                    return Ok(None);
                }
                let (guard, _) = self
                    .available
                    .wait_timeout(inner, left)
                    .expect("queue poisoned");
                inner = guard;
            }
        }
    }

    fn stats(&self) -> Result<QueueStats> {
        let now = self.clock.now();
        let inner = self.inner.lock().expect("queue poisoned");
        // Per-class probe: one lane-front read per present class —
        // O(|classes|), independent of queue depth (every lane is a FIFO
        // whose front is its oldest member, front requeues included).
        let mut classes: Vec<super::ClassStats> = inner
            .queued
            .iter()
            .map(|(rt, lane)| {
                let age_ms = |inv: &Invocation| {
                    inv.stamps
                        .r_start
                        .map(|t| now.since(t).as_millis() as u64)
                        .unwrap_or(0)
                };
                // The lane's seq-front (its oldest member across both QoS
                // sub-queues) drives the general age gauge; the
                // interactive sub-queue front drives the QoS watermark.
                let fi = lane.interactive.front();
                let fb = lane.batch.front();
                let front = match (fi, fb) {
                    (Some(a), Some(b)) => Some(if a.0 <= b.0 { &a.1 } else { &b.1 }),
                    (Some(a), None) => Some(&a.1),
                    (None, Some(b)) => Some(&b.1),
                    (None, None) => None,
                };
                super::ClassStats {
                    runtime: rt.clone(),
                    queued: lane.len(),
                    oldest_waiting_ms: front.map(age_ms).unwrap_or(0),
                    interactive_queued: lane.interactive.len(),
                    interactive_oldest_ms: fi.map(|(_, inv)| age_ms(inv)).unwrap_or(0),
                }
            })
            .collect();
        classes.sort_by(|a, b| a.runtime.cmp(&b.runtime));
        Ok(QueueStats {
            queued: inner.order.len(),
            in_flight: inner.in_flight.len(),
            acked: inner.acked,
            dead: inner.dead.len(),
            classes,
            shards: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::EventSpec;
    use crate::util::clock::TestClock;

    fn inv(id: &str, runtime: &str) -> Invocation {
        Invocation::new(id, EventSpec::new(runtime, "datasets/d"), SimTime(0))
    }

    fn pinv(id: &str, runtime: &str, priority: Priority, at: SimTime) -> Invocation {
        Invocation::new(
            id,
            EventSpec::new(runtime, "datasets/d").with_priority(priority),
            at,
        )
    }

    fn queue() -> (Arc<crate::util::clock::TestClock>, Arc<MemQueue>) {
        let clock = TestClock::new();
        let q = MemQueue::new(clock.clone());
        (clock, q)
    }

    #[test]
    fn fifo_within_class() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        q.publish(inv("2", "a")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into()]);
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "1");
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "2");
        assert!(q.take(&f).unwrap().is_none());
    }

    #[test]
    fn fifo_across_classes_by_publish_order() {
        // The seq tiebreak: with both classes supported and neither warm,
        // delivery follows global publish order, not lane order.
        let (_c, q) = queue();
        q.publish(inv("1", "b")).unwrap();
        q.publish(inv("2", "a")).unwrap();
        q.publish(inv("3", "b")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()]);
        let got: Vec<String> = std::iter::from_fn(|| {
            q.take(&f).unwrap().map(|l| l.invocation.id)
        })
        .collect();
        assert_eq!(got, vec!["1", "2", "3"]);
    }

    #[test]
    fn unsupported_runtime_not_taken() {
        let (_c, q) = queue();
        q.publish(inv("1", "zzz")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into()]);
        assert!(q.take(&f).unwrap().is_none());
        assert_eq!(q.stats().unwrap().queued, 1);
    }

    #[test]
    fn warm_scan_jumps_queue_order() {
        // Paper §IV-D: the node prefers invocations it is warm for, even if
        // they sit behind other work in the queue.
        let (_c, q) = queue();
        q.publish(inv("cold-1", "a")).unwrap();
        q.publish(inv("warm-1", "b")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_warm(vec!["b".into()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "warm-1");
        assert!(lease.warm_hit);
        // Next take falls back to the cold invocation.
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "cold-1");
        assert!(!lease.warm_hit);
    }

    fn dinv(id: &str, runtime: &str, dataset: &str) -> Invocation {
        Invocation::new(id, EventSpec::new(runtime, dataset), SimTime(0))
    }

    #[test]
    fn hot_dataset_jumps_fifo_order() {
        // The affinity tier: a lane whose front reads a locally-cached
        // dataset is served before older cold work — warm ▸ hot ▸ FIFO.
        let (_c, q) = queue();
        q.publish(dinv("cold-1", "a", "datasets/cold")).unwrap();
        q.publish(dinv("hot-1", "b", "datasets/hot")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_hot_datasets(vec!["datasets/hot".into()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "hot-1", "hot data beats FIFO");
        assert!(!lease.warm_hit, "hot is not warm");
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "cold-1");
    }

    #[test]
    fn warm_preference_still_beats_hot_data() {
        let (_c, q) = queue();
        q.publish(dinv("warm-1", "a", "datasets/cold")).unwrap();
        q.publish(dinv("hot-1", "b", "datasets/hot")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_warm(vec!["a".into()])
            .with_hot_datasets(vec!["datasets/hot".into()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "warm-1", "warm instance outranks hot data");
        assert!(lease.warm_hit);
    }

    #[test]
    fn empty_hot_set_is_plain_warm_first_fifo() {
        // Affinity off must be byte-identical to the legacy ranking.
        let (_c, q) = queue();
        q.publish(dinv("cold-1", "a", "datasets/cold")).unwrap();
        q.publish(dinv("hot-1", "b", "datasets/hot")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()]);
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "cold-1");
    }

    #[test]
    fn stale_hot_hint_degrades_to_fifo_without_skipping() {
        // A hot-set entry nothing queued refers to (evicted data, stale
        // gossip) must cost nothing: plain FIFO delivery, never a skip.
        let (_c, q) = queue();
        q.publish(dinv("cold-1", "a", "datasets/cold")).unwrap();
        q.publish(dinv("cold-2", "b", "datasets/other")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_hot_datasets(vec!["datasets/gone".into()]);
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "cold-1");
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "cold-2");
        assert!(q.take(&f).unwrap().is_none());
    }

    #[test]
    fn hot_preference_is_front_of_lane_only() {
        // Hot data buried behind cold work in the *same* lane does not
        // jump within the lane (per-class FIFO is preserved); only lane
        // fronts compete in the affinity tier.
        let (_c, q) = queue();
        q.publish(dinv("a1", "a", "datasets/cold")).unwrap();
        q.publish(dinv("a2", "a", "datasets/hot")).unwrap();
        q.publish(dinv("b1", "b", "datasets/other")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_hot_datasets(vec!["datasets/hot".into()]);
        assert_eq!(
            q.take(&f).unwrap().unwrap().invocation.id,
            "a1",
            "no lane front is hot -> global FIFO"
        );
        // Once the hot invocation reaches its lane front it does win,
        // even against an older cold front in another lane:
        let (_c, q) = queue();
        q.publish(dinv("b1", "b", "datasets/other")).unwrap();
        q.publish(dinv("a2", "a", "datasets/hot")).unwrap();
        assert_eq!(
            q.take(&f).unwrap().unwrap().invocation.id,
            "a2",
            "hot lane front beats the older cold front"
        );
    }

    #[test]
    fn fanin_inputs_count_for_hot_preference() {
        let (_c, q) = queue();
        q.publish(dinv("cold-1", "a", "datasets/cold")).unwrap();
        let mut join = dinv("join-1", "b", "results/p1");
        join.spec = join.spec.with_datasets(["results/p1", "results/p2"]);
        q.publish(join).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_hot_datasets(vec!["results/p2".into()]);
        assert_eq!(
            q.take(&f).unwrap().unwrap().invocation.id,
            "join-1",
            "any fan-in input being hot qualifies"
        );
    }

    #[test]
    fn grouped_take_hot_lane_beats_depth_and_fifo() {
        let (_c, q) = queue();
        q.publish(dinv("c0", "a", "datasets/cold")).unwrap();
        for i in 1..4 {
            q.publish(dinv(&format!("c{i}"), "a", "datasets/cold")).unwrap();
        }
        q.publish(dinv("h0", "b", "datasets/hot")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .preferring_deep(true)
            .with_hot_datasets(vec!["datasets/hot".into()]);
        let leases = q.take_batch_grouped(&f, 8).unwrap();
        let ids: Vec<&str> = leases.iter().map(|l| l.invocation.id.as_str()).collect();
        assert_eq!(ids, vec!["h0"], "hot lane chosen over the deeper cold lane");
        // With the hot lane drained, the deep cold lane flows as before.
        let leases = q.take_batch_grouped(&f, 8).unwrap();
        assert_eq!(leases.len(), 4);
    }

    #[test]
    fn warm_only_reuse_query() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        // completion-time reuse probe for runtime "b": nothing to reuse
        assert!(q.take(&TakeFilter::warm_reuse("b")).unwrap().is_none());
        // for runtime "a": match
        let lease = q.take(&TakeFilter::warm_reuse("a")).unwrap().unwrap();
        assert!(lease.warm_hit);
    }

    #[test]
    fn ack_completes_lease() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        q.ack(&lease.invocation.id).unwrap();
        let s = q.stats().unwrap();
        assert_eq!((s.queued, s.in_flight, s.acked), (0, 0, 1));
        assert!(q.ack("1").is_err(), "double ack rejected");
    }

    #[test]
    fn release_requeues_at_front_without_attempt_burn() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        q.publish(inv("2", "a")).unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.attempt, 1);
        q.release("1").unwrap();
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "1", "released work re-delivered first");
        assert_eq!(lease.attempt, 1, "voluntary release burns no attempt");
    }

    #[test]
    fn released_work_beats_every_queued_class() {
        // Front requeue must win the cross-class seq tiebreak too.
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        q.publish(inv("2", "b")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()]);
        let lease = q.take(&f).unwrap().unwrap();
        assert_eq!(lease.invocation.id, "1");
        q.release("1").unwrap();
        assert_eq!(q.queued_runtimes(), vec!["a", "b"], "released to the front");
        assert_eq!(q.take(&f).unwrap().unwrap().invocation.id, "1");
    }

    #[test]
    fn visibility_timeout_requeues() {
        let (clock, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        let _lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(q.reap_expired().unwrap(), 0, "not expired yet");
        clock.advance(Duration::from_secs(31));
        assert_eq!(q.reap_expired().unwrap(), 1);
        let lease = q.take(&TakeFilter::default()).unwrap().unwrap();
        assert_eq!(lease.attempt, 2, "redelivery increments attempt");
    }

    #[test]
    fn dead_letter_after_max_attempts() {
        let clock = TestClock::new();
        let q = MemQueue::with_config(
            clock.clone(),
            QueueConfig {
                visibility: Duration::from_secs(1),
                max_attempts: 2,
                ..QueueConfig::default()
            },
        );
        q.publish(inv("1", "a")).unwrap();
        for _ in 0..2 {
            q.take(&TakeFilter::default()).unwrap().unwrap();
            clock.advance(Duration::from_secs(2));
            q.reap_expired().unwrap();
        }
        assert!(q.take(&TakeFilter::default()).unwrap().is_none());
        assert_eq!(q.stats().unwrap().dead, 1);
        assert_eq!(q.dead_letters()[0].id, "1");
    }

    #[test]
    fn stale_heap_entries_do_not_reap_new_leases() {
        // ack leaves its deadline-heap entry behind; a later lease of the
        // same id must not be reaped through the stale entry.
        let clock = TestClock::new();
        let q = MemQueue::with_config(
            clock.clone(),
            QueueConfig {
                visibility: Duration::from_secs(1),
                max_attempts: 5,
                ..QueueConfig::default()
            },
        );
        q.publish(inv("1", "a")).unwrap();
        q.take(&TakeFilter::default()).unwrap().unwrap();
        q.ack("1").unwrap();
        // Same id is live again (allowed after ack), leased with a fresh
        // deadline strictly later than the stale one.
        clock.advance(Duration::from_millis(500));
        q.publish(inv("1", "a")).unwrap();
        q.take(&TakeFilter::default()).unwrap().unwrap();
        // Past the stale deadline, before the live one: nothing reaps.
        clock.advance(Duration::from_millis(700));
        assert_eq!(q.reap_expired().unwrap(), 0);
        assert_eq!(q.stats().unwrap().in_flight, 1);
        // Past the live deadline: exactly one reap.
        clock.advance(Duration::from_secs(1));
        assert_eq!(q.reap_expired().unwrap(), 1);
    }

    #[test]
    fn stats_expose_per_class_depth_and_age() {
        let (clock, q) = queue();
        // Two classes: "a" has depth 2 (oldest published at t=0), "b"
        // depth 1 (published at t=4s).
        q.publish(inv("a1", "a")).unwrap();
        q.publish(inv("a2", "a")).unwrap();
        clock.advance(Duration::from_secs(4));
        q.publish(
            Invocation::new("b1", EventSpec::new("b", "datasets/d"), clock.now()),
        )
        .unwrap();
        clock.advance(Duration::from_secs(1));
        let s = q.stats().unwrap();
        assert_eq!(s.queued, 3);
        assert_eq!(s.classes.len(), 2, "{:?}", s.classes);
        assert_eq!(s.classes[0].runtime, "a", "sorted by runtime");
        assert_eq!(s.classes[0].queued, 2);
        assert_eq!(s.classes[0].oldest_waiting_ms, 5000, "front of lane a is a1 (t=0)");
        assert_eq!(s.classes[1].runtime, "b");
        assert_eq!(s.classes[1].queued, 1);
        assert_eq!(s.classes[1].oldest_waiting_ms, 1000);
        // Taking the lane front shifts the class gauge to the next item;
        // draining a lane removes its class entirely.
        let f = TakeFilter::supporting(vec!["a".into()]);
        q.take(&f).unwrap().unwrap();
        q.take(&f).unwrap().unwrap();
        let s = q.stats().unwrap();
        assert_eq!(s.classes.len(), 1, "lane a drained: {:?}", s.classes);
        assert_eq!(s.classes[0].runtime, "b");
        // An expired lease requeued at the front restores the class with
        // its original age.
        clock.advance(Duration::from_secs(31));
        q.reap_expired().unwrap();
        let s = q.stats().unwrap();
        let a = s.classes.iter().find(|c| c.runtime == "a").expect("requeued");
        assert_eq!(a.queued, 2);
        assert_eq!(a.oldest_waiting_ms, 36_000, "age measured from RStart");
    }

    #[test]
    fn duplicate_publish_rejected() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        assert!(q.publish(inv("1", "a")).is_err());
    }

    #[test]
    fn publish_batch_is_all_or_nothing() {
        let (_c, q) = queue();
        q.publish(inv("1", "a")).unwrap();
        // batch colliding with a live id: nothing from it lands
        assert!(q
            .publish_batch(vec![inv("2", "a"), inv("1", "a")])
            .is_err());
        assert_eq!(q.stats().unwrap().queued, 1);
        // batch colliding with itself: same
        assert!(q
            .publish_batch(vec![inv("3", "a"), inv("3", "a")])
            .is_err());
        assert_eq!(q.stats().unwrap().queued, 1);
        // clean batch lands in order
        q.publish_batch(vec![inv("4", "a"), inv("5", "b")]).unwrap();
        assert_eq!(q.queued_runtimes(), vec!["a", "a", "b"]);
    }

    #[test]
    fn take_batch_matches_repeated_takes() {
        let (_c, q) = queue();
        for i in 0..6 {
            q.publish(inv(&format!("i{i}"), if i % 2 == 0 { "a" } else { "b" }))
                .unwrap();
        }
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_warm(vec!["b".into()]);
        // warm lane first (i1, i3, i5), then cold in order (i0, i2)
        let leases = q.take_batch(&f, 5).unwrap();
        let ids: Vec<&str> = leases.iter().map(|l| l.invocation.id.as_str()).collect();
        assert_eq!(ids, vec!["i1", "i3", "i5", "i0", "i2"]);
        assert!(leases[0].warm_hit && leases[2].warm_hit && !leases[3].warm_hit);
        // max respected; remainder still queued
        assert_eq!(q.stats().unwrap().queued, 1);
        let ids: Vec<String> = leases.into_iter().map(|l| l.invocation.id).collect();
        q.ack_batch(&ids).unwrap();
        assert_eq!(q.stats().unwrap().acked, 5);
    }

    #[test]
    fn take_batch_grouped_is_single_class_fifo() {
        let (_c, q) = queue();
        q.publish(inv("a1", "a")).unwrap();
        q.publish(inv("b1", "b")).unwrap();
        q.publish(inv("a2", "a")).unwrap();
        q.publish(inv("b2", "b")).unwrap();
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()]);
        // plain pick: lane of the oldest front (a1), drained FIFO, b untouched
        let leases = q.take_batch_grouped(&f, 8).unwrap();
        let ids: Vec<&str> = leases.iter().map(|l| l.invocation.id.as_str()).collect();
        assert_eq!(ids, vec!["a1", "a2"]);
        assert!(leases.iter().all(|l| !l.warm_hit));
        assert_eq!(q.stats().unwrap().queued, 2, "other class untouched");
        // max respected
        let leases = q.take_batch_grouped(&f, 1).unwrap();
        assert_eq!(leases[0].invocation.id, "b1");
        // nothing matching -> empty
        assert!(q
            .take_batch_grouped(&TakeFilter::supporting(vec!["z".into()]), 4)
            .unwrap()
            .is_empty());
    }

    #[test]
    fn take_batch_grouped_prefer_deep_coalesces_deepest_lane() {
        let (_c, q) = queue();
        q.publish(inv("a1", "a")).unwrap(); // older but shallow
        for i in 0..4 {
            q.publish(inv(&format!("b{i}"), "b")).unwrap();
        }
        let f = TakeFilter::supporting(vec!["a".into(), "b".into()]).preferring_deep(true);
        let leases = q.take_batch_grouped(&f, 8).unwrap();
        let ids: Vec<&str> = leases.iter().map(|l| l.invocation.id.as_str()).collect();
        assert_eq!(ids, vec!["b0", "b1", "b2", "b3"], "deepest lane wins");
        // warm lanes still beat depth: a is warm, the deep b lane is not
        for i in 0..3 {
            q.publish(inv(&format!("c{i}"), "b")).unwrap();
        }
        let warm_f = TakeFilter::supporting(vec!["a".into(), "b".into()])
            .with_warm(vec!["a".into()])
            .preferring_deep(true);
        let leases = q.take_batch_grouped(&warm_f, 8).unwrap();
        let ids: Vec<&str> = leases.iter().map(|l| l.invocation.id.as_str()).collect();
        assert_eq!(ids, vec!["a1"], "warm class preferred over deeper cold lane");
        assert!(leases[0].warm_hit);
    }

    #[test]
    fn weighted_take_interleaves_batch_at_burst_ratio() {
        // 10 batch queued first, then 10 interactive: unrestricted takes
        // serve interactive first but interleave one batch invocation
        // after every `interactive_burst` (= 3) interactive pops, so
        // neither lane starves.  Once interactive drains, batch flows.
        let (_c, q) = queue();
        for i in 0..10 {
            q.publish(pinv(&format!("b{i}"), "a", Priority::Batch, SimTime(0))).unwrap();
        }
        for i in 0..10 {
            q.publish(pinv(&format!("i{i}"), "a", Priority::Interactive, SimTime(0)))
                .unwrap();
        }
        let f = TakeFilter::supporting(vec!["a".into()]);
        let got: Vec<String> = std::iter::from_fn(|| {
            q.take(&f).unwrap().map(|l| l.invocation.id)
        })
        .collect();
        let want: Vec<&str> = vec![
            "i0", "i1", "i2", "b0", // 3:1 interleave while both wait
            "i3", "i4", "i5", "b1", //
            "i6", "i7", "i8", "b2", //
            "i9", // interactive drained mid-burst
            "b3", "b4", "b5", "b6", "b7", "b8", "b9",
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn burst_zero_disables_lanes_to_pure_fifo() {
        // The lanes-off ablation: publish order is delivery order even
        // across priorities.
        let clock = TestClock::new();
        let q = MemQueue::with_config(
            clock.clone(),
            QueueConfig { interactive_burst: 0, ..QueueConfig::default() },
        );
        q.publish(pinv("b0", "a", Priority::Batch, SimTime(0))).unwrap();
        q.publish(pinv("i0", "a", Priority::Interactive, SimTime(0))).unwrap();
        q.publish(pinv("b1", "a", Priority::Batch, SimTime(0))).unwrap();
        let f = TakeFilter::supporting(vec!["a".into()]);
        let got: Vec<String> = std::iter::from_fn(|| {
            q.take(&f).unwrap().map(|l| l.invocation.id)
        })
        .collect();
        assert_eq!(got, vec!["b0", "i0", "b1"], "no precedence with lanes off");
    }

    #[test]
    fn priority_pinned_filter_sees_only_its_lane() {
        let (_c, q) = queue();
        q.publish(pinv("i0", "a", Priority::Interactive, SimTime(0))).unwrap();
        q.publish(pinv("b0", "a", Priority::Batch, SimTime(0))).unwrap();
        // Batch-pinned: the older interactive invocation is invisible.
        let pinned = TakeFilter::supporting(vec!["a".into()])
            .for_priority(Some(Priority::Batch));
        assert_eq!(q.take(&pinned).unwrap().unwrap().invocation.id, "b0");
        assert!(q.take(&pinned).unwrap().is_none(), "batch lane drained");
        // Match-any (empty runtimes) + priority pin takes the probe path.
        let any_inter = TakeFilter::default().for_priority(Some(Priority::Interactive));
        assert_eq!(q.take(&any_inter).unwrap().unwrap().invocation.id, "i0");
        assert_eq!(q.stats().unwrap().queued, 0);
    }

    #[test]
    fn stats_expose_interactive_split_per_class() {
        let (clock, q) = queue();
        q.publish(pinv("b0", "a", Priority::Batch, clock.now())).unwrap();
        clock.advance(Duration::from_secs(2));
        q.publish(pinv("i0", "a", Priority::Interactive, clock.now())).unwrap();
        q.publish(pinv("i1", "a", Priority::Interactive, clock.now())).unwrap();
        clock.advance(Duration::from_secs(1));
        let s = q.stats().unwrap();
        assert_eq!(s.classes.len(), 1);
        let c = &s.classes[0];
        assert_eq!((c.queued, c.interactive_queued), (3, 2));
        assert_eq!(c.oldest_waiting_ms, 3000, "general age from the batch front");
        assert_eq!(c.interactive_oldest_ms, 1000, "QoS age from the interactive front");
    }

    #[test]
    fn scenario_batch_flood_cannot_starve_interactive_p99() {
        use crate::util::Histogram;
        // Deterministic sim-time scenario (the QoS acceptance pin): a
        // 200-invocation batch flood is already queued when interactive
        // work starts arriving at 1 per 4 service ticks.  The consumer
        // serves one invocation per 10 ms tick.  With the weighted lanes
        // every interactive invocation is served the tick it arrives; with
        // the lanes disabled it queues behind the entire flood.
        let run = |burst: u32| -> f64 {
            let clock = TestClock::new();
            let q = MemQueue::with_config(
                clock.clone(),
                QueueConfig { interactive_burst: burst, ..QueueConfig::default() },
            );
            for i in 0..200 {
                q.publish(pinv(&format!("b{i}"), "a", Priority::Batch, clock.now()))
                    .unwrap();
            }
            let f = TakeFilter::supporting(vec!["a".into()]);
            let mut waits = Histogram::new();
            let mut arrivals = 0;
            for t in 0..400u64 {
                if t % 4 == 0 && arrivals < 50 {
                    q.publish(pinv(
                        &format!("i{arrivals}"),
                        "a",
                        Priority::Interactive,
                        clock.now(),
                    ))
                    .unwrap();
                    arrivals += 1;
                }
                if let Some(l) = q.take(&f).unwrap() {
                    if l.invocation.spec.priority == Priority::Interactive {
                        let waited = clock
                            .now()
                            .since(l.invocation.stamps.r_start.unwrap())
                            .as_millis() as f64;
                        waits.record(waited);
                    }
                    q.ack(&l.invocation.id).unwrap();
                }
                clock.advance(Duration::from_millis(10));
            }
            assert_eq!(waits.len(), 50, "all interactive work served (burst={burst})");
            waits.p99().unwrap()
        };
        let with_lanes = run(3);
        let lanes_off = run(0);
        assert!(
            with_lanes <= 50.0,
            "interactive p99 must be flood-independent with lanes on: {with_lanes} ms"
        );
        assert!(
            lanes_off >= 1000.0,
            "control: lanes off, interactive queues behind the flood: {lanes_off} ms"
        );
    }

    #[test]
    fn interactive_flood_cannot_block_priority_pinned_batch_drain() {
        // The inverse guarantee: batch work is always reachable — a
        // batch-pinned take drains it regardless of interactive depth.
        let (_c, q) = queue();
        for i in 0..50 {
            q.publish(pinv(&format!("i{i}"), "a", Priority::Interactive, SimTime(0)))
                .unwrap();
        }
        q.publish(pinv("b0", "a", Priority::Batch, SimTime(0))).unwrap();
        let pinned = TakeFilter::supporting(vec!["a".into()])
            .for_priority(Some(Priority::Batch));
        assert_eq!(q.take(&pinned).unwrap().unwrap().invocation.id, "b0");
    }

    #[test]
    fn concurrent_takers_no_double_delivery() {
        let (_c, q) = queue();
        for i in 0..200 {
            q.publish(inv(&format!("i{i}"), "a")).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(lease) = q.take(&TakeFilter::default()).unwrap() {
                    got.push(lease.invocation.id.clone());
                    q.ack(&lease.invocation.id).unwrap();
                }
                got
            }));
        }
        let mut all: Vec<String> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 200, "every invocation delivered exactly once");
        assert_eq!(q.stats().unwrap().acked, 200);
    }

    #[test]
    fn take_timeout_parks_on_unmatched_backlog() {
        // Regression for the busy-spin: a deep queue of non-matching work
        // must park the caller (and wake it when matching work arrives),
        // not spin-probe until the deadline.
        let (_c, q) = queue();
        for i in 0..100 {
            q.publish(inv(&format!("o{i}"), "other")).unwrap();
        }
        let q2 = q.clone();
        let publisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(120));
            q2.publish(inv("match", "a")).unwrap();
        });
        let t0 = std::time::Instant::now();
        let lease = q
            .take_timeout(
                &TakeFilter::supporting(vec!["a".into()]),
                Duration::from_secs(5),
            )
            .unwrap()
            .expect("woken by the matching publish");
        assert_eq!(lease.invocation.id, "match");
        assert!(t0.elapsed() < Duration::from_secs(2));
        publisher.join().unwrap();
    }

    #[test]
    fn property_scan_never_delivers_unsupported() {
        use crate::prop;
        // Random publish/take interleavings: a node must only ever receive
        // runtimes from its filter, and warm hits only from its warm set.
        prop::check(
            "scan-respects-filter",
            60,
            |rng| {
                let runtimes: Vec<String> =
                    (0..rng.range(1, 4)).map(|i| format!("r{i}")).collect();
                let publishes: Vec<String> = (0..rng.range(0, 30))
                    .map(|_| format!("r{}", rng.below(6)))
                    .collect();
                let warm: Vec<String> =
                    (0..rng.below(3)).map(|i| format!("r{i}")).collect();
                (runtimes, publishes, warm)
            },
            |(runtimes, publishes, warm)| {
                let q = MemQueue::new(TestClock::new());
                for (i, r) in publishes.iter().enumerate() {
                    q.publish(inv(&format!("p{i}"), r)).unwrap();
                }
                let f = TakeFilter::supporting(runtimes.clone())
                    .with_warm(warm.clone());
                while let Ok(Some(lease)) = q.take(&f) {
                    let rt = &lease.invocation.spec.runtime;
                    if !runtimes.contains(rt) && !warm.contains(rt) {
                        return false;
                    }
                    if lease.warm_hit && !warm.contains(rt) {
                        return false;
                    }
                    q.ack(&lease.invocation.id).unwrap();
                }
                true
            },
        );
    }

    #[test]
    fn property_grouped_take_matches_default_and_deep_invariants() {
        use crate::prop;
        // The hand-written MemQueue::take_batch_grouped fast path must
        // stay equivalent to the trait default (built purely from the
        // property-verified take/take_batch primitives) whenever
        // `prefer_deep` is off — same ids, same order, same warm flags.
        // With `prefer_deep` on, the invariants are: one class per call,
        // FIFO within the class, warm classes win, and the chosen class
        // is a deepest matching lane.
        struct DefaultGrouped(Arc<MemQueue>);
        impl InvocationQueue for DefaultGrouped {
            fn publish(&self, i: Invocation) -> Result<()> {
                self.0.publish(i)
            }
            fn take(&self, f: &TakeFilter) -> Result<Option<Lease>> {
                self.0.take(f)
            }
            fn take_batch(&self, f: &TakeFilter, max: usize) -> Result<Vec<Lease>> {
                self.0.take_batch(f, max)
            }
            // take_batch_grouped NOT overridden: the trait default runs.
            fn ack(&self, id: &str) -> Result<()> {
                self.0.ack(id)
            }
            fn release(&self, id: &str) -> Result<()> {
                self.0.release(id)
            }
            fn reap_expired(&self) -> Result<usize> {
                self.0.reap_expired()
            }
            fn stats(&self) -> Result<QueueStats> {
                self.0.stats()
            }
        }
        prop::check(
            "grouped-take-equivalence",
            40,
            |rng| {
                let publishes: Vec<u64> =
                    (0..rng.range(0, 24)).map(|_| rng.below(5)).collect();
                let supported: Vec<u64> = (0..rng.range(1, 4)).map(|_| rng.below(6)).collect();
                let warm: Vec<u64> = (0..rng.below(3)).map(|_| rng.below(6)).collect();
                let max = rng.range(1, 6) as usize;
                (publishes, supported, warm, max)
            },
            |(publishes, supported, warm, max)| {
                let filter = TakeFilter::supporting(
                    supported.iter().map(|c| format!("r{c}")),
                )
                .with_warm(warm.iter().map(|c| format!("r{c}")));
                let fast = MemQueue::new(TestClock::new());
                let slow = DefaultGrouped(MemQueue::new(TestClock::new()));
                for (i, c) in publishes.iter().enumerate() {
                    fast.publish(inv(&format!("p{i}"), &format!("r{c}"))).unwrap();
                    slow.publish(inv(&format!("p{i}"), &format!("r{c}"))).unwrap();
                }
                // prefer_deep off: byte-for-byte equivalent delivery
                loop {
                    let a = fast.take_batch_grouped(&filter, *max).unwrap();
                    let b = slow.take_batch_grouped(&filter, *max).unwrap();
                    let sig = |ls: &[Lease]| -> Vec<(String, bool)> {
                        ls.iter()
                            .map(|l| (l.invocation.id.clone(), l.warm_hit))
                            .collect()
                    };
                    if sig(&a) != sig(&b) {
                        return false;
                    }
                    if a.is_empty() {
                        break;
                    }
                }
                // prefer_deep on: structural invariants on a fresh queue
                let deep_filter = filter.clone().preferring_deep(true);
                let q = MemQueue::new(TestClock::new());
                for (i, c) in publishes.iter().enumerate() {
                    q.publish(inv(&format!("p{i}"), &format!("r{c}"))).unwrap();
                }
                loop {
                    let before = q.stats().unwrap();
                    let depth_of = |rt: &str| {
                        before
                            .classes
                            .iter()
                            .find(|c| c.runtime == rt)
                            .map(|c| c.queued)
                            .unwrap_or(0)
                    };
                    let got = q.take_batch_grouped(&deep_filter, *max).unwrap();
                    if got.is_empty() {
                        break;
                    }
                    let rt = got[0].invocation.spec.runtime.clone();
                    // one class per call, warm flags consistent
                    if !got.iter().all(|l| l.invocation.spec.runtime == rt) {
                        return false;
                    }
                    let is_warm = deep_filter.accepts_warm(&rt);
                    if !got.iter().all(|l| l.warm_hit == is_warm) {
                        return false;
                    }
                    // deepest matching lane (warm beats cold; within the
                    // chosen tier nothing matching was deeper)
                    let tier: Vec<&String> = if is_warm {
                        deep_filter.warm.iter().collect()
                    } else {
                        deep_filter.runtimes.iter().collect()
                    };
                    let max_tier_depth =
                        tier.iter().map(|r| depth_of(r)).max().unwrap_or(0);
                    if depth_of(&rt) < max_tier_depth.min(*max) {
                        return false;
                    }
                    // count respected
                    if got.len() > *max || got.len() < depth_of(&rt).min(*max) {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn property_conservation() {
        use crate::prop;
        // queued + in_flight + acked + dead == published, at every step.
        prop::check(
            "queue-conservation",
            40,
            |rng| (0..rng.range(1, 40)).map(|_| rng.below(3)).collect::<Vec<u64>>(),
            |ops| {
                let clock = TestClock::new();
                let q = MemQueue::with_config(
                    clock.clone(),
                    QueueConfig {
                        visibility: Duration::from_secs(1),
                        max_attempts: 2,
                        ..QueueConfig::default()
                    },
                );
                let mut published = 0usize;
                for (i, op) in ops.iter().enumerate() {
                    match op {
                        0 => {
                            q.publish(inv(&format!("c{i}"), "a")).unwrap();
                            published += 1;
                        }
                        1 => {
                            if let Some(l) = q.take(&TakeFilter::default()).unwrap() {
                                q.ack(&l.invocation.id).unwrap();
                            }
                        }
                        _ => {
                            let _ = q.take(&TakeFilter::default()).unwrap();
                            clock.advance(Duration::from_secs(2));
                            q.reap_expired().unwrap();
                        }
                    }
                    let s = q.stats().unwrap();
                    if s.queued + s.in_flight + s.acked + s.dead != published {
                        return false;
                    }
                }
                true
            },
        );
    }
}
