//! Wire substrate: length-prefixed JSON frames over TCP plus a tiny
//! request/response RPC layer.
//!
//! Used by the distributed deployments of the invocation queue
//! ([`crate::queue::remote`]) and the object store
//! ([`crate::store::remote`]) — the roles Bedrock and Minio play in the
//! paper's prototype.  Frame layout: `u32 little-endian length || payload`,
//! payload is UTF-8 JSON.  Binary blobs ride base64-free as JSON arrays are
//! too slow; they use a second raw frame (see [`write_blob`]).

use crate::json::Json;
use crate::store::Blob;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on a single frame (64 MiB) — guards against corrupt length
/// prefixes taking the process down.
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Write one JSON frame (allocates a fresh serialization buffer; the RPC
/// hot paths use [`write_frame_buf`] with a reused one).
pub fn write_frame(stream: &mut impl Write, v: &Json) -> Result<()> {
    let mut scratch = String::new();
    write_frame_buf(stream, v, &mut scratch)
}

/// Write one JSON frame, serializing into `scratch` (cleared, then
/// reused) — no per-message `String` allocation on persistent
/// connections.
pub fn write_frame_buf(stream: &mut impl Write, v: &Json, scratch: &mut String) -> Result<()> {
    use std::fmt::Write as _;
    scratch.clear();
    write!(scratch, "{v}").expect("fmt to String cannot fail");
    write_blob(stream, scratch.as_bytes())
}

/// Write one raw frame (used for dataset/result payloads).  The length
/// prefix and payload go out in a single vectored write — one syscall
/// per frame instead of two, and no payload copy.
pub fn write_blob(stream: &mut impl Write, data: &[u8]) -> Result<()> {
    let len = u32::try_from(data.len()).context("frame too large")?;
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds MAX_FRAME");
    }
    let header = len.to_le_bytes();
    let total = header.len() + data.len();
    let mut written = 0usize;
    while written < total {
        let res = if written < header.len() {
            stream.write_vectored(&[IoSlice::new(&header[written..]), IoSlice::new(data)])
        } else {
            stream.write(&data[written - header.len()..])
        };
        match res {
            Ok(0) => bail!("connection closed mid-frame ({written}/{total} bytes written)"),
            Ok(n) => written += n,
            // transparent retry, as write_all did before this loop
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    stream.flush()?;
    Ok(())
}

/// Read one JSON frame.
pub fn read_frame(stream: &mut impl Read) -> Result<Json> {
    let data = read_blob(stream)?;
    let text = std::str::from_utf8(&data).context("frame is not utf-8")?;
    Json::parse(text).map_err(|e| anyhow!("bad frame json: {e}"))
}

/// Read one raw frame.
pub fn read_blob(stream: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("incoming frame of {len} bytes exceeds MAX_FRAME");
    }
    let mut data = vec![0u8; len as usize];
    stream.read_exact(&mut data)?;
    Ok(data)
}

// ---------------------------------------------------------------------------
// RPC layer
// ---------------------------------------------------------------------------

/// Handler invoked per request: `(method, params, blob)` → `(result, blob)`.
/// `blob` carries raw payload bytes when the request/response has any
/// (methods set `"blob": true` in their envelope).  The response payload
/// is a shared [`Blob`] so a handler can return a cached/stored buffer
/// straight to the socket writer without copying it.
pub type Handler =
    Arc<dyn Fn(&str, &Json, Option<Vec<u8>>) -> Result<(Json, Option<Blob>)> + Send + Sync>;

/// A TCP RPC server: one thread per connection, sequential requests per
/// connection (the node-manager clients are themselves single-threaded
/// pollers, matching the paper's one-node-manager-per-machine design).
pub struct RpcServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn serve(addr: &str, handler: Handler) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{local}"))
            .spawn(move || {
                // Exponential backoff while idle: an idle cluster runs
                // gateway + queue + store accept loops, and three threads
                // spinning at 2 ms would burn CPU for nothing.  Reset to
                // the floor on any accept so bursts stay responsive; the
                // 50 ms cap also bounds shutdown-join latency.
                const IDLE_FLOOR: Duration = Duration::from_millis(2);
                const IDLE_CAP: Duration = Duration::from_millis(50);
                let mut idle_wait = IDLE_FLOOR;
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            idle_wait = IDLE_FLOOR;
                            let h = handler.clone();
                            let stop3 = stop2.clone();
                            std::thread::spawn(move || {
                                let _ = serve_conn(stream, h, stop3);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(idle_wait);
                            idle_wait = (idle_wait * 2).min(IDLE_CAP);
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(RpcServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(mut stream: TcpStream, handler: Handler, stop: Arc<AtomicBool>) -> Result<()> {
    // Clients disable Nagle at connect; mirror it on the accept side so
    // small response frames (leases, acks) flush immediately instead of
    // waiting out a delayed-ACK round.
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    // Response-serialization buffer, reused across this connection's
    // requests.
    let mut scratch = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let req = match read_frame(&mut stream) {
            Ok(r) => r,
            Err(e) => {
                // timeouts poll the stop flag; EOF/parse errors end the conn
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        continue;
                    }
                }
                return Ok(());
            }
        };
        let method = req.str_of("method").unwrap_or("").to_string();
        let params = req.get("params").cloned().unwrap_or(Json::Null);
        let has_blob = req.get("blob").and_then(|b| b.as_bool()).unwrap_or(false);
        let blob = if has_blob {
            // blob frames follow the envelope immediately; block until read
            stream.set_read_timeout(Some(Duration::from_secs(30)))?;
            let b = read_blob(&mut stream)?;
            stream.set_read_timeout(Some(Duration::from_millis(200)))?;
            Some(b)
        } else {
            None
        };
        match handler(&method, &params, blob) {
            Ok((result, out_blob)) => {
                let resp = Json::obj()
                    .set("ok", true)
                    .set("result", result)
                    .set("blob", out_blob.is_some());
                write_frame_buf(&mut stream, &resp, &mut scratch)?;
                if let Some(b) = out_blob {
                    write_blob(&mut stream, &b)?;
                }
            }
            Err(e) => {
                let resp = Json::obj().set("ok", false).set("error", format!("{e:#}"));
                write_frame_buf(&mut stream, &resp, &mut scratch)?;
            }
        }
    }
}

/// Default client read timeout.  Generous — server-side blocking calls
/// cap their chunks at [`LONG_POLL_CHUNK`] — but finite, so a server that
/// dies mid-call surfaces a clean error instead of hanging the caller
/// forever.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Cap on one server-side blocking chunk (gateway `wait`, queue
/// long-poll).  Must stay well below [`DEFAULT_READ_TIMEOUT`] so a
/// deliberately parked RPC never looks like a dead server; clients loop
/// via [`poll_chunked`] until their own deadline.
pub const LONG_POLL_CHUNK: Duration = Duration::from_secs(10);

/// Client side of a chunked server-blocking call: issue `call(chunk_ms)`
/// until it yields a value or `timeout` elapses.  Each chunk is capped at
/// [`LONG_POLL_CHUNK`], enforcing the read-timeout invariant in one place
/// for every long-polling client (queue take, gateway wait).
pub fn poll_chunked<T>(
    timeout: Duration,
    mut call: impl FnMut(u64) -> Result<Option<T>>,
) -> Result<Option<T>> {
    let deadline = Instant::now() + timeout;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        let chunk = remaining.min(LONG_POLL_CHUNK);
        // Sub-ms budgets round UP to one server-side millisecond: the
        // wire carries whole ms, and truncating to 0 would turn a short
        // park (the micro-batch linger window) into a non-blocking
        // probe.
        let chunk_ms = if chunk.is_zero() {
            0
        } else {
            (chunk.as_millis() as u64).max(1)
        };
        if let Some(v) = call(chunk_ms)? {
            return Ok(Some(v));
        }
        if remaining <= chunk {
            return Ok(None);
        }
    }
}

/// The serialized state of one client connection: the socket plus a
/// reused request-serialization buffer (no per-call `String`).
struct ClientConn {
    stream: TcpStream,
    scratch: String,
}

/// Client side: a persistent connection issuing sequential requests.
pub struct RpcClient {
    conn: Mutex<ClientConn>,
    read_timeout: Duration,
    /// Set when a call died mid-frame: request/response framing may be
    /// desynchronized, so every later call fails fast until reconnect.
    broken: AtomicBool,
    /// Wire round trips attempted (batching assertions, diagnostics).
    calls: std::sync::atomic::AtomicU64,
}

impl RpcClient {
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<RpcClient> {
        RpcClient::connect_with_timeout(addr, DEFAULT_READ_TIMEOUT)
    }

    /// Connect with an explicit per-read timeout (tests, impatient CLIs).
    pub fn connect_with_timeout(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        read_timeout: Duration,
    ) -> Result<RpcClient> {
        let stream = TcpStream::connect(&addr).with_context(|| format!("connect {addr:?}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(read_timeout))?;
        Ok(RpcClient {
            conn: Mutex::new(ClientConn { stream, scratch: String::new() }),
            read_timeout,
            broken: AtomicBool::new(false),
            calls: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// How many RPC round trips this client has issued on the wire
    /// (fast-failed calls on a broken connection are not counted).
    pub fn calls_issued(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Issue `method(params)`; returns the result value.
    pub fn call(&self, method: &str, params: Json) -> Result<Json> {
        Ok(self.call_blob(method, params, None)?.0)
    }

    /// Issue a call that may carry / return a raw payload.
    pub fn call_blob(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>)> {
        let mut conn = self.conn.lock().expect("rpc client poisoned");
        // Checked under the lock: a caller that was blocked on the mutex
        // while another thread's call died mid-frame must not write onto
        // the now-desynchronized stream.
        if self.broken.load(Ordering::SeqCst) {
            bail!("rpc {method}: connection is broken after an earlier mid-call failure; reconnect");
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        match Self::exchange(&mut conn, method, params, blob) {
            Ok(x) => x,
            Err(e) => {
                // IO failed mid-frame (server died, network partition, or
                // no response within the read timeout): the stream can no
                // longer be trusted to be frame-aligned.
                self.broken.store(true, Ordering::SeqCst);
                let timed_out = e
                    .downcast_ref::<std::io::Error>()
                    .map(|ioe| {
                        matches!(
                            ioe.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        )
                    })
                    .unwrap_or(false);
                if timed_out {
                    Err(e.context(format!(
                        "rpc {method}: no response within {:?} — server down or unreachable",
                        self.read_timeout
                    )))
                } else {
                    Err(e.context(format!("rpc {method}: connection failed")))
                }
            }
        }
    }

    /// One request/response exchange.  Outer `Err` = transport failure
    /// (poisons the connection); inner `Result` = server-reported error
    /// (connection stays healthy).
    #[allow(clippy::type_complexity)]
    fn exchange(
        conn: &mut ClientConn,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
    ) -> Result<Result<(Json, Option<Vec<u8>>)>> {
        let req = Json::obj()
            .set("method", method)
            .set("params", params)
            .set("blob", blob.is_some());
        let stream = &mut conn.stream;
        write_frame_buf(stream, &req, &mut conn.scratch)?;
        if let Some(b) = blob {
            write_blob(stream, b)?;
        }
        let resp = read_frame(stream)?;
        if !resp.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
            return Ok(Err(anyhow!(
                "rpc {method} failed: {}",
                resp.get("error").and_then(|e| e.as_str()).unwrap_or("unknown")
            )));
        }
        let out_blob = if resp.get("blob").and_then(|b| b.as_bool()).unwrap_or(false) {
            Some(read_blob(stream)?)
        } else {
            None
        };
        Ok(Ok((resp.get("result").cloned().unwrap_or(Json::Null), out_blob)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> RpcServer {
        let handler: Handler = Arc::new(|method, params, blob| match method {
            "echo" => Ok((params.clone(), blob.map(Blob::from))),
            "add" => {
                let a = params.f64_of("a")?;
                let b = params.f64_of("b")?;
                Ok((Json::obj().set("sum", a + b), None))
            }
            "boom" => Err(anyhow!("intentional failure")),
            other => Err(anyhow!("unknown method {other}")),
        });
        RpcServer::serve("127.0.0.1:0", handler).unwrap()
    }

    #[test]
    fn roundtrip_json_call() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client
            .call("add", Json::obj().set("a", 2.0).set("b", 40.0))
            .unwrap();
        assert_eq!(out.f64_of("sum").unwrap(), 42.0);
    }

    #[test]
    fn blob_roundtrip() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        let payload = vec![7u8; 100_000];
        let (out, blob) = client
            .call_blob("echo", Json::obj().set("k", "v"), Some(&payload))
            .unwrap();
        assert_eq!(out.str_of("k").unwrap(), "v");
        assert_eq!(blob.unwrap(), payload);
    }

    #[test]
    fn error_propagates() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        let err = client.call("boom", Json::Null).unwrap_err();
        assert!(format!("{err}").contains("intentional failure"));
    }

    #[test]
    fn unknown_method_is_error_not_hang() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        assert!(client.call("nope", Json::Null).is_err());
    }

    #[test]
    fn sequential_calls_on_one_connection() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        for i in 0..50 {
            let out = client
                .call("add", Json::obj().set("a", i as f64).set("b", 1.0))
                .unwrap();
            assert_eq!(out.f64_of("sum").unwrap(), i as f64 + 1.0);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr();
        let mut handles = Vec::new();
        for t in 0..8 {
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::connect(addr).unwrap();
                for i in 0..20 {
                    let out = client
                        .call("add", Json::obj().set("a", t as f64).set("b", i as f64))
                        .unwrap();
                    assert_eq!(out.f64_of("sum").unwrap(), (t + i) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn write_blob_survives_partial_writes() {
        // A writer that accepts at most 3 bytes per call exercises every
        // resume point of the vectored header+payload write.
        struct Dribble(Vec<u8>);
        impl Write for Dribble {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                let n = buf.len().min(3);
                self.0.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let payload: Vec<u8> = (0..=255u8).collect();
        let mut w = Dribble(Vec::new());
        write_blob(&mut w, &payload).unwrap();
        let mut cursor = std::io::Cursor::new(w.0);
        assert_eq!(read_blob(&mut cursor).unwrap(), payload);
    }

    #[test]
    fn frame_size_guard() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_blob(&mut cursor).is_err());
    }

    #[test]
    fn stalled_server_times_out_cleanly() {
        // A server that accepts but never replies: the client must return
        // a clean error within its read timeout instead of blocking
        // forever (a dead gateway must not wedge every node).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (keep_tx, keep_rx) = std::sync::mpsc::channel::<()>();
        let hold = std::thread::spawn(move || {
            let conn = listener.accept().unwrap().0;
            // hold the connection open, silently, until the test is done
            let _ = keep_rx.recv_timeout(Duration::from_secs(30));
            drop(conn);
        });
        let client =
            RpcClient::connect_with_timeout(addr, Duration::from_millis(200)).unwrap();
        let t0 = std::time::Instant::now();
        let err = client.call("ping", Json::Null).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "did not hang");
        assert!(
            format!("{err:#}").contains("no response within"),
            "{err:#}"
        );
        // the connection is poisoned: later calls fail fast, no new hang
        let t1 = std::time::Instant::now();
        let err2 = client.call("ping", Json::Null).unwrap_err();
        assert!(t1.elapsed() < Duration::from_millis(50));
        assert!(format!("{err2}").contains("broken"), "{err2}");
        drop(keep_tx);
        hold.join().unwrap();
    }

    #[test]
    fn server_death_mid_call_errors_instead_of_hanging() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let killer = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            drop(conn); // server "crashes" before answering
        });
        let client = RpcClient::connect(addr).unwrap();
        let err = client.call("ping", Json::Null).unwrap_err();
        assert!(format!("{err:#}").contains("rpc ping"), "{err:#}");
        killer.join().unwrap();
    }

    #[test]
    fn server_reported_errors_do_not_poison_the_connection() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        assert!(client.call("boom", Json::Null).is_err());
        // framing stayed aligned: the next call succeeds
        let out = client
            .call("add", Json::obj().set("a", 1.0).set("b", 2.0))
            .unwrap();
        assert_eq!(out.f64_of("sum").unwrap(), 3.0);
    }

    #[test]
    fn server_shutdown_is_clean() {
        let mut server = echo_server();
        let addr = server.addr();
        server.shutdown();
        std::thread::sleep(Duration::from_millis(50));
        // New connections should fail or be ignored after shutdown.
        let r = RpcClient::connect(addr)
            .and_then(|c| c.call("add", Json::obj().set("a", 1.0).set("b", 2.0)));
        assert!(r.is_err() || r.is_ok()); // must not hang — reaching here is the test
    }
}
