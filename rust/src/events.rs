//! Event and invocation model.
//!
//! Paper §IV-B: *"an event always consists of a data set reference that
//! needs to be fetched and additional configuration for the run method"*;
//! events name a **runtime** (e.g. `tinyyolo`) and a **dataset** object and
//! are executed asynchronously with no placement guarantees.
//!
//! The measurement vocabulary follows §V-A exactly: per invocation we track
//! `RStart` (client creation), `NStart` (received by node manager),
//! `EStart`/`EEnd` (execution inside the runtime), `NEnd` (result back at
//! the node manager) and `REnd` (result at the client), and derive
//! `RLat = REnd − RStart`, `ELat = EEnd − EStart`, `DLat = EStart − RStart`.

use crate::json::{Json, JsonError};
use crate::util::SimTime;

/// QoS class of an invocation: which queue lane it rides.
///
/// `Interactive` is the default (single-invocation clients, the paper's
/// benchmark protocol); `Batch` marks bulk/offline work that must never
/// starve interactive traffic — the queue's weighted take rule
/// (`queue::mem`) and the autoscaler's per-priority watermarks both key
/// off this.  Serialized leniently: an absent field parses as
/// `Interactive`, so pre-priority peers interoperate unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Priority {
    #[default]
    Interactive,
    Batch,
}

impl Priority {
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Parse a priority name (CLI/config/wire). Unknown names error.
    pub fn parse(s: &str) -> Result<Priority, String> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            other => Err(format!("unknown priority '{other}' (expected interactive | batch)")),
        }
    }
}

/// What the user submits: runtime + dataset reference + run config.
#[derive(Debug, Clone, PartialEq)]
pub struct EventSpec {
    /// Logical runtime name (e.g. `tinyyolo`). Nodes map this onto a
    /// per-accelerator implementation variant at execution time.
    pub runtime: String,
    /// Object-store key of the primary input dataset (`datasets/...`).
    /// Always equal to `datasets[0]` — kept as its own field so the wire
    /// shape and single-input callers predating fan-in stay unchanged.
    pub dataset: String,
    /// Ordered input list.  Single-input events carry `[dataset]`;
    /// pipeline join stages carry every parent's result key in `after`
    /// order.  Serialized leniently: an absent/empty `datasets` array
    /// parses as `[dataset]`, so pre-fan-in peers interoperate.
    pub datasets: Vec<String>,
    /// Free-form run configuration (forwarded to the runtime).
    pub config: Json,
    /// QoS lane this invocation rides (default `Interactive`).
    pub priority: Priority,
}

impl EventSpec {
    pub fn new(runtime: impl Into<String>, dataset: impl Into<String>) -> EventSpec {
        let dataset = dataset.into();
        EventSpec {
            runtime: runtime.into(),
            datasets: vec![dataset.clone()],
            dataset,
            config: Json::obj(),
            priority: Priority::default(),
        }
    }

    pub fn with_config(mut self, config: Json) -> EventSpec {
        self.config = config;
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> EventSpec {
        self.priority = priority;
        self
    }

    /// Replace the input list with an ordered set of dataset keys (used
    /// by pipeline fan-in stages).  `dataset` mirrors the first entry so
    /// execution and pre-fan-in peers keep working unchanged; an empty
    /// iterator is a no-op.
    pub fn with_datasets(
        mut self,
        keys: impl IntoIterator<Item = impl Into<String>>,
    ) -> EventSpec {
        let keys: Vec<String> = keys.into_iter().map(Into::into).collect();
        if let Some(first) = keys.first() {
            self.dataset = first.clone();
            self.datasets = keys;
        }
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("runtime", self.runtime.as_str())
            .set("dataset", self.dataset.as_str())
            .set(
                "datasets",
                Json::Arr(self.datasets.iter().map(|d| Json::from(d.as_str())).collect()),
            )
            .set("config", self.config.clone())
            .set("priority", self.priority.as_str())
    }

    pub fn from_json(j: &Json) -> Result<EventSpec, JsonError> {
        // `priority` parses leniently (absent/unknown -> Interactive):
        // peers that predate the QoS lanes must interoperate.
        let priority = j
            .get("priority")
            .and_then(|v| v.as_str())
            .and_then(|s| Priority::parse(s).ok())
            .unwrap_or_default();
        let dataset = j.str_of("dataset")?.to_string();
        // `datasets` parses leniently too: absent or empty (pre-fan-in
        // peers) collapses to the single primary input.
        let datasets = j
            .get("datasets")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(String::from))
                    .collect::<Vec<_>>()
            })
            .filter(|v| !v.is_empty())
            .unwrap_or_else(|| vec![dataset.clone()]);
        Ok(EventSpec {
            runtime: j.str_of("runtime")?.to_string(),
            dataset,
            datasets,
            config: j.get("config").cloned().unwrap_or(Json::Null),
            priority,
        })
    }
}

/// Lifecycle status of an invocation.
#[derive(Debug, Clone, PartialEq)]
pub enum Status {
    /// Published to the queue, not yet taken by a node.
    Queued,
    /// Taken by a node manager, in flight.
    Running,
    /// Completed; result object persisted.
    Succeeded,
    /// Failed with a reason (also covers visibility-timeout expiry).
    Failed(String),
}

impl Status {
    pub fn as_str(&self) -> &str {
        match self {
            Status::Queued => "queued",
            Status::Running => "running",
            Status::Succeeded => "succeeded",
            Status::Failed(_) => "failed",
        }
    }
}

/// The paper's six measurement points (sim time). `None` = not reached.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Stamps {
    pub r_start: Option<SimTime>,
    pub n_start: Option<SimTime>,
    pub e_start: Option<SimTime>,
    pub e_end: Option<SimTime>,
    pub n_end: Option<SimTime>,
    pub r_end: Option<SimTime>,
}

impl Stamps {
    /// Total client-observed latency `RLat = REnd − RStart` (ms).
    pub fn rlat_ms(&self) -> Option<f64> {
        Some(diff_ms(self.r_start?, self.r_end?))
    }

    /// Execution latency inside the runtime `ELat = EEnd − EStart` (ms).
    pub fn elat_ms(&self) -> Option<f64> {
        Some(diff_ms(self.e_start?, self.e_end?))
    }

    /// Delivery delay `DLat = EStart − RStart` (ms).
    pub fn dlat_ms(&self) -> Option<f64> {
        Some(diff_ms(self.r_start?, self.e_start?))
    }

    /// Node-side overhead before execution (`EStart − NStart`, ms).
    pub fn node_overhead_ms(&self) -> Option<f64> {
        Some(diff_ms(self.n_start?, self.e_start?))
    }

    /// Queue wait (`NStart − RStart`, ms).
    pub fn queue_wait_ms(&self) -> Option<f64> {
        Some(diff_ms(self.r_start?, self.n_start?))
    }

    fn opt(t: Option<SimTime>) -> Json {
        t.map(|v| Json::from(v.as_micros())).unwrap_or(Json::Null)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("r_start", Self::opt(self.r_start))
            .set("n_start", Self::opt(self.n_start))
            .set("e_start", Self::opt(self.e_start))
            .set("e_end", Self::opt(self.e_end))
            .set("n_end", Self::opt(self.n_end))
            .set("r_end", Self::opt(self.r_end))
    }

    pub fn from_json(j: &Json) -> Stamps {
        let g = |k: &str| j.get(k).and_then(|v| v.as_u64()).map(SimTime);
        Stamps {
            r_start: g("r_start"),
            n_start: g("n_start"),
            e_start: g("e_start"),
            e_end: g("e_end"),
            n_end: g("n_end"),
            r_end: g("r_end"),
        }
    }
}

fn diff_ms(a: SimTime, b: SimTime) -> f64 {
    b.since(a).as_secs_f64() * 1e3
}

/// A submitted event moving through the system.
#[derive(Debug, Clone, PartialEq)]
pub struct Invocation {
    pub id: String,
    pub spec: EventSpec,
    pub status: Status,
    pub stamps: Stamps,
    /// Node that executed (or is executing) the invocation.
    pub node: Option<String>,
    /// Accelerator device id within the node (e.g. `gpu0`).
    pub accelerator: Option<String>,
    /// Concrete runtime implementation variant used (e.g. `tinyyolo-vpu`).
    pub variant: Option<String>,
    /// Whether execution reused a warm runtime instance.
    pub warm: bool,
    /// Object-store key of the persisted result, once succeeded.
    pub result_key: Option<String>,
    /// Cache-affinity gossip, piggybacked on the completion report
    /// (DESIGN.md §15): the reporting node's current hot-set summary —
    /// the dataset keys it holds in its local content cache.  Empty for
    /// invocations that never passed through a caching node (and on the
    /// client-facing copy, which the coordinator strips).  Serialized
    /// leniently: omitted when empty, ignored by pre-affinity peers.
    pub hot_keys: Vec<String>,
    /// Generation counter of the reporting node's cache at summary time —
    /// lets a consumer drop out-of-order summaries.  0 = no summary.
    pub hot_generation: u64,
}

impl Invocation {
    pub fn new(id: impl Into<String>, spec: EventSpec, r_start: SimTime) -> Invocation {
        Invocation {
            id: id.into(),
            spec,
            status: Status::Queued,
            stamps: Stamps { r_start: Some(r_start), ..Stamps::default() },
            node: None,
            accelerator: None,
            variant: None,
            warm: false,
            result_key: None,
            hot_keys: Vec::new(),
            hot_generation: 0,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self.status, Status::Succeeded | Status::Failed(_))
    }

    pub fn to_json(&self) -> Json {
        let status = match &self.status {
            Status::Failed(reason) => Json::obj().set("failed", reason.as_str()),
            s => Json::Str(s.as_str().to_string()),
        };
        let opt_s = |v: &Option<String>| {
            v.as_ref().map(|s| Json::from(s.as_str())).unwrap_or(Json::Null)
        };
        let mut j = Json::obj()
            .set("id", self.id.as_str())
            .set("spec", self.spec.to_json())
            .set("status", status)
            .set("stamps", self.stamps.to_json())
            .set("node", opt_s(&self.node))
            .set("accelerator", opt_s(&self.accelerator))
            .set("variant", opt_s(&self.variant))
            .set("warm", self.warm)
            .set("result_key", opt_s(&self.result_key));
        // Affinity gossip rides only when present: pre-affinity peers
        // (and every non-reporting payload) see the legacy wire shape.
        if !self.hot_keys.is_empty() {
            j = j.set(
                "hot_keys",
                Json::Arr(self.hot_keys.iter().map(|k| Json::from(k.as_str())).collect()),
            );
        }
        if self.hot_generation != 0 {
            j = j.set("hot_generation", self.hot_generation);
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<Invocation, JsonError> {
        let status = match j.req("status")? {
            Json::Str(s) => match s.as_str() {
                "queued" => Status::Queued,
                "running" => Status::Running,
                "succeeded" => Status::Succeeded,
                other => Status::Failed(format!("unknown status {other}")),
            },
            obj => Status::Failed(obj.str_of("failed").unwrap_or("unknown").to_string()),
        };
        let opt_s = |k: &str| {
            j.get(k).and_then(|v| v.as_str()).map(|s| s.to_string())
        };
        Ok(Invocation {
            id: j.str_of("id")?.to_string(),
            spec: EventSpec::from_json(j.req("spec")?)?,
            status,
            stamps: Stamps::from_json(j.req("stamps")?),
            node: opt_s("node"),
            accelerator: opt_s("accelerator"),
            variant: opt_s("variant"),
            warm: j.get("warm").and_then(|v| v.as_bool()).unwrap_or(false),
            result_key: opt_s("result_key"),
            // Lenient: pre-affinity peers never send the gossip section.
            hot_keys: j
                .get("hot_keys")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(String::from))
                        .collect()
                })
                .unwrap_or_default(),
            hot_generation: j.get("hot_generation").and_then(|v| v.as_u64()).unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64) -> SimTime {
        SimTime::from_millis(ms)
    }

    #[test]
    fn spec_roundtrip() {
        let spec = EventSpec::new("tinyyolo", "datasets/img-1")
            .with_config(Json::obj().set("threshold", 0.5));
        let back = EventSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn priority_roundtrip_and_lenient_default() {
        let spec = EventSpec::new("tinyyolo", "datasets/d").with_priority(Priority::Batch);
        let back = EventSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.priority, Priority::Batch);
        // Old-peer simulation: a spec serialized before the priority
        // field existed parses as Interactive, never errors.
        let old = Json::obj()
            .set("runtime", "tinyyolo")
            .set("dataset", "datasets/d")
            .set("config", Json::obj());
        let back = EventSpec::from_json(&old).unwrap();
        assert_eq!(back.priority, Priority::Interactive);
        // Unknown priority values degrade to the default too.
        let odd = old.set("priority", "realtime-v2");
        assert_eq!(EventSpec::from_json(&odd).unwrap().priority, Priority::Interactive);
        assert!(Priority::parse("batch").is_ok());
        assert!(Priority::parse("zzz").is_err());
    }

    #[test]
    fn datasets_list_roundtrips_and_parses_leniently() {
        // Single-input events carry the primary key as a one-entry list.
        let spec = EventSpec::new("tinyyolo", "datasets/d");
        assert_eq!(spec.datasets, vec!["datasets/d".to_string()]);
        // Fan-in: the ordered list wins and `dataset` mirrors its head.
        let spec = spec.with_datasets(["results/inv-a", "results/inv-b"]);
        assert_eq!(spec.dataset, "results/inv-a");
        assert_eq!(
            spec.datasets,
            vec!["results/inv-a".to_string(), "results/inv-b".to_string()]
        );
        let back = EventSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // An empty replacement is a no-op, never an invalid spec.
        let same = spec.clone().with_datasets(Vec::<String>::new());
        assert_eq!(same, spec);
        // Old-peer payload without a datasets array: `[dataset]`.
        let old = Json::obj()
            .set("runtime", "tinyyolo")
            .set("dataset", "datasets/d")
            .set("config", Json::obj());
        let back = EventSpec::from_json(&old).unwrap();
        assert_eq!(back.datasets, vec!["datasets/d".to_string()]);
        // An explicitly empty array degrades the same way.
        let odd = old.set("datasets", Json::Arr(Vec::new()));
        let back = EventSpec::from_json(&odd).unwrap();
        assert_eq!(back.datasets, vec!["datasets/d".to_string()]);
    }

    #[test]
    fn latency_derivations_match_paper_definitions() {
        let s = Stamps {
            r_start: Some(t(1000)),
            n_start: Some(t(1200)),
            e_start: Some(t(1250)),
            e_end: Some(t(2900)),
            n_end: Some(t(2950)),
            r_end: Some(t(3000)),
        };
        assert_eq!(s.rlat_ms(), Some(2000.0)); // REnd - RStart
        assert_eq!(s.elat_ms(), Some(1650.0)); // EEnd - EStart
        assert_eq!(s.dlat_ms(), Some(250.0)); // EStart - RStart
        assert_eq!(s.queue_wait_ms(), Some(200.0));
        assert_eq!(s.node_overhead_ms(), Some(50.0));
    }

    #[test]
    fn incomplete_stamps_yield_none() {
        let s = Stamps { r_start: Some(t(0)), ..Stamps::default() };
        assert!(s.rlat_ms().is_none());
        assert!(s.elat_ms().is_none());
        assert!(s.dlat_ms().is_none());
    }

    #[test]
    fn stamps_json_roundtrip_with_partials() {
        let s = Stamps {
            r_start: Some(t(5)),
            n_start: None,
            e_start: Some(t(9)),
            ..Stamps::default()
        };
        assert_eq!(Stamps::from_json(&s.to_json()), s);
    }

    #[test]
    fn invocation_roundtrip() {
        let mut inv = Invocation::new("inv-1", EventSpec::new("tinyyolo", "datasets/d"), t(10));
        inv.status = Status::Running;
        inv.node = Some("node-1".into());
        inv.accelerator = Some("gpu0".into());
        inv.variant = Some("tinyyolo-gpu".into());
        inv.warm = true;
        let back = Invocation::from_json(&inv.to_json()).unwrap();
        assert_eq!(back.id, "inv-1");
        assert_eq!(back.status, Status::Running);
        assert_eq!(back.node.as_deref(), Some("node-1"));
        assert!(back.warm);
    }

    #[test]
    fn hot_set_gossip_roundtrips_and_stays_off_the_legacy_wire() {
        let mut inv = Invocation::new("inv-3", EventSpec::new("r", "datasets/d"), t(0));
        // No summary: the wire shape is exactly the pre-affinity one.
        assert!(inv.to_json().get("hot_keys").is_none());
        assert!(inv.to_json().get("hot_generation").is_none());
        let back = Invocation::from_json(&inv.to_json()).unwrap();
        assert!(back.hot_keys.is_empty());
        assert_eq!(back.hot_generation, 0);
        // With a summary: roundtrips intact.
        inv.hot_keys = vec!["datasets/d".into(), "datasets/e".into()];
        inv.hot_generation = 7;
        let back = Invocation::from_json(&inv.to_json()).unwrap();
        assert_eq!(back.hot_keys, inv.hot_keys);
        assert_eq!(back.hot_generation, 7);
    }

    #[test]
    fn failed_status_preserves_reason() {
        let mut inv = Invocation::new("inv-2", EventSpec::new("r", "d"), t(0));
        inv.status = Status::Failed("artifact missing".into());
        let back = Invocation::from_json(&inv.to_json()).unwrap();
        assert_eq!(back.status, Status::Failed("artifact missing".into()));
        assert!(back.is_terminal());
    }

    #[test]
    fn terminal_classification() {
        let mut inv = Invocation::new("i", EventSpec::new("r", "d"), t(0));
        assert!(!inv.is_terminal());
        inv.status = Status::Succeeded;
        assert!(inv.is_terminal());
    }
}
