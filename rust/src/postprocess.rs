//! YOLO detection post-processing, in Rust on the request path.
//!
//! The paper's workload is tinyYOLOv2 image detection; the runtime's raw
//! output is the `[GH, GW, A*(5+C)]` grid of box logits.  Decoding
//! (sigmoid offsets, anchor scaling, class softmax) and non-maximum
//! suppression run here — the node persists decoded detections, not raw
//! logits, into the result object (matching "results must be persisted
//! elsewhere before terminating execution", §IV-A).

use crate::json::Json;

/// One decoded detection box (grid-relative units).
#[derive(Debug, Clone, PartialEq)]
pub struct Detection {
    /// Box center (grid units).
    pub cx: f32,
    pub cy: f32,
    /// Box size (grid units).
    pub w: f32,
    pub h: f32,
    /// Objectness × best-class probability.
    pub score: f32,
    pub class: usize,
}

impl Detection {
    /// Axis-aligned corners.
    pub fn corners(&self) -> (f32, f32, f32, f32) {
        (
            self.cx - self.w / 2.0,
            self.cy - self.h / 2.0,
            self.cx + self.w / 2.0,
            self.cy + self.h / 2.0,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("cx", self.cx as f64)
            .set("cy", self.cy as f64)
            .set("w", self.w as f64)
            .set("h", self.h as f64)
            .set("score", self.score as f64)
            .set("class", self.class)
    }
}

/// Intersection-over-union of two detections.
pub fn iou(a: &Detection, b: &Detection) -> f32 {
    let (ax0, ay0, ax1, ay1) = a.corners();
    let (bx0, by0, bx1, by1) = b.corners();
    let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = ix * iy;
    let union = a.w * a.h + b.w * b.h - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn softmax_argmax(logits: &[f32]) -> (usize, f32) {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    let (idx, &best) = exps
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .expect("non-empty class logits");
    (idx, best / sum)
}

/// Decoder configuration (anchors from the AOT manifest).
#[derive(Debug, Clone)]
pub struct DecodeConfig {
    pub anchors: Vec<(f32, f32)>,
    pub num_classes: usize,
    pub score_threshold: f32,
    pub iou_threshold: f32,
}

impl Default for DecodeConfig {
    fn default() -> DecodeConfig {
        // tinyYOLOv2-VOC anchors, as emitted by python/compile/aot.py.
        DecodeConfig {
            anchors: vec![
                (1.08, 1.19),
                (3.42, 4.41),
                (6.63, 11.38),
                (9.42, 5.11),
                (16.62, 10.52),
            ],
            num_classes: 20,
            score_threshold: 0.3,
            iou_threshold: 0.45,
        }
    }
}

impl DecodeConfig {
    pub fn stride(&self) -> usize {
        5 + self.num_classes
    }
}

/// Decode the raw `[gh, gw, A*(5+C)]` grid into thresholded detections.
pub fn decode_grid(grid: &[f32], gh: usize, gw: usize, cfg: &DecodeConfig) -> Vec<Detection> {
    let stride = cfg.stride();
    let per_cell = cfg.anchors.len() * stride;
    assert_eq!(
        grid.len(),
        gh * gw * per_cell,
        "grid of {} f32s does not match {gh}x{gw}x{per_cell}",
        grid.len()
    );
    let mut out = Vec::new();
    for y in 0..gh {
        for x in 0..gw {
            let cell = &grid[(y * gw + x) * per_cell..(y * gw + x + 1) * per_cell];
            for (a, &(aw, ah)) in cfg.anchors.iter().enumerate() {
                let b = &cell[a * stride..(a + 1) * stride];
                let objectness = sigmoid(b[4]);
                if objectness < cfg.score_threshold {
                    continue; // cheap early exit before softmax
                }
                let (class, class_p) = softmax_argmax(&b[5..]);
                let score = objectness * class_p;
                if score < cfg.score_threshold {
                    continue;
                }
                out.push(Detection {
                    cx: x as f32 + sigmoid(b[0]),
                    cy: y as f32 + sigmoid(b[1]),
                    w: aw * b[2].exp(),
                    h: ah * b[3].exp(),
                    score,
                    class,
                });
            }
        }
    }
    out
}

/// Greedy per-class non-maximum suppression.
pub fn nms(mut dets: Vec<Detection>, iou_threshold: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut keep: Vec<Detection> = Vec::new();
    for d in dets {
        let suppressed = keep
            .iter()
            .any(|k| k.class == d.class && iou(k, &d) > iou_threshold);
        if !suppressed {
            keep.push(d);
        }
    }
    keep
}

/// Full pipeline: raw grid → decoded, NMS-filtered detections.
pub fn postprocess(grid: &[f32], gh: usize, gw: usize, cfg: &DecodeConfig) -> Vec<Detection> {
    nms(decode_grid(grid, gh, gw, cfg), cfg.iou_threshold)
}

/// Serialize detections into the result object body.
pub fn detections_to_json(dets: &[Detection]) -> Json {
    Json::obj()
        .set("count", dets.len())
        .set("detections", Json::Arr(dets.iter().map(|d| d.to_json()).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(cx: f32, cy: f32, w: f32, h: f32, score: f32, class: usize) -> Detection {
        Detection { cx, cy, w, h, score, class }
    }

    #[test]
    fn iou_identical_is_one() {
        let a = det(1.0, 1.0, 2.0, 2.0, 0.9, 0);
        assert!((iou(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        let a = det(0.0, 0.0, 1.0, 1.0, 0.9, 0);
        let b = det(5.0, 5.0, 1.0, 1.0, 0.9, 0);
        assert_eq!(iou(&a, &b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        let a = det(0.0, 0.0, 2.0, 2.0, 0.9, 0);
        let b = det(1.0, 0.0, 2.0, 2.0, 0.9, 0);
        // inter = 1x2 = 2, union = 4+4-2 = 6
        assert!((iou(&a, &b) - 2.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn nms_suppresses_same_class_only() {
        let dets = vec![
            det(1.0, 1.0, 2.0, 2.0, 0.9, 0),
            det(1.1, 1.0, 2.0, 2.0, 0.8, 0), // overlaps class 0 -> suppressed
            det(1.1, 1.0, 2.0, 2.0, 0.7, 1), // same box, other class -> kept
        ];
        let kept = nms(dets, 0.45);
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[0].score, 0.9);
        assert_eq!(kept[1].class, 1);
    }

    #[test]
    fn nms_keeps_highest_score() {
        let dets = vec![
            det(1.0, 1.0, 2.0, 2.0, 0.5, 0),
            det(1.0, 1.0, 2.0, 2.0, 0.95, 0),
        ];
        let kept = nms(dets, 0.45);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.95);
    }

    #[test]
    fn decode_thresholds_objectness() {
        let cfg = DecodeConfig { num_classes: 2, anchors: vec![(1.0, 1.0)], ..DecodeConfig::default() };
        // one cell, one anchor, 5+2 channels: low objectness -> no boxes
        let mut grid = vec![0.0f32; 7];
        grid[4] = -10.0;
        assert!(decode_grid(&grid, 1, 1, &cfg).is_empty());
        // high objectness -> one box at the cell center-ish
        grid[4] = 10.0;
        grid[5] = 5.0; // class 0 dominates
        let dets = decode_grid(&grid, 1, 1, &cfg);
        assert_eq!(dets.len(), 1);
        assert_eq!(dets[0].class, 0);
        assert!((dets[0].cx - 0.5).abs() < 1e-5, "sigmoid(0) = 0.5 offset");
        assert!(dets[0].score > 0.9);
    }

    #[test]
    fn decode_anchor_scaling() {
        let cfg = DecodeConfig { num_classes: 1, anchors: vec![(2.0, 3.0)], score_threshold: 0.1, ..DecodeConfig::default() };
        let mut grid = vec![0.0f32; 6];
        grid[4] = 10.0;
        grid[5] = 1.0;
        let dets = decode_grid(&grid, 1, 1, &cfg);
        assert_eq!(dets.len(), 1);
        assert!((dets[0].w - 2.0).abs() < 1e-5, "exp(0) * anchor_w");
        assert!((dets[0].h - 3.0).abs() < 1e-5);
    }

    #[test]
    fn full_pipeline_on_production_shape() {
        // 2x2 grid, 5 anchors, 25 channels each = 500 f32s (the real
        // tinyyolo output shape at 64x64 input).
        let cfg = DecodeConfig::default();
        let mut grid = vec![-10.0f32; 2 * 2 * 125];
        // plant two strong overlapping detections in cell (0,0), anchor 0/1
        grid[4] = 10.0;
        grid[5] = 8.0;
        grid[25 + 4] = 9.0;
        grid[25 + 5] = 8.0;
        let dets = postprocess(&grid, 2, 2, &cfg);
        assert!(!dets.is_empty());
        // anchor 0 (1.08x1.19) and anchor 1 (3.42x4.41) barely overlap ->
        // NMS keeps both or one depending on IoU; both are same class 0.
        for d in &dets {
            assert_eq!(d.class, 0);
            assert!(d.score > 0.5);
        }
    }

    #[test]
    fn json_export_shape() {
        let dets = vec![det(1.0, 2.0, 3.0, 4.0, 0.5, 7)];
        let j = detections_to_json(&dets);
        assert_eq!(j.usize_of("count").unwrap(), 1);
        let d = &j.arr_of("detections").unwrap()[0];
        assert_eq!(d.usize_of("class").unwrap(), 7);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn decode_validates_grid_len() {
        decode_grid(&[0.0; 10], 2, 2, &DecodeConfig::default());
    }

    fn random_dets(rng: &mut crate::util::Rng, n: usize) -> Vec<Detection> {
        (0..n)
            .map(|_| Detection {
                cx: 4.0 * rng.f64() as f32,
                cy: 4.0 * rng.f64() as f32,
                w: 0.2 + 2.0 * rng.f64() as f32,
                h: 0.2 + 2.0 * rng.f64() as f32,
                score: rng.f64() as f32,
                class: rng.below(3) as usize,
            })
            .collect()
    }

    #[test]
    fn property_nms_invariants() {
        use crate::prop;
        prop::check(
            "nms-invariants",
            150,
            |rng| {
                let n = rng.below(25) as usize;
                let mut r = crate::util::Rng::new(rng.next_u64());
                random_dets(&mut r, n)
            },
            |dets| {
                let kept = nms(dets.clone(), 0.45);
                // 1. kept is a subset of the input
                let subset = kept.iter().all(|k| dets.iter().any(|d| d == k));
                // 2. sorted by descending score
                let sorted = kept.windows(2).all(|w| w[0].score >= w[1].score);
                // 3. no same-class pair above the IoU threshold survives
                let separated = kept.iter().enumerate().all(|(i, a)| {
                    kept.iter().skip(i + 1).all(|b| {
                        a.class != b.class || iou(a, b) <= 0.45
                    })
                });
                subset && sorted && separated
            },
        );
    }

    #[test]
    fn property_iou_symmetric_and_bounded() {
        use crate::prop;
        prop::check(
            "iou-bounds",
            150,
            |rng| {
                let mut r = crate::util::Rng::new(rng.next_u64());
                let d = random_dets(&mut r, 2);
                (d[0].clone(), d[1].clone())
            },
            |(a, b)| {
                let ab = iou(a, b);
                let ba = iou(b, a);
                (ab - ba).abs() < 1e-6 && (0.0..=1.0 + 1e-6).contains(&ab)
            },
        );
    }

    #[test]
    fn property_decode_respects_threshold() {
        use crate::prop;
        let cfg = DecodeConfig::default();
        prop::check(
            "decode-threshold",
            40,
            |rng| {
                let mut r = crate::util::Rng::new(rng.next_u64());
                (0..(2 * 2 * 125)).map(|_| 8.0 * (r.f64() as f32 - 0.5)).collect::<Vec<f32>>()
            },
            |grid| {
                decode_grid(grid, 2, 2, &cfg)
                    .iter()
                    .all(|d| d.score >= cfg.score_threshold && d.class < cfg.num_classes)
            },
        );
    }
}
