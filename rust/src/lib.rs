//! # HARDLESS — generalized serverless compute for hardware accelerators
//!
//! A from-scratch reproduction of *"Hardless: A Generalized Serverless
//! Compute Architecture for Hardware Processing Accelerators"* (Werner &
//! Schirmer, TU Berlin, 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution:
//!   a shared invocation queue with scan-before-take semantics
//!   ([`queue`]), an object store for runtimes/datasets/results
//!   ([`store`]), node managers driving heterogeneous accelerators
//!   ([`node`], [`accel`]), warm runtime-instance pools ([`runtime`]),
//!   and the event/measurement vocabulary of the paper's evaluation
//!   ([`events`], [`metrics`], [`workload`]).
//! * **Layer 2** — a TinyYOLOv2-shaped JAX detector (`python/compile/`),
//!   AOT-lowered to HLO text per accelerator variant.
//! * **Layer 1** — Pallas GEMM/pool kernels behind the model
//!   (`python/compile/kernels/`), tiled for an MXU-like target.
//!
//! Python never runs at request time: the [`runtime`] module loads the AOT
//! artifacts through the PJRT C API and executes them from the node
//! managers' worker threads.
//!
//! ## The client surface
//!
//! All user interaction goes through [`api::HardlessClient`] — one
//! submit/status/wait/fetch trait with two transports:
//!
//! * **local** — the trait is implemented on [`coordinator::Cluster`]
//!   (examples, benches, tests);
//! * **remote** — [`api::RemoteClient`] speaks TCP to the
//!   [`api::GatewayServer`] started by `hardless serve`, which hosts the
//!   coordinator server-side: it publishes to the shared queue, receives
//!   node completion reports over RPC, stamps `REnd`, and feeds the
//!   metrics hub.
//!
//! Deployment walkthrough (`serve` → `node` → `submit`):
//!
//! ```text
//! hardless serve                         # gateway + queue + store
//! hardless node --engine mock            # worker node joins
//! hardless submit --dataset datasets/x --wait   # submit, await result
//! hardless status                        # cluster counters
//! ```
//!
//! Publishing raw invocations straight into the queue is deprecated for
//! user code: only the gateway/coordinator stamps `RStart`/`REnd` and
//! tracks completion, so direct-queue events are invisible to `status`,
//! `wait`, and the metrics pipeline.
//!
//! See `DESIGN.md` for the system inventory, the gateway API, and the
//! experiment index, and `EXPERIMENTS.md` for reproduced results.

pub mod api;
pub mod autoscale;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod events;
pub mod metrics;
pub mod node;
pub mod pipeline;
pub mod postprocess;
pub mod json;
pub mod prop;
pub mod accel;
pub mod queue;
pub mod scheduler;
pub mod runtime;
pub mod store;
pub mod util;
pub mod workload;
pub mod wire;
