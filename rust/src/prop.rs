//! Minimal property-based testing harness (proptest is unavailable in this
//! offline build).
//!
//! Generators are plain closures over [`Rng`]; [`check`] runs a property
//! over `n` random cases and, on failure, performs a bounded greedy shrink
//! using a caller-provided shrinker before panicking with the seed and the
//! minimized counterexample.
//!
//! ```ignore
//! prop::check("sorted-idempotent", 200, gen_vec_u32(0..100), |v| {
//!     let mut a = v.clone();
//!     a.sort();
//!     let mut b = a.clone();
//!     b.sort();
//!     a == b
//! });
//! ```

use crate::util::Rng;
use std::fmt::Debug;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Config {
        // Seed can be pinned via env to replay a failure.
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 100, seed, max_shrink_steps: 500 }
    }
}

/// Run `prop` over `cases` random inputs from `gen`; panic with the seed
/// and case index on the first failure (no shrinking).
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let cfg = Config { cases, ..Config::default() };
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n{input:#?}",
                seed = cfg.seed
            );
        }
    }
}

/// Like [`check`] but additionally shrinks the failing input with
/// `shrink` (returns candidate simplifications, tried greedily).
pub fn check_shrink<T, G, P, S>(name: &str, cases: usize, mut gen: G, mut prop: P, shrink: S)
where
    T: Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
    S: Fn(&T) -> Vec<T>,
{
    let cfg = Config { cases, ..Config::default() };
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Greedy shrink: keep applying the first failing simplification.
        let mut smallest = input.clone();
        let mut steps = 0;
        'outer: while steps < cfg.max_shrink_steps {
            for cand in shrink(&smallest) {
                steps += 1;
                if !prop(&cand) {
                    smallest = cand;
                    continue 'outer;
                }
                if steps >= cfg.max_shrink_steps {
                    break;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed (case {case}, seed {seed}).\n\
             original: {input:#?}\nshrunk:   {smallest:#?}",
            seed = cfg.seed
        );
    }
}

// ---------------------------------------------------------------------------
// Common generators / shrinkers
// ---------------------------------------------------------------------------

/// Generator: `Vec<u64>` with length in `0..=max_len`, elements `< max_val`.
pub fn gen_vec_u64(max_len: usize, max_val: u64) -> impl FnMut(&mut Rng) -> Vec<u64> {
    move |rng| {
        let len = rng.below(max_len as u64 + 1) as usize;
        (0..len).map(|_| rng.below(max_val)).collect()
    }
}

/// Standard vector shrinker: drop halves, drop single elements, halve values.
pub fn shrink_vec_u64(v: &Vec<u64>) -> Vec<Vec<u64>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    for i in 0..v.len().min(8) {
        let mut c = v.clone();
        c.remove(i);
        out.push(c);
    }
    let halved: Vec<u64> = v.iter().map(|x| x / 2).collect();
    if &halved != v {
        out.push(halved);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut seen = 0;
        check("count", 50, |r| r.below(10), |_| {
            seen += 1;
            true
        });
        assert_eq!(seen, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_name() {
        check("fails", 10, |r| r.below(10), |&v| v > 100);
    }

    #[test]
    fn shrinker_minimizes() {
        // Property "no element >= 50" fails; shrinking should find a small
        // counterexample. We capture the panic message.
        let result = std::panic::catch_unwind(|| {
            check_shrink(
                "shrinks",
                200,
                gen_vec_u64(20, 100),
                |v| v.iter().all(|&x| x < 50),
                shrink_vec_u64,
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk:"), "{msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first: Vec<u64> = Vec::new();
        check("record", 5, |r| r.below(1000), |&v| {
            first.push(v);
            true
        });
        let mut second: Vec<u64> = Vec::new();
        check("record", 5, |r| r.below(1000), |&v| {
            second.push(v);
            true
        });
        assert_eq!(first, second);
    }
}
