//! `hardless` — the HARDLESS leader/CLI binary.
//!
//! Distributed deployments are gateway-centric (serve → node → submit):
//!
//!   run        — run a full experiment (preset or config file), print the
//!                paper-style summary, write CSVs
//!   figures    — regenerate the paper's Fig. 3 + Fig. 4 and text tables
//!   serve      — start the gateway + shared queue + object store
//!   node       — start a worker node against a running `serve`
//!   submit     — submit one event through the gateway (`--wait` blocks
//!                for the result and prints latencies)
//!   status     — one invocation's lifecycle, or the cluster counters
//!   inspect    — print artifact/bundle information
//!
//! Publishing invocations straight into the queue (the pre-gateway
//! `submit`) is deprecated: only the gateway stamps `RStart`/`REnd` and
//! tracks status, so direct-queue events are invisible to `status`,
//! `wait`, and the metrics pipeline.

use hardless::api::{
    GatewayConfig, GatewayServer, HardlessClient, RemoteClient, RemoteReporter,
    SubmissionStatus,
};
use hardless::bench::{self, Engine};
use hardless::cli::{App, Command};
use hardless::config::Config;
use hardless::events::EventSpec;
use hardless::json::Json;
use hardless::runtime::{artifacts_dir, RuntimeBundle};
use std::time::Duration;

const DEFAULT_GATEWAY: &str = "127.0.0.1:7400";

fn app() -> App {
    App::new("hardless", "generalized serverless compute for hardware accelerators")
        .command(
            Command::new("run", "run one experiment end-to-end")
                .opt("config", "paper-all", "preset (paper-dualgpu | paper-all) or JSON config path")
                .opt("engine", "pjrt", "pjrt | mock")
                .opt("out", "bench_out", "CSV output directory")
                .opt("name", "run", "experiment name for output files"),
        )
        .command(
            Command::new("figures", "regenerate the paper's Fig. 3 and Fig. 4")
                .opt("engine", "pjrt", "pjrt | mock")
                .opt("out", "bench_out", "CSV output directory"),
        )
        .command(
            Command::new("serve", "serve the gateway + shared queue + object store over TCP")
                .opt("gateway-addr", DEFAULT_GATEWAY, "gateway (client API) bind address")
                .opt("queue-addr", "127.0.0.1:7401", "queue bind address")
                .opt("queue-shards", "1", "queue shard count: >1 serves an M-way sharded queue with rendezvous-hashed class lanes (1 = single indexed engine)")
                .opt("store-addr", "127.0.0.1:7402", "store bind address")
                .opt("store-dir", "", "object store directory (empty = in-memory)")
                .opt("runtimes", "tinyyolo", "comma-separated runtimes to announce")
                .opt("rpc-workers", "4", "bounded RPC handler pool size per server (reactor backends)")
                .opt("rpc-backend", "auto", "RPC transport: auto | epoll | uring | threaded (uring falls back to epoll if the kernel probe fails)")
                .flag("autoscale", "run the elasticity controller (advisory: decisions are logged and surfaced in `hardless status`; node provisioning stays external)")
                .opt("autoscale-min", "0", "warm floor (scale-in never goes below this many nodes)")
                .opt("autoscale-max", "8", "fleet ceiling")
                .opt("autoscale-up-depth", "4", "scale out when a runtime class queues more than this per node")
                .opt("autoscale-up-oldest-ms", "10000", "...or when a class's oldest queued event has waited this long")
                .opt("autoscale-idle-ms", "30000", "scale in one node after the system has been empty this long")
                .opt("autoscale-cooldown-up-ms", "15000", "minimum spacing between scale-outs")
                .opt("autoscale-cooldown-down-ms", "60000", "minimum spacing between a scale-in and the last action"),
        )
        .command(
            Command::new("node", "run a worker node against a running `serve`")
                .opt("queue-addr", "127.0.0.1:7401", "queue address")
                .opt("store-addr", "127.0.0.1:7402", "store address")
                .opt("gateway-addr", DEFAULT_GATEWAY, "gateway address for completion reporting (empty = node-local only)")
                .opt("devices", "paper-all", "device preset: paper-dualgpu | paper-all")
                .opt("id", "node-1", "node id")
                .opt("policy", "warm-first", "warm-first | fifo | deadline:<ms> | priority:interactive | priority:batch | affinity[:<inner>]")
                .opt("engine", "pjrt", "pjrt | mock (mock needs no artifacts)")
                .opt("duration-s", "30", "how long to serve before draining")
                .opt("node-cache-mb", "256", "per-cache MiB budget for the node's raw-object and decoded-input caches (worst-case memory 2x this; 0 = disabled)")
                .opt("max-batch", "8", "device micro-batch cap: same-runtime invocations coalesced into one accelerator dispatch (1 = serial execution)")
                .opt("max-linger-ms", "5", "adaptive linger ceiling in ms: how long a forming batch may wait for more same-runtime work (scaled down automatically at low load; 0 = never wait)"),
        )
        .command(
            Command::new("submit", "submit one event through the gateway")
                .opt("gateway-addr", DEFAULT_GATEWAY, "gateway address")
                .opt("runtime", "tinyyolo", "logical runtime name")
                .opt("priority", "interactive", "QoS lane: interactive | batch")
                .opt("timeout-s", "120", "wait timeout (with --wait)")
                .flag("wait", "block until the result arrives; print latencies")
                .req("dataset", "dataset object key"),
        )
        .command(
            Command::new("status", "inspect one invocation or the whole cluster")
                .opt("gateway-addr", DEFAULT_GATEWAY, "gateway address")
                .opt("id", "", "invocation id (empty = cluster stats + runtimes)"),
        )
        .command(
            Command::new("pipeline", "submit or inspect a multi-stage invocation pipeline")
                .pos("action", "submit | status")
                .opt("gateway-addr", DEFAULT_GATEWAY, "gateway address")
                .opt("stages", "", "comma-separated stages as name:runtime[:parent+parent], e.g. 'decode:tinyyolo,post:tinyyolo:decode' (submit)")
                .opt("dataset", "", "input dataset key for the root stages (submit)")
                .opt("priority", "interactive", "QoS lane for every stage: interactive | batch (submit)")
                .opt("id", "", "pipeline id (status)")
                .opt("timeout-s", "120", "wait timeout in seconds (with --wait)")
                .flag("wait", "block until the pipeline is terminal; print the stage table"),
        )
        .command(
            Command::new("inspect", "print AOT bundle information")
                .opt("artifacts", "", "artifacts dir (default: ./artifacts or $HARDLESS_ARTIFACTS)"),
        )
}

fn main() {
    hardless::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, m) = match app().parse(&argv) {
        Ok(x) => x,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(if msg.contains("usage:") { 0 } else { 2 });
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&m),
        "figures" => cmd_figures(&m),
        "serve" => cmd_serve(&m),
        "node" => cmd_node(&m),
        "submit" => cmd_submit(&m),
        "status" => cmd_status(&m),
        "pipeline" => cmd_pipeline(&m),
        "inspect" => cmd_inspect(&m),
        other => {
            eprintln!("unhandled command {other}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_engine(m: &hardless::cli::Matches) -> anyhow::Result<Engine> {
    match m.str_req("engine") {
        "pjrt" => Ok(Engine::Pjrt),
        "mock" => Ok(Engine::Mock),
        other => anyhow::bail!("unknown engine '{other}' (pjrt | mock)"),
    }
}

fn cmd_run(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let cfg = Config::load(m.str_req("config"))?;
    let engine = parse_engine(m)?;
    let result = bench::run_experiment(m.str_req("name"), &cfg, engine)?;
    result.write_csvs(m.str_req("out"))?;
    print!("{}", result.summary_text());
    println!("CSVs written to {}/", m.str_req("out"));
    Ok(())
}

fn cmd_figures(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let engine = parse_engine(m)?;
    let out = m.str_req("out");
    let fig3 = bench::fig3_dualgpu(engine)?;
    fig3.write_csvs(out)?;
    print!("{}", fig3.summary_text());
    let fig4 = bench::fig4_allaccel(engine)?;
    fig4.write_csvs(out)?;
    print!("{}", fig4.summary_text());
    println!("\n== paper comparison ==");
    println!(
        "max RFast  dual-GPU: {:.2}/s   all-accel: {:.2}/s   delta: +{:.2}/s",
        fig3.rfast_max,
        fig4.rfast_max,
        fig4.rfast_max - fig3.rfast_max
    );
    println!("(paper: ~3/s -> ~4/s, delta ~ +0.75..1; shape criterion: all-accel > dual-GPU by ~slot ratio)");
    for (kind, med) in fig4.median_elat_by_kind() {
        println!("median ELat [{kind}]: {med:.0} ms (paper: gpu 1675 ms, vpu 1577 ms)");
    }
    Ok(())
}

fn cmd_serve(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    use hardless::queue::{InvocationQueue, MemQueue, QueueServer, ShardedQueue};
    use hardless::store::{FsStore, MemStore, ObjectStore, StoreServer};
    use hardless::util::clock::ScaledClock;
    use std::sync::Arc;

    let clock = ScaledClock::realtime();
    let shards: usize = m.parse_num("queue-shards").map_err(|e| anyhow::anyhow!(e))?;
    // Shard count 1 keeps the single indexed engine (no per-shard stats
    // section on the wire); >1 partitions the runtime classes over M
    // independently-locked engines via rendezvous hashing (DESIGN.md §13).
    let queue: Arc<dyn InvocationQueue> = if shards <= 1 {
        MemQueue::new(clock.clone())
    } else {
        ShardedQueue::new(clock.clone(), shards)
    };
    let store: Arc<dyn ObjectStore> = match m.str_req("store-dir") {
        "" => Arc::new(MemStore::new()),
        dir => Arc::new(FsStore::open(dir)?),
    };
    let announce: Vec<String> = m
        .str_req("runtimes")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    let autoscale = if m.flag("autoscale") {
        let ms = |name: &str| -> anyhow::Result<Duration> {
            Ok(Duration::from_millis(
                m.parse_num::<u64>(name).map_err(|e| anyhow::anyhow!(e))?,
            ))
        };
        let cfg = hardless::autoscale::AutoscaleConfig {
            min_nodes: m.parse_num("autoscale-min").map_err(|e| anyhow::anyhow!(e))?,
            max_nodes: m.parse_num("autoscale-max").map_err(|e| anyhow::anyhow!(e))?,
            up_depth_per_node: m
                .parse_num("autoscale-up-depth")
                .map_err(|e| anyhow::anyhow!(e))?,
            up_oldest: ms("autoscale-up-oldest-ms")?,
            down_idle: ms("autoscale-idle-ms")?,
            cooldown_up: ms("autoscale-cooldown-up-ms")?,
            cooldown_down: ms("autoscale-cooldown-down-ms")?,
            ..hardless::autoscale::AutoscaleConfig::default()
        };
        if cfg.min_nodes > cfg.max_nodes {
            anyhow::bail!(
                "--autoscale-min {} exceeds --autoscale-max {}",
                cfg.min_nodes,
                cfg.max_nodes
            );
        }
        Some(cfg)
    } else {
        None
    };
    let rpc = hardless::wire::RpcConfig {
        backend: m.str_req("rpc-backend").parse()?,
        workers: m.parse_num("rpc-workers").map_err(|e| anyhow::anyhow!(e))?,
        ..hardless::wire::RpcConfig::default()
    };
    let qs = QueueServer::serve_with(m.str_req("queue-addr"), queue.clone(), rpc.clone())?;
    let ss = StoreServer::serve_with(m.str_req("store-addr"), store.clone(), rpc.clone())?;
    let gw = GatewayServer::serve(
        m.str_req("gateway-addr"),
        queue.clone(),
        store,
        clock,
        GatewayConfig {
            announce_runtimes: announce,
            autoscale: autoscale.clone(),
            rpc: rpc.clone(),
            ..GatewayConfig::default()
        },
    )?;
    if let Some(cfg) = &autoscale {
        println!(
            "autoscale (advisory): nodes {}..{}, up at depth>{}/node or oldest>={}ms, in after {}ms idle",
            cfg.min_nodes,
            cfg.max_nodes,
            cfg.up_depth_per_node,
            cfg.up_oldest.as_millis(),
            cfg.down_idle.as_millis()
        );
    }
    println!("gateway listening on {}  (submit/status/wait/results)", gw.addr());
    if shards > 1 {
        println!("queue   listening on {}  ({} shards, node managers take work here)", qs.addr(), shards);
    } else {
        println!("queue   listening on {}  (node managers take work here)", qs.addr());
    }
    println!("store   listening on {}  (datasets, bundles, results)", ss.addr());
    println!("start nodes (`hardless node`), then submit (`hardless submit --wait`); ctrl-c to stop");
    loop {
        std::thread::sleep(Duration::from_secs(30));
        let counts = gw.coordinator().counts();
        if counts.submitted > 0 {
            let q = queue.stats()?;
            log::info!(
                "gateway: submitted {} | inflight {} | completed {} | queued {}",
                counts.submitted,
                counts.inflight,
                counts.completed,
                q.queued
            );
        }
    }
}

fn cmd_node(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    use hardless::accel::{paper_all_accel, paper_dualgpu};
    use hardless::node::{
        spawn_node, CompletionSink, InstanceReserve, NodeConfig, NodeDeps, TeeSink,
    };
    use hardless::queue::QueueClient;
    use hardless::runtime::{instance::MockExecutor, RuntimeInstance};
    use hardless::scheduler::parse_policy;
    use hardless::store::StoreClient;
    use hardless::util::clock::ScaledClock;
    use std::sync::{mpsc, Arc};

    let registry = match m.str_req("devices") {
        "paper-dualgpu" => paper_dualgpu(),
        "paper-all" => paper_all_accel(),
        other => anyhow::bail!("unknown device preset '{other}'"),
    };
    let queue = Arc::new(QueueClient::connect(m.str_req("queue-addr"))?);
    let store = Arc::new(StoreClient::connect(m.str_req("store-addr"))?);
    let clock = ScaledClock::realtime();

    let reserve = InstanceReserve::new();
    match parse_engine(m)? {
        Engine::Pjrt => {
            // Fetch the runtime bundle from the store and prewarm
            // executors — what the paper's node manager does at join time.
            let bundle = RuntimeBundle::fetch("tinyyolo", store.as_ref())
                .or_else(|_| RuntimeBundle::load_dir("tinyyolo", artifacts_dir()))?;
            let built = reserve.prewarm_pjrt(&registry, &bundle)?;
            println!("node {}: prewarmed {built} PJRT instances", m.str_req("id"));
        }
        Engine::Mock => {
            for d in registry.devices() {
                for variant in d.profile.runtimes.values() {
                    for _ in 0..d.profile.slots {
                        reserve.add(RuntimeInstance::start(
                            variant.clone(),
                            d.id.clone(),
                            MockExecutor::factory(1.0, Duration::from_millis(1)),
                        )?);
                    }
                }
            }
            println!(
                "node {}: mock engine, {} instances reserved",
                m.str_req("id"),
                reserve.total()
            );
        }
    }

    // Completion reporting: to the gateway over RPC (so REnd is stamped
    // and `hardless status` sees the completion) plus a local channel for
    // the progress printout below.
    let (tx, rx) = mpsc::channel();
    let gateway_addr = m.str_req("gateway-addr");
    let completions: Arc<dyn CompletionSink> = if gateway_addr.is_empty() {
        println!("no gateway configured; completions stay node-local");
        Arc::new(tx)
    } else {
        match RemoteReporter::connect(gateway_addr) {
            Ok(reporter) => {
                println!("reporting completions to gateway {gateway_addr}");
                Arc::new(TeeSink(vec![Arc::new(reporter), Arc::new(tx)]))
            }
            Err(e) => {
                eprintln!(
                    "warning: gateway {gateway_addr} unreachable ({e:#}); completions stay node-local"
                );
                Arc::new(tx)
            }
        }
    };

    let deps = NodeDeps {
        queue,
        store,
        clock,
        policy: parse_policy(m.str_req("policy"))?,
        reserve,
        completions,
    };
    // Node-local content cache: repeated dataset fetches are served from
    // memory instead of re-crossing the store TCP link per invocation.
    let cache_mb: usize = m.parse_num("node-cache-mb").map_err(|e| anyhow::anyhow!(e))?;
    let mut cfg = NodeConfig::new(m.str_req("id"));
    cfg.cache_bytes = cache_mb * 1024 * 1024;
    // Micro-batching: N same-runtime invocations per device dispatch,
    // with an adaptive linger window (DESIGN.md §11).
    cfg.batch = hardless::node::BatchConfig {
        max_batch: m.parse_num("max-batch").map_err(|e| anyhow::anyhow!(e))?,
        max_linger: Duration::from_millis(
            m.parse_num("max-linger-ms").map_err(|e| anyhow::anyhow!(e))?,
        ),
        ..hardless::node::BatchConfig::default()
    };
    if cfg.batch.max_batch == 0 {
        anyhow::bail!("--max-batch must be >= 1");
    }
    let node = spawn_node(cfg, registry, deps)?;
    let secs: u64 = m.parse_num("duration-s").map_err(|e| anyhow::anyhow!(e))?;
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    let mut served = 0usize;
    while std::time::Instant::now() < deadline {
        if let Ok(inv) = rx.recv_timeout(Duration::from_millis(200)) {
            // Gossip-only report (idle hot-set refresh, empty id): the
            // gateway tee already folded it; nothing was served.
            if inv.id.is_empty() {
                continue;
            }
            served += 1;
            println!(
                "completed {} on {} ({}) ELat {:.0} ms",
                inv.id,
                inv.accelerator.as_deref().unwrap_or("-"),
                if inv.warm { "warm" } else { "cold" },
                inv.stamps.elat_ms().unwrap_or(f64::NAN)
            );
        }
    }
    let cache = node.cache_stats();
    let batch = node.batch_stats();
    node.stop();
    println!(
        "node served {served} invocations (store cache: {} hits, {} misses, {} coalesced, {} evictions), exiting",
        cache.hits, cache.misses, cache.coalesced, cache.evictions
    );
    for b in batch {
        println!(
            "  batch [{}]: {} invocations in {} dispatches / {} device programs (mean {:.1}, {} full, {} lingered, {} pad slots)",
            b.variant,
            b.invocations,
            b.batches,
            b.device_programs,
            b.mean_size(),
            b.full,
            b.lingered,
            b.pad_slots
        );
    }
    Ok(())
}

fn cmd_submit(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let gateway_addr = m.str_req("gateway-addr");
    let client = RemoteClient::connect(gateway_addr)?;
    let priority = hardless::events::Priority::parse(m.str_req("priority"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let id = client.submit(
        EventSpec::new(m.str_req("runtime"), m.str_req("dataset")).with_priority(priority),
    )?;
    println!("submitted {id} via gateway {gateway_addr}");
    if !m.flag("wait") {
        println!("poll with: hardless status --id {id}");
        return Ok(());
    }
    let timeout_s: u64 = m.parse_num("timeout-s").map_err(|e| anyhow::anyhow!(e))?;
    let Some(inv) = client.wait(&id, Duration::from_secs(timeout_s))? else {
        anyhow::bail!("{id} not terminal after {timeout_s}s (still queued or running)");
    };
    println!("status:      {:?}", inv.status);
    println!("node:        {}", inv.node.as_deref().unwrap_or("-"));
    println!("accelerator: {}", inv.accelerator.as_deref().unwrap_or("-"));
    println!("variant:     {}", inv.variant.as_deref().unwrap_or("-"));
    println!("warm start:  {}", inv.warm);
    println!(
        "RLat: {:.0} ms | ELat: {:.0} ms | DLat: {:.0} ms",
        inv.stamps.rlat_ms().unwrap_or(f64::NAN),
        inv.stamps.elat_ms().unwrap_or(f64::NAN),
        inv.stamps.dlat_ms().unwrap_or(f64::NAN)
    );
    if let Some(body) = client.fetch_result(&id)? {
        match std::str::from_utf8(&body) {
            Ok(text) if text.starts_with('{') || text.starts_with('[') => {
                println!("result ({} bytes): {text}", body.len())
            }
            _ => println!("result: {} bytes (binary)", body.len()),
        }
    }
    Ok(())
}

fn cmd_status(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let client = RemoteClient::connect(m.str_req("gateway-addr"))?;
    match m.str_req("id") {
        "" => {
            let stats = client.cluster_stats()?;
            let out = stats.to_json().set(
                "runtimes",
                Json::Arr(
                    client
                        .list_runtimes()?
                        .into_iter()
                        .map(Json::Str)
                        .collect(),
                ),
            );
            println!("{}", out.to_pretty());
            if !stats.rpc.backend.is_empty() {
                println!(
                    "rpc: {} backend | {} conns ({} parked) | {} busy of {} workers | {} requests ({} saturated)",
                    stats.rpc.backend,
                    stats.rpc.conns_active,
                    stats.rpc.parked,
                    stats.rpc.worker_busy,
                    stats.rpc.workers,
                    stats.rpc.requests,
                    stats.rpc.saturated
                );
            }
        }
        id => match client.status(id)? {
            SubmissionStatus::Unknown => println!("{id}: unknown to this gateway"),
            SubmissionStatus::Expired => println!(
                "{id}: expired (completed, but evicted from the tracking window; \
                 its result object has been garbage-collected)"
            ),
            SubmissionStatus::InFlight => println!("{id}: in flight (queued or running)"),
            SubmissionStatus::Done(inv) => println!("{}", inv.to_json().to_pretty()),
        },
    }
    Ok(())
}

/// One `--stages` element: `name:runtime[:parent+parent]`.
fn parse_stage(part: &str) -> anyhow::Result<hardless::pipeline::StageSpec> {
    let fields: Vec<&str> = part.split(':').collect();
    let stage = match fields.as_slice() {
        [name, runtime] => hardless::pipeline::StageSpec::new(*name, *runtime),
        [name, runtime, parents] => hardless::pipeline::StageSpec::new(*name, *runtime)
            .after(parents.split('+').map(str::trim).filter(|p| !p.is_empty())),
        _ => anyhow::bail!(
            "bad stage '{part}' (expected name:runtime or name:runtime:parent+parent)"
        ),
    };
    Ok(stage)
}

fn cmd_pipeline(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    use hardless::pipeline::{PipelineSpec, PipelineState};
    let client = RemoteClient::connect(m.str_req("gateway-addr"))?;
    match m.pos("action") {
        Some("submit") => {
            let dataset = m.str_req("dataset");
            if dataset.is_empty() {
                anyhow::bail!("--dataset is required for pipeline submit");
            }
            let priority = hardless::events::Priority::parse(m.str_req("priority"))
                .map_err(|e| anyhow::anyhow!(e))?;
            let mut spec = PipelineSpec::new(dataset).with_priority(priority);
            for part in m
                .str_req("stages")
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
            {
                spec = spec.stage(parse_stage(part)?);
            }
            // Validate client-side so a malformed DAG fails before the RPC.
            spec.validate()?;
            let id = client.submit_pipeline(spec)?;
            println!("submitted pipeline {id}");
            if !m.flag("wait") {
                println!("poll with: hardless pipeline status --id {id}");
                return Ok(());
            }
            let timeout_s: u64 = m.parse_num("timeout-s").map_err(|e| anyhow::anyhow!(e))?;
            let deadline = std::time::Instant::now() + Duration::from_secs(timeout_s);
            loop {
                let st = client
                    .pipeline_status(&id)?
                    .ok_or_else(|| anyhow::anyhow!("{id} vanished from the gateway"))?;
                if st.state != PipelineState::Running {
                    println!("{}", st.describe());
                    if st.state == PipelineState::PartialFailure {
                        anyhow::bail!("pipeline {id} finished with failed stages");
                    }
                    return Ok(());
                }
                if std::time::Instant::now() >= deadline {
                    println!("{}", st.describe());
                    anyhow::bail!("{id} not terminal after {timeout_s}s");
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        }
        Some("status") => {
            let id = m.str_req("id");
            if id.is_empty() {
                anyhow::bail!("--id is required for pipeline status");
            }
            match client.pipeline_status(id)? {
                Some(st) => println!("{}", st.describe()),
                None => println!("{id}: unknown to this gateway"),
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown pipeline action {:?} (expected submit | status)",
            other.unwrap_or("")
        ),
    }
}

fn cmd_inspect(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let dir = match m.str_req("artifacts") {
        "" => artifacts_dir(),
        d => d.into(),
    };
    let bundle = RuntimeBundle::load_dir("tinyyolo", &dir)?;
    let mut out = Json::obj()
        .set("bundle", bundle.name.as_str())
        .set("weights", bundle.weights.len())
        .set("weight_bytes", bundle.weight_blob.len());
    let mut arts = Vec::new();
    for a in &bundle.artifacts {
        arts.push(
            Json::obj()
                .set("name", a.name.as_str())
                .set("input", Json::from(&a.input_shape[..]))
                .set("output", Json::from(&a.output_shape[..]))
                .set("dtype", a.compute_dtype.as_str())
                .set("hlo_bytes", bundle.hlo_text(&a.name)?.len()),
        );
    }
    out = out.set("artifacts", Json::Arr(arts));
    println!("{}", out.to_pretty());
    Ok(())
}
