//! `hardless` — the HARDLESS leader/CLI binary.
//!
//! Subcommands:
//!   run        — run a full experiment (preset or config file), print the
//!                paper-style summary, write CSVs
//!   figures    — regenerate the paper's Fig. 3 + Fig. 4 and text tables
//!   serve      — start queue + store TCP services (distributed deployment)
//!   node       — start a worker node against remote queue/store services
//!   submit     — publish one event to a remote queue
//!   inspect    — print artifact/bundle information

use hardless::bench::{self, Engine};
use hardless::cli::{App, Command};
use hardless::config::Config;
use hardless::json::Json;
use hardless::runtime::{artifacts_dir, RuntimeBundle};
use std::time::Duration;

fn app() -> App {
    App::new("hardless", "generalized serverless compute for hardware accelerators")
        .command(
            Command::new("run", "run one experiment end-to-end")
                .opt("config", "paper-all", "preset (paper-dualgpu | paper-all) or JSON config path")
                .opt("engine", "pjrt", "pjrt | mock")
                .opt("out", "bench_out", "CSV output directory")
                .opt("name", "run", "experiment name for output files"),
        )
        .command(
            Command::new("figures", "regenerate the paper's Fig. 3 and Fig. 4")
                .opt("engine", "pjrt", "pjrt | mock")
                .opt("out", "bench_out", "CSV output directory"),
        )
        .command(
            Command::new("serve", "serve the shared queue + object store over TCP")
                .opt("queue-addr", "127.0.0.1:7401", "queue bind address")
                .opt("store-addr", "127.0.0.1:7402", "store bind address")
                .opt("store-dir", "", "object store directory (empty = in-memory)"),
        )
        .command(
            Command::new("node", "run a worker node against remote services")
                .opt("queue-addr", "127.0.0.1:7401", "queue address")
                .opt("store-addr", "127.0.0.1:7402", "store address")
                .opt("devices", "paper-all", "device preset: paper-dualgpu | paper-all")
                .opt("id", "node-1", "node id")
                .opt("policy", "warm-first", "warm-first | fifo | deadline:<ms>")
                .opt("duration-s", "30", "how long to serve before draining"),
        )
        .command(
            Command::new("submit", "publish one event to a remote queue")
                .opt("queue-addr", "127.0.0.1:7401", "queue address")
                .opt("runtime", "tinyyolo", "logical runtime name")
                .req("dataset", "dataset object key"),
        )
        .command(
            Command::new("inspect", "print AOT bundle information")
                .opt("artifacts", "", "artifacts dir (default: ./artifacts or $HARDLESS_ARTIFACTS)"),
        )
}

fn main() {
    hardless::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, m) = match app().parse(&argv) {
        Ok(x) => x,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(if msg.contains("usage:") { 0 } else { 2 });
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&m),
        "figures" => cmd_figures(&m),
        "serve" => cmd_serve(&m),
        "node" => cmd_node(&m),
        "submit" => cmd_submit(&m),
        "inspect" => cmd_inspect(&m),
        other => {
            eprintln!("unhandled command {other}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_engine(m: &hardless::cli::Matches) -> anyhow::Result<Engine> {
    match m.str_req("engine") {
        "pjrt" => Ok(Engine::Pjrt),
        "mock" => Ok(Engine::Mock),
        other => anyhow::bail!("unknown engine '{other}' (pjrt | mock)"),
    }
}

fn cmd_run(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let cfg = Config::load(m.str_req("config"))?;
    let engine = parse_engine(m)?;
    let result = bench::run_experiment(m.str_req("name"), &cfg, engine)?;
    result.write_csvs(m.str_req("out"))?;
    print!("{}", result.summary_text());
    println!("CSVs written to {}/", m.str_req("out"));
    Ok(())
}

fn cmd_figures(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let engine = parse_engine(m)?;
    let out = m.str_req("out");
    let fig3 = bench::fig3_dualgpu(engine)?;
    fig3.write_csvs(out)?;
    print!("{}", fig3.summary_text());
    let fig4 = bench::fig4_allaccel(engine)?;
    fig4.write_csvs(out)?;
    print!("{}", fig4.summary_text());
    println!("\n== paper comparison ==");
    println!(
        "max RFast  dual-GPU: {:.2}/s   all-accel: {:.2}/s   delta: +{:.2}/s",
        fig3.rfast_max,
        fig4.rfast_max,
        fig4.rfast_max - fig3.rfast_max
    );
    println!("(paper: ~3/s -> ~4/s, delta ~ +0.75..1; shape criterion: all-accel > dual-GPU by ~slot ratio)");
    for (kind, med) in fig4.median_elat_by_kind() {
        println!("median ELat [{kind}]: {med:.0} ms (paper: gpu 1675 ms, vpu 1577 ms)");
    }
    Ok(())
}

fn cmd_serve(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    use hardless::queue::{MemQueue, QueueServer};
    use hardless::store::{FsStore, MemStore, ObjectStore, StoreServer};
    use hardless::util::clock::ScaledClock;
    use std::sync::Arc;

    let clock = ScaledClock::realtime();
    let queue = MemQueue::new(clock);
    let store: Arc<dyn ObjectStore> = match m.str_req("store-dir") {
        "" => Arc::new(MemStore::new()),
        dir => Arc::new(FsStore::open(dir)?),
    };
    let qs = QueueServer::serve(m.str_req("queue-addr"), queue)?;
    let ss = StoreServer::serve(m.str_req("store-addr"), store)?;
    println!("queue listening on {}", qs.addr());
    println!("store listening on {}", ss.addr());
    println!("publish the runtime bundle and start nodes; ctrl-c to stop");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_node(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    use hardless::accel::{paper_all_accel, paper_dualgpu};
    use hardless::node::{spawn_node, InstanceReserve, NodeConfig, NodeDeps};
    use hardless::queue::QueueClient;
    use hardless::scheduler::parse_policy;
    use hardless::store::StoreClient;
    use hardless::util::clock::ScaledClock;
    use std::sync::{mpsc, Arc};

    let registry = match m.str_req("devices") {
        "paper-dualgpu" => paper_dualgpu(),
        "paper-all" => paper_all_accel(),
        other => anyhow::bail!("unknown device preset '{other}'"),
    };
    let queue = Arc::new(QueueClient::connect(m.str_req("queue-addr"))?);
    let store = Arc::new(StoreClient::connect(m.str_req("store-addr"))?);
    let clock = ScaledClock::realtime();

    // Fetch the runtime bundle from the store and prewarm executors —
    // exactly what the paper's node manager does at join time.
    let bundle = RuntimeBundle::fetch("tinyyolo", store.as_ref())
        .or_else(|_| RuntimeBundle::load_dir("tinyyolo", artifacts_dir()))?;
    let reserve = InstanceReserve::new();
    let built = reserve.prewarm_pjrt(&registry, &bundle)?;
    println!("node {}: prewarmed {built} PJRT instances", m.str_req("id"));

    let (tx, rx) = mpsc::channel();
    let deps = NodeDeps {
        queue,
        store,
        clock,
        policy: parse_policy(m.str_req("policy"))?,
        reserve,
        completions: tx,
    };
    let node = spawn_node(NodeConfig::new(m.str_req("id")), registry, deps)?;
    let secs: u64 = m.parse_num("duration-s").map_err(|e| anyhow::anyhow!(e))?;
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    let mut served = 0usize;
    while std::time::Instant::now() < deadline {
        if let Ok(inv) = rx.recv_timeout(Duration::from_millis(200)) {
            served += 1;
            println!(
                "completed {} on {} ({}) ELat {:.0} ms",
                inv.id,
                inv.accelerator.as_deref().unwrap_or("-"),
                if inv.warm { "warm" } else { "cold" },
                inv.stamps.elat_ms().unwrap_or(f64::NAN)
            );
        }
    }
    node.stop();
    println!("node served {served} invocations, exiting");
    Ok(())
}

fn cmd_submit(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    use hardless::events::{EventSpec, Invocation};
    use hardless::queue::{InvocationQueue, QueueClient};
    use hardless::util::next_id;

    let queue = QueueClient::connect(m.str_req("queue-addr"))?;
    let id = next_id("inv");
    let inv = Invocation::new(
        &id,
        EventSpec::new(m.str_req("runtime"), m.str_req("dataset")),
        hardless::util::SimTime(0),
    );
    queue.publish(inv)?;
    println!("published {id}");
    Ok(())
}

fn cmd_inspect(m: &hardless::cli::Matches) -> anyhow::Result<()> {
    let dir = match m.str_req("artifacts") {
        "" => artifacts_dir(),
        d => d.into(),
    };
    let bundle = RuntimeBundle::load_dir("tinyyolo", &dir)?;
    let mut out = Json::obj()
        .set("bundle", bundle.name.as_str())
        .set("weights", bundle.weights.len())
        .set("weight_bytes", bundle.weight_blob.len());
    let mut arts = Vec::new();
    for a in &bundle.artifacts {
        arts.push(
            Json::obj()
                .set("name", a.name.as_str())
                .set("input", Json::from(&a.input_shape[..]))
                .set("output", Json::from(&a.output_shape[..]))
                .set("dtype", a.compute_dtype.as_str())
                .set("hlo_bytes", bundle.hlo_text(&a.name)?.len()),
        );
    }
    out = out.set("artifacts", Json::Arr(arts));
    println!("{}", out.to_pretty());
    Ok(())
}
