//! Declarative CLI substrate (clap is unavailable in this offline build).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults and requiredness, positional arguments, and generated
//! `--help` text.  Used by the `hardless` binary, the examples, and the
//! bench harnesses.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One option/flag specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub required: bool,
    pub is_flag: bool,
}

/// A (sub)command specification.
#[derive(Debug, Clone, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), required: false, is_flag: false });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, required: true, is_flag: false });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, required: false, is_flag: true });
        self
    }

    /// Positional argument (ordered).
    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn help_text(&self, program: &str) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = write!(s, "usage: {program} {}", self.name);
        for (p, _) in &self.positionals {
            let _ = write!(s, " <{p}>");
        }
        let _ = writeln!(s, " [options]");
        for (p, h) in &self.positionals {
            let _ = writeln!(s, "  <{p}>  {h}");
        }
        for o in &self.opts {
            let mut left = format!("--{}", o.name);
            if !o.is_flag {
                left.push_str(" <v>");
            }
            let extra = match (&o.default, o.required) {
                (Some(d), _) => format!(" [default: {d}]"),
                (None, true) => " [required]".to_string(),
                _ => String::new(),
            };
            let _ = writeln!(s, "  {left:<24} {}{extra}", o.help);
        }
        s
    }

    /// Parse `args` (without the program / subcommand names).
    pub fn parse(&self, args: &[String]) -> Result<Matches, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos_vals: Vec<String> = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.help_text("hardless"));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.help_text("hardless")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?
                            .clone(),
                    };
                    values.insert(key, val);
                }
            } else {
                pos_vals.push(arg.clone());
            }
        }
        if pos_vals.len() > self.positionals.len() {
            return Err(format!(
                "unexpected positional argument '{}'",
                pos_vals[self.positionals.len()]
            ));
        }
        // defaults + requiredness
        for o in &self.opts {
            if o.is_flag || values.contains_key(o.name) {
                continue;
            }
            match (o.default, o.required) {
                (Some(d), _) => {
                    values.insert(o.name.to_string(), d.to_string());
                }
                (None, true) => return Err(format!("missing required option --{}", o.name)),
                _ => {}
            }
        }
        let mut positionals = BTreeMap::new();
        for ((name, _), val) in self.positionals.iter().zip(pos_vals) {
            positionals.insert(name.to_string(), val);
        }
        Ok(Matches { values, flags, positionals })
    }
}

/// Parsed argument values.
#[derive(Debug, Clone)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: BTreeMap<String, String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str_req(&self, name: &str) -> &str {
        self.get(name).unwrap_or_else(|| panic!("option --{name} missing after parse"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn pos(&self, name: &str) -> Option<&str> {
        self.positionals.get(name).map(|s| s.as_str())
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| format!("option --{name} not provided"))?;
        raw.parse::<T>()
            .map_err(|e| format!("--{name}={raw}: {e}"))
    }
}

/// Top-level multi-command app.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    commands: Vec<Command>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> App {
        App { name, about, commands: Vec::new() }
    }

    pub fn command(mut self, cmd: Command) -> App {
        self.commands.push(cmd);
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "usage: {} <command> [options]\n\ncommands:", self.name);
        for c in &self.commands {
            let _ = writeln!(s, "  {:<18} {}", c.name, c.about);
        }
        let _ = writeln!(s, "\nrun '{} <command> --help' for command options", self.name);
        s
    }

    /// Dispatch: returns `(command name, matches)` or a help/error string.
    pub fn parse(&self, argv: &[String]) -> Result<(String, Matches), String> {
        let Some(cmd_name) = argv.first() else {
            return Err(self.help_text());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(self.help_text());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.help_text()))?;
        let matches = cmd.parse(&argv[1..])?;
        Ok((cmd_name.clone(), matches))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("serve", "run a node")
            .opt("nodes", "1", "node count")
            .req("config", "config path")
            .flag("verbose", "log more")
            .pos("name", "cluster name")
    }

    #[test]
    fn parses_defaults_required_flags_positionals() {
        let m = cmd()
            .parse(&argv(&["mycluster", "--config", "c.json", "--verbose"]))
            .unwrap();
        assert_eq!(m.get("nodes"), Some("1"));
        assert_eq!(m.str_req("config"), "c.json");
        assert!(m.flag("verbose"));
        assert_eq!(m.pos("name"), Some("mycluster"));
    }

    #[test]
    fn equals_syntax() {
        let m = cmd().parse(&argv(&["--config=x.json", "--nodes=5"])).unwrap();
        assert_eq!(m.get("nodes"), Some("5"));
        assert_eq!(m.parse_num::<u32>("nodes").unwrap(), 5);
    }

    #[test]
    fn missing_required_errors() {
        let e = cmd().parse(&argv(&[])).unwrap_err();
        assert!(e.contains("--config"), "{e}");
    }

    #[test]
    fn unknown_option_errors() {
        let e = cmd().parse(&argv(&["--config", "c", "--what"])).unwrap_err();
        assert!(e.contains("unknown option"), "{e}");
    }

    #[test]
    fn flag_with_value_rejected() {
        let e = cmd()
            .parse(&argv(&["--config", "c", "--verbose=yes"]))
            .unwrap_err();
        assert!(e.contains("takes no value"), "{e}");
    }

    #[test]
    fn too_many_positionals_rejected() {
        let e = cmd()
            .parse(&argv(&["a", "b", "--config", "c"]))
            .unwrap_err();
        assert!(e.contains("unexpected positional"), "{e}");
    }

    #[test]
    fn numeric_parse_errors_carry_context() {
        let m = cmd().parse(&argv(&["--config", "c", "--nodes", "NaN"])).unwrap();
        let e = m.parse_num::<u32>("nodes").unwrap_err();
        assert!(e.contains("--nodes=NaN"), "{e}");
    }

    #[test]
    fn app_dispatch_and_help() {
        let app = App::new("hardless", "serverless accelerators")
            .command(cmd())
            .command(Command::new("bench", "run benches"));
        let (name, m) = app
            .parse(&argv(&["serve", "clu", "--config", "c"]))
            .unwrap();
        assert_eq!(name, "serve");
        assert_eq!(m.pos("name"), Some("clu"));
        let help = app.parse(&argv(&[])).unwrap_err();
        assert!(help.contains("commands:"), "{help}");
        let bad = app.parse(&argv(&["zzz"])).unwrap_err();
        assert!(bad.contains("unknown command"), "{bad}");
    }

    #[test]
    fn help_flag_returns_usage() {
        let e = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.contains("usage:"), "{e}");
        assert!(e.contains("[default: 1]"), "{e}");
        assert!(e.contains("[required]"), "{e}");
    }
}
