//! Measurement pipeline — the paper's §V-A vocabulary, end to end.
//!
//! Per invocation we keep the six timestamps (RStart..REnd) plus placement
//! facts; periodically we sample gauges (`#queued`, in-flight, free
//! slots).  From these the harness derives everything the paper plots:
//! `RLat`, `ELat`, `DLat`, `RSuccess`, and `RFast` (trailing-10 s
//! completion rate), split by accelerator where needed (the median-ELat
//! table).

use crate::events::{Invocation, Status};
use crate::queue::QueueStats;
use crate::util::{Histogram, MovingWindow, SimTime};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Completed-invocation record (immutable snapshot for analysis).
#[derive(Debug, Clone)]
pub struct Record {
    pub id: String,
    pub runtime: String,
    pub node: Option<String>,
    pub accelerator: Option<String>,
    pub variant: Option<String>,
    pub warm: bool,
    pub success: bool,
    pub rlat_ms: Option<f64>,
    pub elat_ms: Option<f64>,
    pub dlat_ms: Option<f64>,
    pub r_start: Option<SimTime>,
    pub r_end: Option<SimTime>,
}

impl Record {
    pub fn from_invocation(inv: &Invocation) -> Record {
        Record {
            id: inv.id.clone(),
            runtime: inv.spec.runtime.clone(),
            node: inv.node.clone(),
            accelerator: inv.accelerator.clone(),
            variant: inv.variant.clone(),
            warm: inv.warm,
            success: matches!(inv.status, Status::Succeeded),
            rlat_ms: inv.stamps.rlat_ms(),
            elat_ms: inv.stamps.elat_ms(),
            dlat_ms: inv.stamps.dlat_ms(),
            r_start: inv.stamps.r_start,
            r_end: inv.stamps.r_end,
        }
    }

    /// Accelerator kind prefix of the device id (`gpu0` → `gpu`).
    pub fn accel_kind(&self) -> Option<String> {
        self.accelerator
            .as_ref()
            .map(|a| a.trim_end_matches(|c: char| c.is_ascii_digit()).to_string())
    }
}

/// One periodic gauge sample (paper: "#queued and which accelerator is
/// processing which event").
#[derive(Debug, Clone, Copy)]
pub struct GaugeSample {
    pub t: SimTime,
    pub queued: usize,
    pub in_flight: usize,
    pub free_slots: usize,
}

/// Thread-safe collection hub shared by coordinator, nodes, and clients.
#[derive(Default)]
pub struct MetricsHub {
    records: Mutex<Vec<Record>>,
    gauges: Mutex<Vec<GaugeSample>>,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    pub fn record_completion(&self, inv: &Invocation) {
        self.records
            .lock()
            .expect("metrics poisoned")
            .push(Record::from_invocation(inv));
    }

    pub fn sample_gauge(&self, t: SimTime, q: QueueStats, free_slots: usize) {
        self.gauges.lock().expect("metrics poisoned").push(GaugeSample {
            t,
            queued: q.queued,
            in_flight: q.in_flight,
            free_slots,
        });
    }

    pub fn records(&self) -> Vec<Record> {
        self.records.lock().expect("metrics poisoned").clone()
    }

    pub fn gauges(&self) -> Vec<GaugeSample> {
        self.gauges.lock().expect("metrics poisoned").clone()
    }

    pub fn len(&self) -> usize {
        self.records.lock().expect("metrics poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Post-hoc analysis (the numbers/series the paper reports)
// ---------------------------------------------------------------------------

/// Summary over one record subset.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub success: usize,
    pub rlat: Histogram,
    pub elat: Histogram,
    pub dlat: Histogram,
    pub warm_fraction: f64,
}

pub fn summarize<'a>(records: impl IntoIterator<Item = &'a Record>) -> Summary {
    let mut s = Summary {
        n: 0,
        success: 0,
        rlat: Histogram::new(),
        elat: Histogram::new(),
        dlat: Histogram::new(),
        warm_fraction: 0.0,
    };
    let mut warm = 0usize;
    for r in records {
        s.n += 1;
        if r.success {
            s.success += 1;
        }
        if r.warm {
            warm += 1;
        }
        if let Some(v) = r.rlat_ms {
            s.rlat.record(v);
        }
        if let Some(v) = r.elat_ms {
            s.elat.record(v);
        }
        if let Some(v) = r.dlat_ms {
            s.dlat.record(v);
        }
    }
    s.warm_fraction = if s.n == 0 { 0.0 } else { warm as f64 / s.n as f64 };
    s
}

/// Per-accelerator-kind summaries (the paper's GPU 1675 ms vs VPU 1577 ms
/// median-ELat comparison).
pub fn summaries_by_kind(records: &[Record]) -> BTreeMap<String, Summary> {
    let mut groups: BTreeMap<String, Vec<&Record>> = BTreeMap::new();
    for r in records {
        if let Some(kind) = r.accel_kind() {
            groups.entry(kind).or_default().push(r);
        }
    }
    groups
        .into_iter()
        .map(|(k, v)| (k, summarize(v.into_iter())))
        .collect()
}

/// The paper's RFast series: successful completions in a trailing 10 s
/// window, sampled every `step`, normalized per second.
pub fn rfast_series(records: &[Record], step: Duration) -> Vec<(SimTime, f64)> {
    let mut ends: Vec<SimTime> = records
        .iter()
        .filter(|r| r.success)
        .filter_map(|r| r.r_end)
        .collect();
    ends.sort();
    let Some(&last) = ends.last() else {
        return Vec::new();
    };
    let mut window = MovingWindow::rfast();
    for &e in &ends {
        window.record(e);
    }
    let mut out = Vec::new();
    let step_us = step.as_micros() as u64;
    let mut t = 0u64;
    while t <= last.as_micros() + step_us {
        let now = SimTime(t);
        out.push((now, window.rate_per_sec(now)));
        t += step_us;
    }
    out
}

/// Maximum of the RFast series — the paper's headline per-setup number
/// (≈3/s dual-GPU, ≈4/s all-accelerator).
pub fn rfast_max(records: &[Record]) -> f64 {
    rfast_series(records, Duration::from_secs(1))
        .into_iter()
        .map(|(_, v)| v)
        .fold(0.0, f64::max)
}

// ---------------------------------------------------------------------------
// CSV export (bench harness output, one file per figure panel)
// ---------------------------------------------------------------------------

/// Per-invocation series CSV: `t_s,rlat_ms,elat_ms,dlat_ms,accel,warm`.
pub fn records_csv(records: &[Record]) -> String {
    let mut rows: Vec<&Record> = records.iter().filter(|r| r.r_end.is_some()).collect();
    rows.sort_by_key(|r| r.r_end);
    let mut s = String::from("t_s,rlat_ms,elat_ms,dlat_ms,accelerator,variant,warm,success\n");
    for r in rows {
        s.push_str(&format!(
            "{:.3},{:.1},{:.1},{:.1},{},{},{},{}\n",
            r.r_end.unwrap().as_secs_f64(),
            r.rlat_ms.unwrap_or(f64::NAN),
            r.elat_ms.unwrap_or(f64::NAN),
            r.dlat_ms.unwrap_or(f64::NAN),
            r.accelerator.as_deref().unwrap_or("-"),
            r.variant.as_deref().unwrap_or("-"),
            r.warm,
            r.success,
        ));
    }
    s
}

/// Gauge series CSV: `t_s,queued,in_flight,free_slots`.
pub fn gauges_csv(gauges: &[GaugeSample]) -> String {
    let mut s = String::from("t_s,queued,in_flight,free_slots\n");
    for g in gauges {
        s.push_str(&format!(
            "{:.3},{},{},{}\n",
            g.t.as_secs_f64(),
            g.queued,
            g.in_flight,
            g.free_slots
        ));
    }
    s
}

/// RFast series CSV: `t_s,rfast_per_s`.
pub fn rfast_csv(series: &[(SimTime, f64)]) -> String {
    let mut s = String::from("t_s,rfast_per_s\n");
    for (t, v) in series {
        s.push_str(&format!("{:.3},{:.3}\n", t.as_secs_f64(), v));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{EventSpec, Stamps};

    fn t(ms: u64) -> SimTime {
        SimTime::from_millis(ms)
    }

    fn rec(id: &str, accel: &str, r_start: u64, e_ms: u64, r_end: u64, warm: bool) -> Record {
        let mut inv = Invocation::new(id, EventSpec::new("tinyyolo", "d"), t(r_start));
        inv.status = Status::Succeeded;
        inv.accelerator = Some(accel.to_string());
        inv.variant = Some(format!("tinyyolo-{}", &accel[..3]));
        inv.warm = warm;
        inv.stamps = Stamps {
            r_start: Some(t(r_start)),
            n_start: Some(t(r_start + 50)),
            e_start: Some(t(r_start + 100)),
            e_end: Some(t(r_start + 100 + e_ms)),
            n_end: Some(t(r_end - 10)),
            r_end: Some(t(r_end)),
        };
        Record::from_invocation(&inv)
    }

    #[test]
    fn record_derives_latencies() {
        let r = rec("1", "gpu0", 1000, 1675, 3000, true);
        assert_eq!(r.rlat_ms, Some(2000.0));
        assert_eq!(r.elat_ms, Some(1675.0));
        assert_eq!(r.dlat_ms, Some(100.0));
        assert_eq!(r.accel_kind(), Some("gpu".to_string()));
    }

    #[test]
    fn summarize_medians_and_warm_fraction() {
        let records = vec![
            rec("1", "gpu0", 0, 1600, 2000, true),
            rec("2", "gpu0", 0, 1700, 2100, false),
            rec("3", "gpu1", 0, 1800, 2200, true),
        ];
        let mut s = summarize(records.iter());
        assert_eq!(s.n, 3);
        assert_eq!(s.success, 3);
        assert_eq!(s.elat.median(), Some(1700.0));
        assert!((s.warm_fraction - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn by_kind_split_matches_paper_table_shape() {
        let records = vec![
            rec("1", "gpu0", 0, 1675, 2000, true),
            rec("2", "gpu1", 0, 1675, 2000, true),
            rec("3", "vpu0", 0, 1577, 1900, true),
        ];
        let by = summaries_by_kind(&records);
        assert_eq!(by.len(), 2);
        assert_eq!(by["gpu"].n, 2);
        let mut vpu = by["vpu"].clone();
        assert_eq!(vpu.elat.median(), Some(1577.0));
    }

    #[test]
    fn rfast_counts_trailing_window() {
        // 20 completions spread over 5 s -> rate 2/s once window fills
        let records: Vec<Record> = (0..20)
            .map(|i| rec(&format!("i{i}"), "gpu0", i * 250, 100, i * 250 + 500, true))
            .collect();
        let max = rfast_max(&records);
        assert!((max - 2.0).abs() < 0.3, "max rfast {max}");
    }

    #[test]
    fn rfast_ignores_failures() {
        let mut records = vec![rec("ok", "gpu0", 0, 100, 500, true)];
        let mut failed = rec("bad", "gpu0", 0, 100, 600, true);
        failed.success = false;
        records.push(failed);
        let series = rfast_series(&records, Duration::from_secs(1));
        let max = series.iter().map(|(_, v)| *v).fold(0.0, f64::max);
        assert!((max - 0.1).abs() < 1e-9, "only 1 success in 10s window: {max}");
    }

    #[test]
    fn hub_is_thread_safe() {
        let hub = std::sync::Arc::new(MetricsHub::new());
        let mut handles = Vec::new();
        for i in 0..4 {
            let hub = hub.clone();
            handles.push(std::thread::spawn(move || {
                for j in 0..25 {
                    let mut inv = Invocation::new(
                        format!("t{i}-{j}"),
                        EventSpec::new("r", "d"),
                        t(0),
                    );
                    inv.status = Status::Succeeded;
                    hub.record_completion(&inv);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hub.len(), 100);
    }

    #[test]
    fn csv_exports_parse_back() {
        let records = vec![rec("1", "gpu0", 0, 1675, 2000, true)];
        let csv = records_csv(&records);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("t_s,"));
        assert!(lines[1].contains("gpu0"));
        let g = vec![GaugeSample { t: t(1000), queued: 5, in_flight: 4, free_slots: 1 }];
        assert!(gauges_csv(&g).contains("1.000,5,4,1"));
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(rfast_max(&[]), 0.0);
        assert!(rfast_series(&[], Duration::from_secs(1)).is_empty());
        let s = summarize(Vec::<Record>::new().iter());
        assert_eq!(s.n, 0);
    }
}
