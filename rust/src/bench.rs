//! Experiment harness: runs a configured experiment end-to-end and emits
//! the series/rows the paper reports (DESIGN.md §4 experiment index).
//!
//! Every figure/table bench under `benches/` is a thin wrapper over
//! [`run_experiment`]; `examples/paper_figures.rs` drives the same code.

use crate::config::Config;
use crate::coordinator::cluster::{Cluster, ExecutorKind};
use crate::metrics::{
    self, gauges_csv, records_csv, rfast_csv, summaries_by_kind, GaugeSample, Record,
};
use crate::runtime::{artifacts_available, artifacts_dir, RuntimeBundle};
use crate::scheduler::parse_policy;
use crate::workload::{self, RunReport};
use anyhow::{Context, Result};
use std::path::Path;
use std::time::Duration;

/// Executor selection for an experiment run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Real AOT artifacts through PJRT (needs `make artifacts`).
    Pjrt,
    /// Mock executors — same coordination plane, no PJRT (fast CI path).
    Mock,
}

/// Everything an experiment produces.
pub struct ExperimentResult {
    pub name: String,
    pub report: RunReport,
    pub records: Vec<Record>,
    pub gauges: Vec<GaugeSample>,
    pub rfast: Vec<(crate::util::SimTime, f64)>,
    pub rfast_max: f64,
    pub wall: Duration,
}

impl ExperimentResult {
    /// Write the figure panels as CSVs under `dir`:
    /// `<name>_series.csv` (per-invocation latencies over time — Fig a),
    /// `<name>_gauges.csv` (#queued etc.), `<name>_rfast.csv` (Fig b).
    pub fn write_csvs(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}_series.csv", self.name)), records_csv(&self.records))?;
        std::fs::write(dir.join(format!("{}_gauges.csv", self.name)), gauges_csv(&self.gauges))?;
        std::fs::write(dir.join(format!("{}_rfast.csv", self.name)), rfast_csv(&self.rfast))?;
        Ok(())
    }

    /// Human-readable summary block (the rows the paper's text reports).
    pub fn summary_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("== experiment {} ==\n", self.name));
        s.push_str(&format!(
            "submitted {} | completed {} | succeeded {} | lost {} | wall {:.1}s\n",
            self.report.submitted,
            self.report.completed,
            self.report.succeeded,
            self.report.lost,
            self.wall.as_secs_f64()
        ));
        s.push_str(&format!("max RFast: {:.2}/s\n", self.rfast_max));
        let mut all = metrics::summarize(self.records.iter());
        s.push_str(&format!(
            "RLat: {} (ms)\nELat: {} (ms)\nDLat: {} (ms)\nwarm fraction: {:.2}\n",
            all.rlat.summary(),
            all.elat.summary(),
            all.dlat.summary(),
            all.warm_fraction
        ));
        for (kind, mut summary) in summaries_by_kind(&self.records) {
            s.push_str(&format!(
                "  [{kind}] n={} median ELat {:.0} ms | median RLat {:.0} ms\n",
                summary.n,
                summary.elat.median().unwrap_or(f64::NAN),
                summary.rlat.median().unwrap_or(f64::NAN),
            ));
        }
        let max_queued = self.gauges.iter().map(|g| g.queued).max().unwrap_or(0);
        s.push_str(&format!("max #queued: {max_queued}\n"));
        s
    }

    /// Median ELat per accelerator kind (paper T2).
    pub fn median_elat_by_kind(&self) -> Vec<(String, f64)> {
        summaries_by_kind(&self.records)
            .into_iter()
            .map(|(k, mut s)| (k, s.elat.median().unwrap_or(f64::NAN)))
            .collect()
    }
}

/// Run one experiment from a config.
pub fn run_experiment(name: &str, cfg: &Config, engine: Engine) -> Result<ExperimentResult> {
    let t0 = std::time::Instant::now();
    let executor = match engine {
        Engine::Pjrt => {
            anyhow::ensure!(
                artifacts_available(),
                "artifacts not built — run `make artifacts` first"
            );
            ExecutorKind::Pjrt(
                RuntimeBundle::load_dir("tinyyolo", artifacts_dir())
                    .context("load AOT bundle")?,
            )
        }
        Engine::Mock => ExecutorKind::Mock {
            scale: 1.0,
            delay: Duration::from_millis(1),
        },
    };

    let mut builder = Cluster::builder()
        .time_scale(cfg.time_scale)
        .policy(parse_policy(&cfg.policy)?)
        .executors(executor)
        .node_batch(cfg.batch_config())
        .gauge_interval(Duration::from_secs(1));
    for node in &cfg.nodes {
        builder = builder.node(&node.id, node.registry());
    }
    let cluster = builder.build()?;

    let datasets = workload::synthetic_image_datasets(&cluster, cfg.dataset_count, 1234)?;
    let wl = cfg.workload.clone().with_datasets(datasets);

    // Generous drain: the P1 overload backlog has to clear at capacity
    // rate; budget the whole protocol again in wall time.
    let drain_wall =
        Duration::from_secs_f64((wl.duration().as_secs_f64() / cfg.time_scale) * 3.0 + 30.0);
    let report = workload::run_workload(&cluster, &wl, drain_wall)?;

    let records = cluster.metrics.records();
    let gauges = cluster.metrics.gauges();
    let rfast = metrics::rfast_series(&records, Duration::from_secs(1));
    let rfast_max = metrics::rfast_max(&records);
    cluster.shutdown();

    Ok(ExperimentResult {
        name: name.to_string(),
        report,
        records,
        gauges,
        rfast,
        rfast_max,
        wall: t0.elapsed(),
    })
}

/// Fig. 3: the dual-GPU setup.
pub fn fig3_dualgpu(engine: Engine) -> Result<ExperimentResult> {
    run_experiment("fig3_dualgpu", &Config::paper_dualgpu(), engine)
}

/// Fig. 4: GPUs + VPU.
pub fn fig4_allaccel(engine: Engine) -> Result<ExperimentResult> {
    run_experiment("fig4_allaccel", &Config::paper_all(), engine)
}

/// Output directory for bench CSVs.
pub fn bench_out_dir() -> std::path::PathBuf {
    std::env::var("HARDLESS_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("bench_out"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fast mock-engine experiment exercising the whole harness.
    #[test]
    fn mock_experiment_end_to_end() {
        let mut cfg = Config::paper_dualgpu();
        cfg.time_scale = 40.0; // compress aggressively for the unit test
        cfg.protocol_scale = 0.05;
        cfg.workload = crate::workload::Workload::paper_protocol("tinyyolo", 0.5, 3.0, 0.05);
        let result = run_experiment("unit_mock", &cfg, Engine::Mock).unwrap();
        assert!(result.report.submitted > 50, "{}", result.report.submitted);
        assert_eq!(result.report.lost, 0);
        assert_eq!(result.report.succeeded, result.report.submitted);
        assert!(result.rfast_max > 0.5, "rfast max {}", result.rfast_max);
        // ELat pacing: medians near the K600 calibration
        let by = result.median_elat_by_kind();
        let gpu = by.iter().find(|(k, _)| k == "gpu").expect("gpu records");
        assert!((gpu.1 - 1675.0).abs() < 120.0, "gpu median ELat {}", gpu.1);
        let text = result.summary_text();
        assert!(text.contains("max RFast"), "{text}");
    }

    #[test]
    fn csv_outputs_written() {
        let mut cfg = Config::paper_dualgpu();
        cfg.time_scale = 60.0;
        cfg.protocol_scale = 0.02;
        cfg.workload = crate::workload::Workload::paper_protocol("tinyyolo", 0.5, 2.0, 0.02);
        let result = run_experiment("unit_csv", &cfg, Engine::Mock).unwrap();
        let dir = std::env::temp_dir().join(format!("hardless-bench-{}", std::process::id()));
        result.write_csvs(&dir).unwrap();
        for suffix in ["series", "gauges", "rfast"] {
            let p = dir.join(format!("unit_csv_{suffix}.csv"));
            assert!(p.is_file(), "{p:?}");
            assert!(std::fs::read_to_string(p).unwrap().lines().count() > 1);
        }
        let _ = std::fs::remove_dir_all(dir);
    }
}
