//! Phased open-loop workload generation — the paper's benchmark client.
//!
//! §V-A: *"For each workload, we performed a set of invocations split into
//! three phases (P0–P2): a 2-minute warm-up phase (P0), a 10-minute
//! scaling phase (P1), and a 2-minute cooldown phase (P2). Each phase has
//! a target invocation throughput"* (trps), following the workload
//! vocabulary of Kuhlenkamp et al. [17].
//!
//! The generator is **open loop**: arrival times depend only on the target
//! rate (deterministic spacing or Poisson), never on completions — the
//! property that makes backlog growth visible when the system saturates.

use crate::api::HardlessClient;
use crate::coordinator::Cluster;
use crate::events::EventSpec;
use crate::json::Json;
use crate::util::{Clock, Rng, SimTime};
use anyhow::Result;
use std::time::Duration;

/// One phase: hold `target_trps` for `duration` (sim time).
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub name: String,
    pub duration: Duration,
    pub target_trps: f64,
}

impl Phase {
    pub fn new(name: &str, duration: Duration, target_trps: f64) -> Phase {
        Phase { name: name.into(), duration, target_trps }
    }
}

/// Arrival process within a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrivals {
    /// Evenly spaced (1/rate) — what a load generator firing on a timer
    /// produces; matches the paper's "target invocation throughput".
    Uniform,
    /// Poisson process (exponential inter-arrivals).
    Poisson,
}

/// A full workload description.
#[derive(Debug, Clone)]
pub struct Workload {
    pub runtime: String,
    pub phases: Vec<Phase>,
    pub arrivals: Arrivals,
    /// Dataset keys cycled round-robin across events.
    pub datasets: Vec<String>,
    pub seed: u64,
}

impl Workload {
    /// The paper's protocol shape (P0 warm-up, P1 scaling, P2 cool-down),
    /// time-compressed by the cluster clock.  `p1_trps` is the scaling
    /// phase's target rate; warm-up runs at `p0_trps`.
    ///
    /// Durations are the paper's 2/10/2 minutes scaled by `protocol_scale`
    /// (e.g. 0.05 ⇒ 6 s / 30 s / 6 s of *sim* time — still long relative
    /// to the ~1.6 s service times, preserving the queueing regimes).
    pub fn paper_protocol(
        runtime: &str,
        p0_trps: f64,
        p1_trps: f64,
        protocol_scale: f64,
    ) -> Workload {
        let mins = |m: f64| Duration::from_secs_f64(60.0 * m * protocol_scale);
        Workload {
            runtime: runtime.into(),
            phases: vec![
                Phase::new("P0", mins(2.0), p0_trps),
                Phase::new("P1", mins(10.0), p1_trps),
                Phase::new("P2", mins(2.0), p0_trps),
            ],
            arrivals: Arrivals::Uniform,
            datasets: Vec::new(),
            seed: 42,
        }
    }

    pub fn with_datasets(mut self, datasets: Vec<String>) -> Workload {
        self.datasets = datasets;
        self
    }

    pub fn with_arrivals(mut self, arrivals: Arrivals) -> Workload {
        self.arrivals = arrivals;
        self
    }

    /// Total sim-time duration.
    pub fn duration(&self) -> Duration {
        self.phases.iter().map(|p| p.duration).sum()
    }

    /// Expected number of events over the whole protocol.
    pub fn expected_events(&self) -> f64 {
        self.phases
            .iter()
            .map(|p| p.duration.as_secs_f64() * p.target_trps)
            .sum()
    }

    /// Compute the full arrival schedule (sim-time offsets from start).
    /// Deterministic for a given seed.
    pub fn schedule(&self) -> Vec<(SimTime, String)> {
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::new();
        let mut phase_start = 0f64; // seconds
        for phase in &self.phases {
            let dur = phase.duration.as_secs_f64();
            if phase.target_trps <= 0.0 {
                phase_start += dur;
                continue;
            }
            let mut t = match self.arrivals {
                Arrivals::Uniform => 1.0 / phase.target_trps,
                Arrivals::Poisson => rng.exp(phase.target_trps),
            };
            while t <= dur {
                out.push((
                    SimTime((1e6 * (phase_start + t)) as u64),
                    phase.name.clone(),
                ));
                t += match self.arrivals {
                    Arrivals::Uniform => 1.0 / phase.target_trps,
                    Arrivals::Poisson => rng.exp(phase.target_trps),
                };
            }
            phase_start += dur;
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("runtime", self.runtime.as_str())
            .set(
                "phases",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            Json::obj()
                                .set("name", p.name.as_str())
                                .set("duration_s", p.duration.as_secs_f64())
                                .set("target_trps", p.target_trps)
                        })
                        .collect(),
                ),
            )
            .set(
                "arrivals",
                match self.arrivals {
                    Arrivals::Uniform => "uniform",
                    Arrivals::Poisson => "poisson",
                },
            )
            .set("seed", self.seed)
    }
}

/// Outcome of a workload run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub submitted: usize,
    pub completed: usize,
    pub succeeded: usize,
    /// Events still in flight when the drain timeout expired.
    pub lost: usize,
}

/// Drive a workload against a cluster: submit on schedule (sim time), then
/// drain.  Returns per-run counts; per-invocation data lands in the
/// cluster's metrics hub.
pub fn run_workload(cluster: &Cluster, workload: &Workload, drain_timeout: Duration) -> Result<RunReport> {
    anyhow::ensure!(
        !workload.datasets.is_empty(),
        "workload has no datasets uploaded"
    );
    let schedule = workload.schedule();
    let mut submitted = 0usize;
    for (i, (at, _phase)) in schedule.iter().enumerate() {
        // Open loop: sleep until the scheduled arrival, regardless of how
        // far behind the system is.
        let now = cluster.clock.now();
        if *at > now {
            cluster.clock.sleep(at.since(now));
        }
        let dataset = &workload.datasets[i % workload.datasets.len()];
        cluster.submit(
            EventSpec::new(&workload.runtime, dataset)
                .with_config(Json::obj().set("seq", i)),
        )?;
        submitted += 1;
    }
    let lost = cluster.drain(drain_timeout);
    let counts = cluster.coordinator.counts();
    Ok(RunReport {
        submitted,
        completed: counts.completed,
        succeeded: counts.succeeded,
        lost,
    })
}

/// Upload `n` synthetic image datasets sized for the tinyyolo input
/// (64×64×3 f32 in [0, 255]), returning their keys.
pub fn synthetic_image_datasets(cluster: &Cluster, n: usize, seed: u64) -> Result<Vec<String>> {
    let mut rng = Rng::new(seed);
    let mut keys = Vec::with_capacity(n);
    for i in 0..n {
        let img: Vec<f32> = (0..64 * 64 * 3).map(|_| 255.0 * rng.f64() as f32).collect();
        keys.push(cluster.upload_dataset(&format!("img-{i}"), &img)?);
    }
    Ok(keys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_protocol_shape() {
        let w = Workload::paper_protocol("tinyyolo", 1.0, 4.0, 1.0);
        assert_eq!(w.phases.len(), 3);
        assert_eq!(w.duration(), Duration::from_secs(14 * 60));
        assert_eq!(w.phases[1].target_trps, 4.0);
        // 2min*1 + 10min*4 + 2min*1 = 120 + 2400 + 120
        assert!((w.expected_events() - 2640.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_schedule_is_evenly_spaced() {
        let w = Workload {
            runtime: "r".into(),
            phases: vec![Phase::new("P", Duration::from_secs(10), 2.0)],
            arrivals: Arrivals::Uniform,
            datasets: vec![],
            seed: 1,
        };
        let s = w.schedule();
        assert_eq!(s.len(), 20);
        let gap = s[1].0.as_micros() - s[0].0.as_micros();
        assert_eq!(gap, 500_000, "2 trps -> 500 ms spacing");
        assert!(s.last().unwrap().0 <= SimTime(10_000_000));
    }

    #[test]
    fn phase_boundaries_respected() {
        let w = Workload {
            runtime: "r".into(),
            phases: vec![
                Phase::new("A", Duration::from_secs(5), 1.0),
                Phase::new("B", Duration::from_secs(5), 3.0),
            ],
            arrivals: Arrivals::Uniform,
            datasets: vec![],
            seed: 1,
        };
        let s = w.schedule();
        let a: Vec<_> = s.iter().filter(|(_, p)| p == "A").collect();
        let b: Vec<_> = s.iter().filter(|(_, p)| p == "B").collect();
        assert_eq!(a.len(), 5);
        assert_eq!(b.len(), 15);
        assert!(a.iter().all(|(t, _)| t.as_secs_f64() <= 5.0));
        assert!(b.iter().all(|(t, _)| t.as_secs_f64() > 5.0));
    }

    #[test]
    fn poisson_schedule_rate_approximates_target() {
        let w = Workload {
            runtime: "r".into(),
            phases: vec![Phase::new("P", Duration::from_secs(500), 4.0)],
            arrivals: Arrivals::Poisson,
            datasets: vec![],
            seed: 7,
        };
        let n = w.schedule().len() as f64;
        assert!((n - 2000.0).abs() < 150.0, "poisson count {n}");
    }

    #[test]
    fn schedule_deterministic_per_seed() {
        let mk = |seed| Workload {
            runtime: "r".into(),
            phases: vec![Phase::new("P", Duration::from_secs(30), 2.0)],
            arrivals: Arrivals::Poisson,
            datasets: vec![],
            seed,
        };
        assert_eq!(mk(5).schedule(), mk(5).schedule());
        assert_ne!(mk(5).schedule(), mk(6).schedule());
    }

    #[test]
    fn zero_rate_phase_emits_nothing() {
        let w = Workload {
            runtime: "r".into(),
            phases: vec![
                Phase::new("idle", Duration::from_secs(10), 0.0),
                Phase::new("go", Duration::from_secs(2), 1.0),
            ],
            arrivals: Arrivals::Uniform,
            datasets: vec![],
            seed: 1,
        };
        let s = w.schedule();
        assert_eq!(s.len(), 2);
        assert!(s[0].0.as_secs_f64() > 10.0, "first event after the idle phase");
    }

    #[test]
    fn end_to_end_small_run() {
        use crate::accel::paper_dualgpu;
        use crate::coordinator::cluster::ExecutorKind;
        let cluster = Cluster::builder()
            .time_scale(300.0)
            .executors(ExecutorKind::Mock { scale: 1.0, delay: Duration::from_millis(1) })
            .node("node-1", paper_dualgpu())
            .build()
            .unwrap();
        let datasets = synthetic_image_datasets(&cluster, 2, 9).unwrap();
        let w = Workload {
            runtime: "tinyyolo".into(),
            phases: vec![Phase::new("P", Duration::from_secs(20), 1.0)],
            arrivals: Arrivals::Uniform,
            datasets,
            seed: 3,
        }; // 20 events over 20 sim-s ≈ 70 wall-ms at 300x
        let report = run_workload(&cluster, &w, Duration::from_secs(60)).unwrap();
        assert_eq!(report.submitted, 20);
        assert_eq!(report.lost, 0);
        assert_eq!(report.succeeded, 20);
        cluster.shutdown();
    }

    #[test]
    fn workload_json_export() {
        let w = Workload::paper_protocol("tinyyolo", 1.0, 4.0, 0.1);
        let j = w.to_json();
        assert_eq!(j.str_of("runtime").unwrap(), "tinyyolo");
        assert_eq!(j.arr_of("phases").unwrap().len(), 3);
    }
}
