//! Closed-loop elasticity — the serverless promise the paper leaves to
//! the platform operator, automated.
//!
//! HARDLESS claims accelerator workloads get the *fully automated
//! elastic* experience of CPU serverless (§I, §IV); the Berkeley View
//! makes auto-scaling (including scale-to-zero) the defining property of
//! serverless.  This module closes the loop the coordinator leaves open:
//! a controller samples per-runtime-class signals — queue depth,
//! oldest-waiting age, free slots, warm-pool occupancy — and issues
//! `add_node` / `remove_node` decisions through a [`ScaleExecutor`],
//! with hysteresis watermarks, per-direction cooldowns, min/max node
//! bounds, and scale-to-zero above a configurable warm floor.
//!
//! Layering:
//!
//! * [`controller::AutoscaleController`] — the pure decision core
//!   (signals + sim-time in, decision out; no clocks, threads, or I/O).
//! * [`Autoscaler`] — a thread-safe handle pairing the controller with a
//!   [`ScaleExecutor`]; whoever owns the loop (the in-process
//!   `Cluster`'s autoscale thread, the gateway's housekeeping tick, a
//!   test harness) calls [`Autoscaler::tick`] at its own cadence.
//! * [`SignalSource`] / [`ScaleExecutor`] — the two seams to the rest of
//!   the system; `coordinator::Cluster` implements both for real nodes,
//!   [`AdvisoryExecutor`] stands in where provisioning is external.
//!
//! Every timestamp flows through [`crate::util::Clock`], so the whole
//! subsystem is reproducible under [`crate::util::SimClock`]: the
//! scenario suite (`rust/tests/autoscale_scenarios.rs`) replays bursts,
//! ramps, and idle tails with zero wall-clock sleeps, and the same seed
//! reproduces the same decision log byte for byte.

pub mod controller;
#[cfg(test)]
mod reference;

pub use controller::{Action, AutoscaleController, Decision};

use crate::json::Json;
use crate::queue::ClassStats;
use crate::util::SimTime;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Controller tunables (all durations are sim time).
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Warm floor: scale-in never goes below this many nodes (0 = full
    /// scale-to-zero), and lost capacity below it is replenished.
    pub min_nodes: usize,
    /// Hard ceiling on the fleet.
    pub max_nodes: usize,
    /// High watermark: scale out when any class's queue depth exceeds
    /// `up_depth_per_node × live nodes`.
    pub up_depth_per_node: usize,
    /// ...or when any class's oldest queued invocation has waited this
    /// long (latency guard for shallow-but-stuck lanes).
    pub up_oldest: Duration,
    /// Interactive high watermark: scale out when any class's
    /// *interactive* backlog exceeds `up_interactive_depth_per_node ×
    /// live nodes`.  Tighter than `up_depth_per_node`, so latency-class
    /// pressure drives capacity before raw batch depth would (checked
    /// first in the pressure scan; inert while no interactive work is
    /// queued).
    pub up_interactive_depth_per_node: usize,
    /// ...or when the oldest queued *interactive* invocation has waited
    /// this long.  Tighter than `up_oldest` for the same reason.
    pub up_interactive_oldest: Duration,
    /// Low watermark: scale in one node only after the whole system
    /// (queued + in-flight) has been empty this long.
    pub down_idle: Duration,
    /// Minimum spacing between successive scale-outs.
    pub cooldown_up: Duration,
    /// Minimum spacing between a scale-in and the last action in either
    /// direction (flip protection: no up-then-down inside this window).
    pub cooldown_down: Duration,
    /// Capacity one template node is expected to add (sizes the
    /// backlog-proportional scale-out step).
    pub node_slots_hint: usize,
    /// Cap on nodes added by a single decision.
    pub max_step_up: usize,
    /// Evaluation period for loop owners that honor it (the in-process
    /// cluster's autoscale thread; the gateway ticks on housekeeping).
    pub tick: Duration,
}

impl AutoscaleConfig {
    /// Bounds sanity for Result-returning entry points
    /// (`Cluster::start_autoscale`, `GatewayServer::serve`) — the
    /// controller itself asserts the same invariant.
    pub fn validate(&self) -> Result<()> {
        if self.min_nodes > self.max_nodes {
            anyhow::bail!(
                "autoscale min_nodes {} exceeds max_nodes {}",
                self.min_nodes,
                self.max_nodes
            );
        }
        Ok(())
    }
}

impl Default for AutoscaleConfig {
    fn default() -> AutoscaleConfig {
        AutoscaleConfig {
            min_nodes: 0,
            max_nodes: 8,
            up_depth_per_node: 4,
            up_oldest: Duration::from_secs(10),
            up_interactive_depth_per_node: 2,
            up_interactive_oldest: Duration::from_secs(3),
            down_idle: Duration::from_secs(30),
            cooldown_up: Duration::from_secs(15),
            cooldown_down: Duration::from_secs(60),
            node_slots_hint: 4,
            max_step_up: 4,
            tick: Duration::from_secs(2),
        }
    }
}

/// One controller input sample: the cluster's load/capacity state at an
/// instant, as cheap gauges (everything here is O(nodes + classes) to
/// collect — see DESIGN.md §10).
#[derive(Debug, Clone, Default)]
pub struct Signals {
    /// Total queued (not leased) invocations.
    pub queued: usize,
    /// Leased, not yet acked.
    pub in_flight: usize,
    /// Per-runtime-class depth/age (sorted by runtime).
    pub classes: Vec<ClassStats>,
    /// Live node count.
    pub nodes: usize,
    /// Free accelerator slots across live nodes.
    pub free_slots: usize,
    /// Live warm runtime instances across node pools.
    pub warm_instances: usize,
}

/// Where scale decisions land.  The in-process `Cluster` stamps real
/// nodes from its `NodeTemplate`; distributed deployments may translate
/// these into provisioning calls, or use [`AdvisoryExecutor`].
pub trait ScaleExecutor: Send + Sync {
    /// Add `count` nodes; returns their ids.
    fn scale_up(&self, count: usize) -> Result<Vec<String>>;

    /// Gracefully retire up to `count` idlest nodes (stop taking new
    /// leases, drain, then stop); returns the retired ids.
    fn scale_down(&self, count: usize) -> Result<Vec<String>>;
}

/// Where the controller's input sample comes from.
pub trait SignalSource: Send + Sync {
    fn sample(&self) -> Signals;
}

/// Counters surfaced through `cluster_stats` (the `autoscale` section).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AutoscaleStats {
    pub enabled: bool,
    /// Node count at the last evaluation.
    pub nodes: usize,
    /// Node count the last decision targeted.
    pub target: usize,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub holds: u64,
    pub ticks: u64,
    /// Last decision, rendered (`up+2`, `down-1`, `hold`, "" before the
    /// first tick).
    pub last_action: String,
    pub last_reason: String,
}

impl AutoscaleStats {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("enabled", self.enabled)
            .set("nodes", self.nodes)
            .set("target", self.target)
            .set("scale_ups", self.scale_ups)
            .set("scale_downs", self.scale_downs)
            .set("holds", self.holds)
            .set("ticks", self.ticks)
            .set("last_action", self.last_action.as_str())
            .set("last_reason", self.last_reason.as_str())
    }

    /// Lenient parse: a stats payload from a deployment without the
    /// autoscaler (or predating it) yields the disabled default.
    pub fn from_json(j: &Json) -> AutoscaleStats {
        let num = |k: &str| j.u64_of(k).unwrap_or(0);
        let s = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(String::from)
                .unwrap_or_default()
        };
        AutoscaleStats {
            enabled: j.get("enabled").and_then(|v| v.as_bool()).unwrap_or(false),
            nodes: num("nodes") as usize,
            target: num("target") as usize,
            scale_ups: num("scale_ups"),
            scale_downs: num("scale_downs"),
            holds: num("holds"),
            ticks: num("ticks"),
            last_action: s("last_action"),
            last_reason: s("last_reason"),
        }
    }
}

/// Thread-safe controller + executor pairing.  The loop owner samples
/// signals and calls [`tick`](Autoscaler::tick); this evaluates the
/// controller and applies any resulting action.
pub struct Autoscaler {
    controller: Mutex<AutoscaleController>,
    /// Executor failures (e.g. template exhausted) — the decision stays
    /// logged, the fleet is simply smaller than targeted.
    exec_errors: AtomicU64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        Autoscaler {
            controller: Mutex::new(AutoscaleController::new(cfg)),
            exec_errors: AtomicU64::new(0),
        }
    }

    /// One control-loop turn: evaluate, then apply through `exec`.
    pub fn tick(&self, signals: &Signals, now: SimTime, exec: &dyn ScaleExecutor) -> Decision {
        let decision = self
            .controller
            .lock()
            .expect("autoscaler poisoned")
            .evaluate(signals, now);
        let result = match decision.action {
            Action::Hold => Ok(Vec::new()),
            Action::Up(n) => exec.scale_up(n),
            Action::Down(n) => exec.scale_down(n),
        };
        match result {
            Ok(ids) if !ids.is_empty() => {
                log::info!("autoscale: {} -> {:?}", decision.describe(), ids)
            }
            Ok(_) => {}
            Err(e) => {
                self.exec_errors.fetch_add(1, Ordering::Relaxed);
                log::warn!("autoscale: {} failed: {e:#}", decision.describe());
            }
        }
        decision
    }

    pub fn stats(&self) -> AutoscaleStats {
        self.controller.lock().expect("autoscaler poisoned").stats()
    }

    pub fn decisions(&self) -> Vec<Decision> {
        self.controller
            .lock()
            .expect("autoscaler poisoned")
            .decisions()
    }

    pub fn log_digest(&self) -> String {
        self.controller
            .lock()
            .expect("autoscaler poisoned")
            .log_digest()
    }

    pub fn exec_errors(&self) -> u64 {
        self.exec_errors.load(Ordering::Relaxed)
    }
}

/// Advisory executor for deployments whose nodes are provisioned
/// externally (`hardless serve`): decisions move a *virtual* node count
/// and are logged + surfaced through `cluster_stats`, telling the
/// operator (or an external orchestrator watching `hardless status`)
/// what the fleet should look like.
pub struct AdvisoryExecutor {
    nodes: AtomicUsize,
    floor: usize,
}

impl AdvisoryExecutor {
    pub fn new(initial: usize, floor: usize) -> AdvisoryExecutor {
        AdvisoryExecutor { nodes: AtomicUsize::new(initial), floor }
    }

    /// The advisory (virtual) node count decisions have accumulated to.
    pub fn nodes(&self) -> usize {
        self.nodes.load(Ordering::SeqCst)
    }
}

impl ScaleExecutor for AdvisoryExecutor {
    fn scale_up(&self, count: usize) -> Result<Vec<String>> {
        let after = self.nodes.fetch_add(count, Ordering::SeqCst) + count;
        Ok((after - count + 1..=after)
            .map(|i| format!("advisory-{i}"))
            .collect())
    }

    fn scale_down(&self, count: usize) -> Result<Vec<String>> {
        let mut removed = Vec::new();
        for _ in 0..count {
            let prev = self
                .nodes
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                    (n > self.floor).then_some(n - 1)
                });
            match prev {
                Ok(n) => removed.push(format!("advisory-{n}")),
                Err(_) => break,
            }
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SimClock;
    use crate::util::Clock;

    #[test]
    fn autoscale_stats_json_roundtrip() {
        let stats = AutoscaleStats {
            enabled: true,
            nodes: 3,
            target: 4,
            scale_ups: 7,
            scale_downs: 2,
            holds: 40,
            ticks: 49,
            last_action: "up+1".into(),
            last_reason: "class tinyyolo: depth 9 > 8 (4x2 nodes)".into(),
        };
        assert_eq!(AutoscaleStats::from_json(&stats.to_json()), stats);
    }

    #[test]
    fn autoscale_stats_parse_lenient_on_missing() {
        let parsed = AutoscaleStats::from_json(&Json::obj());
        assert_eq!(parsed, AutoscaleStats::default());
        assert!(!parsed.enabled);
    }

    #[test]
    fn advisory_executor_moves_virtual_fleet_within_floor() {
        let exec = AdvisoryExecutor::new(1, 1);
        assert_eq!(exec.scale_up(2).unwrap().len(), 2);
        assert_eq!(exec.nodes(), 3);
        assert_eq!(exec.scale_down(1).unwrap().len(), 1);
        assert_eq!(exec.nodes(), 2);
        // Floor stops the virtual fleet, even when asked for more.
        assert_eq!(exec.scale_down(5).unwrap().len(), 1);
        assert_eq!(exec.nodes(), 1);
        assert!(exec.scale_down(1).unwrap().is_empty());
    }

    #[test]
    fn tick_applies_decisions_through_the_executor() {
        let clock = SimClock::new();
        let scaler = Autoscaler::new(AutoscaleConfig {
            max_nodes: 4,
            ..AutoscaleConfig::default()
        });
        let exec = AdvisoryExecutor::new(0, 0);
        let signals = Signals { queued: 3, nodes: 0, ..Signals::default() };
        let d = scaler.tick(&signals, clock.now(), &exec);
        assert_eq!(d.action, Action::Up(1));
        assert_eq!(exec.nodes(), 1, "decision applied");
        assert_eq!(scaler.stats().scale_ups, 1);
        assert_eq!(scaler.exec_errors(), 0);
    }
}
