//! Autoscaler property tests — random load traces through the
//! controller, in the style of `queue/reference.rs`: a small passive
//! fleet model drives [`AutoscaleController`] with randomized arrival
//! traces and asserts the invariants that make the controller safe to
//! run unattended:
//!
//! * decisions never target a fleet outside `[min_nodes, max_nodes]`,
//!   and the applied node count never leaves those bounds;
//! * no up-then-down flip inside a `cooldown_down` window (and no two
//!   scale-outs inside `cooldown_up`);
//! * the same seed reproduces the same decision log, byte for byte
//!   (the [`crate::util::Rng`] trace generator and the controller are
//!   both deterministic).

use super::controller::{Action, AutoscaleController};
use super::{AutoscaleConfig, Signals};
use crate::prop;
use crate::queue::ClassStats;
use crate::util::clock::SimClock;
use crate::util::{Clock, SimTime};
use std::collections::VecDeque;
use std::time::Duration;

/// Passive single-class fleet model: per tick, each node serves up to
/// `slots` queued invocations (oldest first), then the controller sees
/// the resulting gauges.  Arrivals come from the random trace.
struct FleetModel {
    /// Enqueue times of queued invocations, oldest first.
    queued: VecDeque<SimTime>,
    nodes: usize,
    slots: usize,
}

impl FleetModel {
    fn step(&mut self, arrivals: usize, now: SimTime) -> Signals {
        let capacity = self.nodes * self.slots;
        for _ in 0..capacity.min(self.queued.len()) {
            self.queued.pop_front();
        }
        for _ in 0..arrivals {
            self.queued.push_back(now);
        }
        let classes = if self.queued.is_empty() {
            Vec::new()
        } else {
            vec![ClassStats {
                runtime: "tinyyolo".into(),
                queued: self.queued.len(),
                oldest_waiting_ms: now.since(self.queued[0]).as_millis() as u64,
                ..ClassStats::default()
            }]
        };
        Signals {
            queued: self.queued.len(),
            in_flight: 0,
            classes,
            nodes: self.nodes,
            free_slots: self.nodes * self.slots,
            warm_instances: 0,
        }
    }

    fn apply(&mut self, action: Action) {
        match action {
            Action::Hold => {}
            Action::Up(n) => self.nodes += n,
            Action::Down(n) => self.nodes = self.nodes.saturating_sub(n),
        }
    }
}

/// One full run over a trace; returns the controller for inspection.
fn run_trace(cfg: &AutoscaleConfig, trace: &[usize]) -> AutoscaleController {
    let clock = SimClock::new();
    let mut controller = AutoscaleController::new(cfg.clone());
    let mut fleet = FleetModel { queued: VecDeque::new(), nodes: cfg.min_nodes, slots: cfg.node_slots_hint };
    for &arrivals in trace {
        clock.advance(cfg.tick);
        let signals = fleet.step(arrivals, clock.now());
        let decision = controller.evaluate(&signals, clock.now());
        fleet.apply(decision.action);
    }
    controller
}

fn prop_cfg(min_nodes: usize) -> AutoscaleConfig {
    AutoscaleConfig {
        min_nodes,
        max_nodes: 6,
        up_depth_per_node: 4,
        up_oldest: Duration::from_secs(8),
        up_interactive_depth_per_node: 2,
        up_interactive_oldest: Duration::from_secs(3),
        down_idle: Duration::from_secs(6),
        cooldown_up: Duration::from_secs(3),
        cooldown_down: Duration::from_secs(10),
        node_slots_hint: 3,
        max_step_up: 3,
        tick: Duration::from_secs(1),
    }
}

#[test]
fn property_targets_never_leave_bounds() {
    prop::check(
        "autoscale-bounds",
        60,
        |rng| {
            let min = rng.below(3) as usize;
            // Bursty trace: mostly quiet, occasional heavy ticks.
            let trace: Vec<usize> = (0..rng.range(10, 120))
                .map(|_| if rng.chance(0.25) { rng.below(40) as usize } else { 0 })
                .collect();
            (min, trace)
        },
        |(min, trace)| {
            let cfg = prop_cfg(*min);
            let controller = run_trace(&cfg, trace);
            // Replay the applied node counts from the decision log.
            let mut nodes = cfg.min_nodes;
            for d in controller.decisions() {
                if d.target < cfg.min_nodes || d.target > cfg.max_nodes {
                    return false;
                }
                match d.action {
                    Action::Hold => {}
                    Action::Up(n) => nodes += n,
                    Action::Down(n) => {
                        if nodes < n {
                            return false;
                        }
                        nodes -= n;
                    }
                }
                if nodes != d.target || nodes > cfg.max_nodes || nodes < cfg.min_nodes {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn property_no_flip_inside_cooldown_windows() {
    prop::check(
        "autoscale-no-flip",
        60,
        |rng| {
            (0..rng.range(20, 150))
                .map(|_| if rng.chance(0.3) { rng.below(30) as usize } else { 0 })
                .collect::<Vec<usize>>()
        },
        |trace| {
            let cfg = prop_cfg(0);
            let controller = run_trace(&cfg, trace);
            let decisions = controller.decisions();
            for (i, d) in decisions.iter().enumerate() {
                match d.action {
                    // A scale-in must be at least cooldown_down after the
                    // most recent action in either direction.
                    Action::Down(_) => {
                        for prev in &decisions[..i] {
                            if !prev.action.is_hold()
                                && d.at.since(prev.at) < cfg.cooldown_down
                            {
                                return false;
                            }
                        }
                    }
                    // Successive scale-outs are spaced by cooldown_up.
                    Action::Up(_) => {
                        for prev in &decisions[..i] {
                            if matches!(prev.action, Action::Up(_))
                                && d.at.since(prev.at) < cfg.cooldown_up
                            {
                                return false;
                            }
                        }
                    }
                    Action::Hold => {}
                }
            }
            true
        },
    );
}

#[test]
fn property_same_seed_same_decision_log() {
    prop::check(
        "autoscale-deterministic",
        30,
        |rng| {
            (0..rng.range(10, 100))
                .map(|_| rng.below(20) as usize)
                .collect::<Vec<usize>>()
        },
        |trace| {
            let cfg = prop_cfg(1);
            let a = run_trace(&cfg, trace);
            let b = run_trace(&cfg, trace);
            a.log_digest() == b.log_digest() && !a.log_digest().is_empty()
        },
    );
}
