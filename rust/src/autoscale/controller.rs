//! The hysteresis controller — the autoscaler's pure decision core.
//!
//! [`AutoscaleController::evaluate`] maps one [`Signals`] sample and the
//! current [`SimTime`] to one [`Decision`].  It is deliberately free of
//! clocks, threads, and I/O: time is an argument, state is explicit, and
//! the decision log is append-only — which is what makes the scenario
//! suite (`rust/tests/autoscale_scenarios.rs`) and the property tests
//! (`reference.rs`) fully deterministic under [`crate::util::SimClock`].
//!
//! State machine (DESIGN.md §10):
//!
//! ```text
//!             pressure && !up-cooldown && nodes < max
//!   Steady ────────────────────────────────────────────▶ Up(step)
//!     ▲  ▲                                                  │
//!     │  └──────────── work arrives (idle timer resets) ◀───┘
//!     │ idle ≥ down_idle && !down-cooldown && nodes > min
//!     └────────────────────────────────────────────────▶ Down(1)
//! ```
//!
//! Hysteresis comes from three mechanisms: the up/down conditions use
//! different watermarks (depth pressure vs total idleness), each
//! direction has its own cooldown, and a scale-in is additionally gated
//! on `cooldown_down` having elapsed since the *last scale-out* — so an
//! up-then-down flip inside one cooldown window is impossible by
//! construction (asserted as a property in `reference.rs`).

use super::{AutoscaleConfig, AutoscaleStats, Signals};
use crate::util::SimTime;

/// What the controller wants done this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// No change (reason says why: steady, cooldown, at bound, ...).
    Hold,
    /// Add this many nodes.
    Up(usize),
    /// Retire this many (idlest-first) nodes.
    Down(usize),
}

impl Action {
    pub fn is_hold(&self) -> bool {
        matches!(self, Action::Hold)
    }

    /// Canonical rendering, shared by [`Decision::describe`] and the
    /// stats `last_action` field (tests pin both; they must not drift).
    pub fn render(&self) -> String {
        match self {
            Action::Hold => "hold".to_string(),
            Action::Up(n) => format!("up+{n}"),
            Action::Down(n) => format!("down-{n}"),
        }
    }
}

/// One evaluated tick: the action, the node count it targets, and a
/// human-readable reason (deterministic — part of the decision log).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decision {
    /// Evaluation tick ordinal (1 = first evaluate call).
    pub tick: u64,
    /// Sim time of the evaluation.
    pub at: SimTime,
    pub action: Action,
    /// Node count after the action is applied (= observed nodes on Hold).
    pub target: usize,
    pub reason: String,
}

impl Decision {
    /// Canonical one-line rendering (the unit of the reproducibility
    /// digest: same seed ⇒ same lines, byte for byte).
    pub fn describe(&self) -> String {
        format!(
            "#{} t={}ms {} -> {} nodes: {}",
            self.tick,
            self.at.as_micros() / 1000,
            self.action.render(),
            self.target,
            self.reason
        )
    }
}

/// How many decisions the log retains (a forever-running cluster must
/// not grow without bound; the counters stay exact regardless).
const LOG_RETENTION: usize = 4096;

/// The per-runtime-class closed-loop controller state.
pub struct AutoscaleController {
    cfg: AutoscaleConfig,
    /// When the system (queue + in-flight) last became empty.
    idle_since: Option<SimTime>,
    last_up: Option<SimTime>,
    last_down: Option<SimTime>,
    ticks: u64,
    ups: u64,
    downs: u64,
    holds: u64,
    log: std::collections::VecDeque<Decision>,
    /// Last observed node count and last decision target (stats surface).
    last_nodes: usize,
    last_target: usize,
}

impl AutoscaleController {
    pub fn new(cfg: AutoscaleConfig) -> AutoscaleController {
        assert!(cfg.min_nodes <= cfg.max_nodes, "min_nodes > max_nodes");
        AutoscaleController {
            last_target: cfg.min_nodes,
            cfg,
            idle_since: None,
            last_up: None,
            last_down: None,
            ticks: 0,
            ups: 0,
            downs: 0,
            holds: 0,
            log: std::collections::VecDeque::new(),
            last_nodes: 0,
        }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Evaluate one tick.  Pure in (signals, now, internal state); the
    /// caller applies the returned action through its `ScaleExecutor`.
    pub fn evaluate(&mut self, s: &Signals, now: SimTime) -> Decision {
        self.ticks += 1;
        // Idle tracking: the timer arms when the system empties and
        // resets the moment any work exists (queued or leased).
        if s.queued + s.in_flight > 0 {
            self.idle_since = None;
        } else if self.idle_since.is_none() {
            self.idle_since = Some(now);
        }

        let (action, target, reason) = self.decide(s, now);
        match action {
            Action::Up(_) => {
                self.last_up = Some(now);
                self.ups += 1;
            }
            Action::Down(_) => {
                self.last_down = Some(now);
                self.downs += 1;
            }
            Action::Hold => self.holds += 1,
        }
        let decision = Decision { tick: self.ticks, at: now, action, target, reason };
        self.last_nodes = s.nodes;
        self.last_target = target;
        self.log.push_back(decision.clone());
        while self.log.len() > LOG_RETENTION {
            self.log.pop_front();
        }
        decision
    }

    fn decide(&self, s: &Signals, now: SimTime) -> (Action, usize, String) {
        let cfg = &self.cfg;
        let nodes = s.nodes;

        // --- scale-out pressure -----------------------------------------
        let pressure = self.pressure(s);
        if let Some(reason) = pressure {
            if nodes >= cfg.max_nodes {
                return (Action::Hold, nodes, format!("at max ({}); {reason}", cfg.max_nodes));
            }
            if let Some(t) = self.last_up {
                let since = now.since(t);
                if since < cfg.cooldown_up {
                    return (
                        Action::Hold,
                        nodes,
                        format!("up-cooldown ({}ms < {}ms); {reason}",
                            since.as_millis(), cfg.cooldown_up.as_millis()),
                    );
                }
            }
            let step = self.up_step(s);
            return (Action::Up(step), nodes + step, reason);
        }

        // --- warm-floor replenishment (lost capacity, e.g. a dead node)
        // bypasses the pressure watermarks but not the up-cooldown.
        if nodes < cfg.min_nodes {
            let step = (cfg.min_nodes - nodes).min(cfg.max_step_up.max(1));
            if let Some(t) = self.last_up {
                let since = now.since(t);
                if since < cfg.cooldown_up {
                    return (
                        Action::Hold,
                        nodes,
                        format!("up-cooldown ({}ms); below warm floor {}",
                            since.as_millis(), cfg.min_nodes),
                    );
                }
            }
            return (
                Action::Up(step),
                nodes + step,
                format!("below warm floor ({nodes} < {})", cfg.min_nodes),
            );
        }

        // --- scale-in ---------------------------------------------------
        if nodes > cfg.min_nodes {
            let Some(since) = self.idle_since else {
                return (Action::Hold, nodes, "steady (work in flight)".to_string());
            };
            let idle = now.since(since);
            if idle < cfg.down_idle {
                return (
                    Action::Hold,
                    nodes,
                    format!("idle {}ms < {}ms", idle.as_millis(), cfg.down_idle.as_millis()),
                );
            }
            // Flip protection: no scale-in inside `cooldown_down` of the
            // last action in *either* direction.
            for (label, last) in [("up", self.last_up), ("down", self.last_down)] {
                if let Some(t) = last {
                    let since_action = now.since(t);
                    if since_action < cfg.cooldown_down {
                        return (
                            Action::Hold,
                            nodes,
                            format!("down-cooldown after {label} ({}ms < {}ms)",
                                since_action.as_millis(), cfg.cooldown_down.as_millis()),
                        );
                    }
                }
            }
            return (
                Action::Down(1),
                nodes - 1,
                format!("idle {}ms >= {}ms", idle.as_millis(), cfg.down_idle.as_millis()),
            );
        }

        let reason = if nodes == cfg.min_nodes && cfg.min_nodes > 0 {
            format!("at warm floor ({})", cfg.min_nodes)
        } else {
            "steady".to_string()
        };
        (Action::Hold, nodes, reason)
    }

    /// The per-class scan: the scale-from-zero guard, then the two
    /// *interactive* high watermarks (tighter, checked first so
    /// latency-class backlog drives scale-out before raw batch depth),
    /// then the two general ones — O(|classes|) comparisons total.
    /// Returns the first (deterministic — classes arrive sorted)
    /// triggering reason.
    fn pressure(&self, s: &Signals) -> Option<String> {
        let cfg = &self.cfg;
        if s.nodes == 0 && s.queued + s.in_flight > 0 {
            return Some(format!(
                "work with zero nodes (queued {}, in-flight {})",
                s.queued, s.in_flight
            ));
        }
        // Interactive watermarks: guarded on interactive_queued > 0, so
        // batch-only traffic is judged purely by the general watermarks
        // below (and pre-QoS peers, whose stats parse to 0, are inert).
        let i_depth_limit = cfg.up_interactive_depth_per_node * s.nodes.max(1);
        let i_age_limit_ms = cfg.up_interactive_oldest.as_millis() as u64;
        for c in &s.classes {
            if c.interactive_queued == 0 {
                continue;
            }
            if c.interactive_queued > i_depth_limit {
                return Some(format!(
                    "class {}: interactive depth {} > {} ({}x{} nodes)",
                    c.runtime,
                    c.interactive_queued,
                    i_depth_limit,
                    cfg.up_interactive_depth_per_node,
                    s.nodes.max(1)
                ));
            }
            if c.interactive_oldest_ms >= i_age_limit_ms {
                return Some(format!(
                    "class {}: interactive oldest waiting {}ms >= {}ms",
                    c.runtime, c.interactive_oldest_ms, i_age_limit_ms
                ));
            }
        }
        let depth_limit = cfg.up_depth_per_node * s.nodes.max(1);
        let age_limit_ms = cfg.up_oldest.as_millis() as u64;
        for c in &s.classes {
            if c.queued > depth_limit {
                return Some(format!(
                    "class {}: depth {} > {} ({}x{} nodes)",
                    c.runtime,
                    c.queued,
                    depth_limit,
                    cfg.up_depth_per_node,
                    s.nodes.max(1)
                ));
            }
            if c.queued > 0 && c.oldest_waiting_ms >= age_limit_ms {
                return Some(format!(
                    "class {}: oldest waiting {}ms >= {}ms",
                    c.runtime, c.oldest_waiting_ms, age_limit_ms
                ));
            }
        }
        None
    }

    /// Size the scale-out to the backlog the current free slots cannot
    /// absorb, in units of `node_slots_hint`, clamped to
    /// `[1, max_step_up]` and the max-nodes bound.
    fn up_step(&self, s: &Signals) -> usize {
        let cfg = &self.cfg;
        let hint = cfg.node_slots_hint.max(1);
        let deficit = s.queued.saturating_sub(s.free_slots);
        let wanted = deficit.div_ceil(hint);
        wanted
            .min(cfg.max_step_up.max(1))
            .min(cfg.max_nodes - s.nodes)
            .max(1)
    }

    /// Retained decisions, oldest first.
    pub fn decisions(&self) -> Vec<Decision> {
        self.log.iter().cloned().collect()
    }

    /// The reproducibility digest: every retained decision rendered by
    /// [`Decision::describe`], newline-joined.  Two runs over the same
    /// trace must produce identical digests, byte for byte.
    pub fn log_digest(&self) -> String {
        let mut out = String::new();
        for d in &self.log {
            out.push_str(&d.describe());
            out.push('\n');
        }
        out
    }

    pub fn stats(&self) -> AutoscaleStats {
        let last = self.log.back();
        AutoscaleStats {
            enabled: true,
            nodes: self.last_nodes,
            target: self.last_target,
            scale_ups: self.ups,
            scale_downs: self.downs,
            holds: self.holds,
            ticks: self.ticks,
            last_action: last.map(|d| d.action.render()).unwrap_or_default(),
            last_reason: last.map(|d| d.reason.clone()).unwrap_or_default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::ClassStats;
    use crate::util::clock::SimClock;
    use crate::util::Clock;
    use std::time::Duration;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_nodes: 0,
            max_nodes: 4,
            up_depth_per_node: 4,
            up_oldest: Duration::from_secs(10),
            up_interactive_depth_per_node: 2,
            up_interactive_oldest: Duration::from_secs(3),
            down_idle: Duration::from_secs(5),
            cooldown_up: Duration::from_secs(2),
            cooldown_down: Duration::from_secs(8),
            node_slots_hint: 4,
            max_step_up: 2,
            tick: Duration::from_secs(1),
        }
    }

    fn signals(nodes: usize, queued: usize, oldest_ms: u64) -> Signals {
        Signals {
            queued,
            in_flight: 0,
            classes: if queued > 0 {
                vec![ClassStats {
                    runtime: "tinyyolo".into(),
                    queued,
                    oldest_waiting_ms: oldest_ms,
                    interactive_queued: 0,
                    interactive_oldest_ms: 0,
                }]
            } else {
                Vec::new()
            },
            nodes,
            free_slots: 0,
            warm_instances: 0,
        }
    }

    #[test]
    fn scales_up_from_zero_on_any_work() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        let d = c.evaluate(&signals(0, 1, 0), clock.now());
        assert_eq!(d.action, Action::Up(1), "{d:?}");
        assert_eq!(d.target, 1);
        assert!(d.reason.contains("zero nodes"), "{}", d.reason);
    }

    #[test]
    fn depth_watermark_scales_with_node_count() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        // 2 nodes, depth 8 = 4/node x 2: at the watermark, not above it.
        let d = c.evaluate(&signals(2, 8, 0), clock.now());
        assert!(d.action.is_hold(), "{d:?}");
        // depth 9 crosses it.
        clock.advance(Duration::from_secs(3));
        let d = c.evaluate(&signals(2, 9, 0), clock.now());
        assert_eq!(d.action, Action::Up(2), "deficit 9 over hint 4 -> 2 (capped): {d:?}");
    }

    #[test]
    fn interactive_depth_triggers_below_the_general_watermark() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        // 2 nodes: general limit 4x2=8, interactive limit 2x2=4.  Total
        // depth 6 is under the general watermark — batch-only holds...
        let d = c.evaluate(&signals(2, 6, 0), clock.now());
        assert!(d.action.is_hold(), "{d:?}");
        // ...but the same depth with 5 interactive crosses the tighter
        // interactive watermark.
        let mut c = AutoscaleController::new(cfg());
        let mut s = signals(2, 6, 0);
        s.classes[0].interactive_queued = 5;
        let d = c.evaluate(&s, clock.now());
        assert!(matches!(d.action, Action::Up(_)), "{d:?}");
        assert!(d.reason.contains("interactive depth"), "{}", d.reason);
    }

    #[test]
    fn interactive_age_triggers_below_the_general_age_bound() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        // 3s-old head: far under up_oldest (10s) — holds as batch...
        let d = c.evaluate(&signals(2, 1, 3_000), clock.now());
        assert!(d.action.is_hold(), "{d:?}");
        // ...but 3s of *interactive* waiting hits up_interactive_oldest.
        let mut c = AutoscaleController::new(cfg());
        let mut s = signals(2, 1, 3_000);
        s.classes[0].interactive_queued = 1;
        s.classes[0].interactive_oldest_ms = 3_000;
        let d = c.evaluate(&s, clock.now());
        assert!(matches!(d.action, Action::Up(_)), "{d:?}");
        assert!(d.reason.contains("interactive oldest"), "{}", d.reason);
    }

    #[test]
    fn oldest_age_triggers_even_at_shallow_depth() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        let d = c.evaluate(&signals(2, 1, 10_000), clock.now());
        assert_eq!(d.action, Action::Up(1), "{d:?}");
        assert!(d.reason.contains("oldest waiting"), "{}", d.reason);
    }

    #[test]
    fn up_cooldown_holds_then_releases() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        assert_eq!(c.evaluate(&signals(0, 9, 0), clock.now()).action, Action::Up(2));
        clock.advance(Duration::from_secs(1));
        let d = c.evaluate(&signals(2, 9, 0), clock.now());
        assert!(d.action.is_hold(), "inside cooldown_up: {d:?}");
        assert!(d.reason.contains("up-cooldown"), "{}", d.reason);
        clock.advance(Duration::from_secs(1));
        let d = c.evaluate(&signals(2, 20, 0), clock.now());
        assert_eq!(d.action, Action::Up(2), "cooldown elapsed: {d:?}");
    }

    #[test]
    fn never_targets_above_max() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        let d = c.evaluate(&signals(4, 500, 60_000), clock.now());
        assert!(d.action.is_hold(), "{d:?}");
        assert!(d.reason.contains("at max"), "{}", d.reason);
        assert_eq!(d.target, 4);
    }

    #[test]
    fn scale_to_zero_after_idle_and_cooldowns() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        // Busy, then empty: the idle timer arms on the first empty tick.
        c.evaluate(&signals(1, 2, 0), clock.now());
        clock.advance(Duration::from_secs(3));
        let d = c.evaluate(&signals(1, 0, 0), clock.now());
        assert!(d.action.is_hold(), "idle timer just armed: {d:?}");
        // 5s idle but still < cooldown_down=8s... no prior up/down action
        // besides none, so only idle gates.
        clock.advance(Duration::from_secs(5));
        let d = c.evaluate(&signals(1, 0, 0), clock.now());
        assert_eq!(d.action, Action::Down(1), "{d:?}");
        assert_eq!(d.target, 0, "scale-to-zero with min_nodes = 0");
    }

    #[test]
    fn warm_floor_blocks_scale_in_and_replenishes() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(AutoscaleConfig { min_nodes: 1, ..cfg() });
        // At the floor, long idle: hold, not down.
        clock.advance(Duration::from_secs(60));
        let d = c.evaluate(&signals(1, 0, 0), clock.now());
        assert!(d.action.is_hold(), "{d:?}");
        assert!(d.reason.contains("warm floor"), "{}", d.reason);
        // Below the floor (node died): replenish without pressure.
        clock.advance(Duration::from_secs(1));
        let d = c.evaluate(&signals(0, 0, 0), clock.now());
        assert_eq!(d.action, Action::Up(1), "{d:?}");
        assert!(d.reason.contains("below warm floor"), "{}", d.reason);
    }

    #[test]
    fn idle_timer_resets_on_new_work() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        c.evaluate(&signals(1, 0, 0), clock.now()); // idle arms at t=0
        clock.advance(Duration::from_secs(4));
        c.evaluate(&signals(1, 1, 0), clock.now()); // work: timer resets
        clock.advance(Duration::from_secs(4));
        // 4s since the queue emptied again (at most) — below down_idle.
        let d = c.evaluate(&signals(1, 0, 0), clock.now());
        assert!(d.action.is_hold(), "{d:?}");
    }

    #[test]
    fn down_cooldown_spaces_successive_scale_ins() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        clock.advance(Duration::from_secs(10));
        assert_eq!(c.evaluate(&signals(3, 0, 0), clock.now()).action, Action::Hold);
        clock.advance(Duration::from_secs(10));
        assert_eq!(c.evaluate(&signals(3, 0, 0), clock.now()).action, Action::Down(1));
        clock.advance(Duration::from_secs(2));
        let d = c.evaluate(&signals(2, 0, 0), clock.now());
        assert!(d.action.is_hold(), "{d:?}");
        assert!(d.reason.contains("down-cooldown"), "{}", d.reason);
        clock.advance(Duration::from_secs(8));
        assert_eq!(c.evaluate(&signals(2, 0, 0), clock.now()).action, Action::Down(1));
    }

    #[test]
    fn stats_and_digest_reflect_the_log() {
        let clock = SimClock::new();
        let mut c = AutoscaleController::new(cfg());
        c.evaluate(&signals(0, 9, 0), clock.now());
        clock.advance(Duration::from_secs(5));
        c.evaluate(&signals(2, 0, 0), clock.now());
        let s = c.stats();
        assert!(s.enabled);
        assert_eq!(s.ticks, 2);
        assert_eq!(s.scale_ups, 1);
        assert_eq!(s.holds, 1);
        assert_eq!(s.nodes, 2);
        let digest = c.log_digest();
        assert_eq!(digest.lines().count(), 2, "{digest}");
        assert!(digest.starts_with("#1 t=0ms up+2 -> 2 nodes:"), "{digest}");
    }
}
