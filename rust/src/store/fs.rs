//! Filesystem object store backend.
//!
//! Durable variant of the store: objects live under a root directory with
//! the key as relative path (keys are validated against traversal in
//! [`super::validate_key`]).  Writes are atomic (temp file + rename) so a
//! crashed node never leaves a half-written runtime bundle for others.

use super::{validate_key, Blob, ObjectStore};
use anyhow::{bail, Context, Result};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Object store rooted at a directory.
pub struct FsStore {
    root: PathBuf,
    tmp_counter: AtomicU64,
}

impl FsStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<FsStore> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root).with_context(|| format!("create store root {root:?}"))?;
        Ok(FsStore { root, tmp_counter: AtomicU64::new(0) })
    }

    fn path_of(&self, key: &str) -> Result<PathBuf> {
        validate_key(key)?;
        Ok(self.root.join(key))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }
}

impl ObjectStore for FsStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let path = self.path_of(key)?;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        // Atomic publish: write to a unique temp name, then rename.
        let tmp = self.root.join(format!(
            ".tmp.{}.{}",
            std::process::id(),
            self.tmp_counter.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, data).with_context(|| format!("write {tmp:?}"))?;
        fs::rename(&tmp, &path).with_context(|| format!("publish {path:?}"))?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Blob> {
        let path = self.path_of(key)?;
        if !path.is_file() {
            bail!("object not found: {key}");
        }
        let bytes = fs::read(&path).with_context(|| format!("read {path:?}"))?;
        Ok(Blob::from(bytes))
    }

    fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.path_of(key)?.is_file())
    }

    fn delete(&self, key: &str) -> Result<()> {
        let path = self.path_of(key)?;
        match fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        walk(&self.root, &self.root, &mut out)?;
        out.retain(|k| k.starts_with(prefix) && !k.starts_with(".tmp."));
        out.sort();
        Ok(out)
    }
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if let Ok(rel) = path.strip_prefix(root) {
            out.push(rel.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::conformance;

    fn tmp_store(name: &str) -> FsStore {
        let dir = std::env::temp_dir().join(format!(
            "hardless-fsstore-{name}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        FsStore::open(dir).unwrap()
    }

    #[test]
    fn conformance_suite() {
        let s = tmp_store("conf");
        conformance::run_all(&s);
        let _ = fs::remove_dir_all(s.root());
    }

    #[test]
    fn persists_across_reopen() {
        let s = tmp_store("reopen");
        let root = s.root().to_path_buf();
        s.put("datasets/x", b"payload").unwrap();
        drop(s);
        let s2 = FsStore::open(&root).unwrap();
        assert_eq!(s2.get("datasets/x").unwrap(), b"payload");
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn nested_keys_make_directories() {
        let s = tmp_store("nest");
        s.put("a/b/c/d", b"deep").unwrap();
        assert_eq!(s.get("a/b/c/d").unwrap(), b"deep");
        assert_eq!(s.list("a/b/").unwrap(), vec!["a/b/c/d".to_string()]);
        let _ = fs::remove_dir_all(s.root());
    }

    #[test]
    fn traversal_cannot_escape_root() {
        let s = tmp_store("trav");
        assert!(s.put("../escape", b"x").is_err());
        assert!(s.get("../../etc/passwd").is_err());
        let _ = fs::remove_dir_all(s.root());
    }

    #[test]
    fn temp_files_not_listed() {
        let s = tmp_store("tmpfiles");
        // simulate a crashed write
        fs::write(s.root().join(".tmp.999.0"), b"junk").unwrap();
        s.put("real/key", b"x").unwrap();
        let keys = s.list("").unwrap();
        assert_eq!(keys, vec!["real/key".to_string()]);
        let _ = fs::remove_dir_all(s.root());
    }
}
