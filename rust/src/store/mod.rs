//! Object storage — the role Minio plays in the paper's prototype.
//!
//! Paper §IV-D: *"Object storage is used in this architecture to store
//! runtime implementations, input configuration, and input data."*  The
//! HARDLESS data flow is strictly stateless: the benchmark client `put`s
//! datasets, node managers `get` runtime bundles + datasets before running
//! and `put` results before completing.
//!
//! Three backends share one trait: [`MemStore`] (in-process, used by unit
//! tests and single-machine experiments), [`FsStore`] (durable, content
//! verified), and [`remote::StoreClient`] (TCP, served by
//! [`remote::StoreServer`] — the distributed deployment).
//!
//! Keys are namespaced by convention: `runtimes/...`, `datasets/...`,
//! `results/...` (helpers below).

pub mod cache;
pub mod fs;
pub mod mem;
pub mod remote;

pub use cache::{CacheStats, CachedStore, DecodedCache};
pub use fs::FsStore;
pub use mem::MemStore;
pub use remote::{StoreClient, StoreServer};

use anyhow::Result;
use sha2::{Digest, Sha256};
use std::sync::Arc;

/// An immutable, cheaply cloneable byte buffer — the unit the data plane
/// moves around.  Backed by `Arc<[u8]>`: cloning a `Blob` is a refcount
/// bump, so a cached dataset can be handed to N workers (and to the wire
/// writer) without copying the payload.  `Deref<Target = [u8]>` keeps
/// call sites byte-slice-shaped.
#[derive(Clone)]
pub struct Blob(Arc<[u8]>);

impl Blob {
    /// True when `a` and `b` share the same underlying allocation (the
    /// zero-copy property tests assert on).
    pub fn ptr_eq(a: &Blob, b: &Blob) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.0
    }

    /// Copy out an owned `Vec<u8>` (boundary crossings that need one).
    pub fn to_vec(&self) -> Vec<u8> {
        self.0.to_vec()
    }
}

impl std::ops::Deref for Blob {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl AsRef<[u8]> for Blob {
    fn as_ref(&self) -> &[u8] {
        &self.0
    }
}

impl From<Vec<u8>> for Blob {
    fn from(v: Vec<u8>) -> Blob {
        Blob(v.into())
    }
}

impl From<&[u8]> for Blob {
    fn from(v: &[u8]) -> Blob {
        Blob(v.into())
    }
}

impl std::fmt::Debug for Blob {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let head: Vec<u8> = self.0.iter().copied().take(8).collect();
        write!(f, "Blob({} bytes, {head:02x?}..)", self.0.len())
    }
}

impl PartialEq for Blob {
    fn eq(&self, other: &Blob) -> bool {
        self.0 == other.0
    }
}

impl Eq for Blob {}

impl PartialEq<[u8]> for Blob {
    fn eq(&self, other: &[u8]) -> bool {
        self.0.as_ref() == other
    }
}

impl PartialEq<&[u8]> for Blob {
    fn eq(&self, other: &&[u8]) -> bool {
        self.0.as_ref() == *other
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for Blob {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.0.as_ref() == other.as_slice()
    }
}

impl PartialEq<Vec<u8>> for Blob {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.0.as_ref() == other.as_slice()
    }
}

/// Namespace helpers (bucket conventions).
pub mod keys {
    pub fn runtime(name: &str) -> String {
        format!("runtimes/{name}")
    }
    pub fn dataset(name: &str) -> String {
        format!("datasets/{name}")
    }
    pub fn result(invocation_id: &str) -> String {
        format!("results/{invocation_id}")
    }
}

/// Blob storage interface shared by all backends.
pub trait ObjectStore: Send + Sync {
    /// Store `data` under `key` (overwrites).
    fn put(&self, key: &str, data: &[u8]) -> Result<()>;

    /// Fetch the object at `key` as a shared immutable buffer.  Backends
    /// that hold bytes in memory ([`MemStore`], [`CachedStore`]) hand out
    /// clones of the same allocation — no per-get copy.
    fn get(&self, key: &str) -> Result<Blob>;

    fn exists(&self, key: &str) -> Result<bool>;

    fn delete(&self, key: &str) -> Result<()>;

    /// Keys under a prefix (sorted).
    fn list(&self, prefix: &str) -> Result<Vec<String>>;

    /// Content-addressed put: stores under `cas/<sha256>` and returns the
    /// key.  Used for runtime bundles so identical uploads dedupe —
    /// re-publishing a runtime is free, which the paper's warm-start story
    /// depends on.
    fn put_cas(&self, data: &[u8]) -> Result<String> {
        let key = format!("cas/{}", hex_sha256(data));
        if !self.exists(&key)? {
            self.put(&key, data)?;
        }
        Ok(key)
    }
}

/// Lowercase hex SHA-256 of `data`.  Hex via a static nibble table — this
/// runs over multi-MB bundles on every `put_cas`, so no per-byte heap
/// formatting.
pub fn hex_sha256(data: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut h = Sha256::new();
    h.update(data);
    let out = h.finalize();
    let mut s = Vec::with_capacity(64);
    for b in out {
        s.push(HEX[(b >> 4) as usize]);
        s.push(HEX[(b & 0x0f) as usize]);
    }
    String::from_utf8(s).expect("hex is ascii")
}

/// Validate a key: non-empty, no traversal, printable ascii subset.
/// Enforced by every backend so FsStore keys can map to paths safely.
pub fn validate_key(key: &str) -> Result<()> {
    anyhow::ensure!(!key.is_empty(), "empty object key");
    anyhow::ensure!(key.len() <= 512, "object key too long");
    anyhow::ensure!(!key.starts_with('/'), "absolute object key: {key}");
    for part in key.split('/') {
        anyhow::ensure!(!part.is_empty(), "empty path segment in key: {key}");
        anyhow::ensure!(part != "." && part != "..", "path traversal in key: {key}");
    }
    anyhow::ensure!(
        key.bytes().all(|b| b.is_ascii_alphanumeric() || b"-_./[]".contains(&b)),
        "invalid character in object key: {key}"
    );
    Ok(())
}

#[cfg(test)]
pub(crate) mod conformance {
    //! Backend-agnostic conformance suite, run against every backend.
    use super::*;

    pub fn run_all(store: &dyn ObjectStore) {
        put_get_roundtrip(store);
        overwrite(store);
        overwrite_after_read(store);
        delete_invalidates_reads(store);
        missing_get_errors(store);
        exists_and_delete(store);
        list_by_prefix(store);
        cas_dedupes(store);
        rejects_bad_keys(store);
        empty_and_large_values(store);
    }

    fn put_get_roundtrip(s: &dyn ObjectStore) {
        s.put("datasets/a", b"hello").unwrap();
        assert_eq!(s.get("datasets/a").unwrap(), b"hello");
    }

    fn overwrite(s: &dyn ObjectStore) {
        s.put("datasets/ow", b"v1").unwrap();
        s.put("datasets/ow", b"v2").unwrap();
        assert_eq!(s.get("datasets/ow").unwrap(), b"v2");
    }

    /// Overwrite *after* a read: a caching decorator must invalidate what
    /// the first `get` populated, never serve the stale buffer.
    fn overwrite_after_read(s: &dyn ObjectStore) {
        s.put("datasets/oar", b"old").unwrap();
        assert_eq!(s.get("datasets/oar").unwrap(), b"old");
        s.put("datasets/oar", b"new").unwrap();
        assert_eq!(s.get("datasets/oar").unwrap(), b"new");
    }

    /// Delete after a read: the key must become a hard miss (not a cached
    /// hit), and a later re-put must be visible.
    fn delete_invalidates_reads(s: &dyn ObjectStore) {
        s.put("tmp/di", b"v1").unwrap();
        assert_eq!(s.get("tmp/di").unwrap(), b"v1");
        s.delete("tmp/di").unwrap();
        assert!(s.get("tmp/di").is_err(), "deleted key must not read back");
        s.put("tmp/di", b"v2").unwrap();
        assert_eq!(s.get("tmp/di").unwrap(), b"v2");
    }

    fn missing_get_errors(s: &dyn ObjectStore) {
        assert!(s.get("nope/missing").is_err());
    }

    fn exists_and_delete(s: &dyn ObjectStore) {
        s.put("tmp/x", b"x").unwrap();
        assert!(s.exists("tmp/x").unwrap());
        s.delete("tmp/x").unwrap();
        assert!(!s.exists("tmp/x").unwrap());
        // deleting a missing key is idempotent
        s.delete("tmp/x").unwrap();
    }

    fn list_by_prefix(s: &dyn ObjectStore) {
        s.put("runtimes/r1", b"1").unwrap();
        s.put("runtimes/r2", b"2").unwrap();
        s.put("results/z", b"3").unwrap();
        let keys = s.list("runtimes/").unwrap();
        assert!(keys.contains(&"runtimes/r1".to_string()), "{keys:?}");
        assert!(keys.contains(&"runtimes/r2".to_string()));
        assert!(!keys.iter().any(|k| k.starts_with("results/")));
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "list must be sorted");
    }

    fn cas_dedupes(s: &dyn ObjectStore) {
        let k1 = s.put_cas(b"bundle-bytes").unwrap();
        let k2 = s.put_cas(b"bundle-bytes").unwrap();
        assert_eq!(k1, k2);
        assert!(k1.starts_with("cas/"));
        assert_eq!(s.get(&k1).unwrap(), b"bundle-bytes");
    }

    fn rejects_bad_keys(s: &dyn ObjectStore) {
        for bad in ["", "/abs", "a//b", "../up", "a/../b", "sp ace", "null\0"] {
            assert!(s.put(bad, b"x").is_err(), "should reject key {bad:?}");
        }
    }

    fn empty_and_large_values(s: &dyn ObjectStore) {
        s.put("datasets/empty", b"").unwrap();
        assert_eq!(s.get("datasets/empty").unwrap(), b"");
        let big = vec![0xAB; 3 * 1024 * 1024];
        s.put("datasets/big", &big).unwrap();
        assert_eq!(s.get("datasets/big").unwrap(), big);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_clone_is_zero_copy() {
        let b = Blob::from(vec![1u8, 2, 3]);
        let c = b.clone();
        assert!(Blob::ptr_eq(&b, &c), "clone must share the allocation");
        assert_eq!(b, c);
        assert_eq!(b, &[1u8, 2, 3][..]);
        assert_eq!(b.len(), 3);
        let d = Blob::from(vec![1u8, 2, 3]);
        assert_eq!(b, d, "value equality across allocations");
        assert!(!Blob::ptr_eq(&b, &d), "distinct allocations");
    }

    #[test]
    fn sha256_known_vector() {
        assert_eq!(
            hex_sha256(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn key_validation() {
        assert!(validate_key("datasets/img-1").is_ok());
        assert!(validate_key("cas/0abc").is_ok());
        assert!(validate_key("weights[0].bin").is_ok());
        assert!(validate_key("/etc/passwd").is_err());
        assert!(validate_key("a/./b").is_err());
        assert!(validate_key("a/../../b").is_err());
        assert!(validate_key("").is_err());
        assert!(validate_key(&"x".repeat(600)).is_err());
    }

    #[test]
    fn key_helpers() {
        assert_eq!(keys::runtime("tinyyolo"), "runtimes/tinyyolo");
        assert_eq!(keys::dataset("img"), "datasets/img");
        assert_eq!(keys::result("inv-1"), "results/inv-1");
    }
}
