//! Node-local content cache with single-flight fetch — the data-plane
//! answer to the ship-data-to-code anti-pattern (Berkeley View §4): under
//! the paper's protocol the same TinyYOLO input is fetched thousands of
//! times, so every node keeps a bounded read-through cache in front of
//! the (possibly remote) object store.
//!
//! * [`CachedStore`] decorates any [`ObjectStore`]: `get` is served from
//!   a bytes-budgeted LRU of shared [`Blob`]s (a hit is an `Arc` clone —
//!   no copy, no RPC); concurrent cold-starts on one key coalesce into
//!   exactly one backing fetch (waiters park on a condvar); `put`/`delete`
//!   through the decorator invalidate, and an invalidation racing an
//!   in-flight fetch poisons it so a stale buffer is never cached.
//!   `cas/…` keys are content-addressed and therefore immutable — they
//!   cache pinned (evicted only when nothing unpinned is left) and
//!   `put_cas` seeds them without a read-back.
//! * [`DecodedCache`] sits one layer up: workers decode dataset bytes to
//!   `Arc<Vec<f32>>` once per distinct buffer per node, keyed by object
//!   key and verified by buffer identity, so a cache-invalidated refetch
//!   re-decodes while steady-state invocations skip the bytes→f32 pass
//!   entirely.
//!
//! Caveat (documented contract, same as the paper's Minio): invalidation
//! is local to writes issued *through this decorator*.  Datasets and
//! results are write-once by protocol convention; a foreign writer
//! mutating an object behind a node's cache is out of scope.

use super::{hex_sha256, Blob, ObjectStore};
use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Counters a cache exposes (surfaced through `cluster_stats`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// `get`s served from the cache (includes `put_cas` dedupe hits).
    pub hits: u64,
    /// `get`s that went to the backing store.
    pub misses: u64,
    /// Entries dropped to stay under the bytes budget.
    pub evictions: u64,
    /// `get`s that parked on another caller's in-flight fetch instead of
    /// issuing their own (the single-flight win).
    pub coalesced: u64,
    /// Current entry count (gauge).
    pub entries: u64,
    /// Current cached bytes (gauge).
    pub bytes: u64,
}

impl CacheStats {
    /// Accumulate another cache's counters (cluster-level aggregation).
    pub fn add(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.coalesced += other.coalesced;
        self.entries += other.entries;
        self.bytes += other.bytes;
    }
}

struct Entry {
    blob: Blob,
    tick: u64,
    pinned: bool,
}

#[derive(Default)]
struct CacheState {
    map: HashMap<String, Entry>,
    /// Eviction order for unpinned entries (tick → key).
    lru: BTreeMap<u64, String>,
    /// Pinned (`cas/…`) entries, evicted only when `lru` is empty.
    pinned_lru: BTreeMap<u64, String>,
    tick: u64,
    bytes: usize,
    evictions: u64,
    /// Bumped whenever the cached **key-set** changes (insert, eviction,
    /// invalidation) — recency bumps don't count.  Lets a hot-set
    /// consumer (DESIGN.md §15) drop out-of-order summaries and skip
    /// recomputing an unchanged one.
    generation: u64,
}

impl CacheState {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Cache lookup; bumps recency on hit.
    fn lookup(&mut self, key: &str) -> Option<Blob> {
        let tick = self.next_tick();
        let entry = self.map.get_mut(key)?;
        let order = if entry.pinned { &mut self.pinned_lru } else { &mut self.lru };
        // reuse the removed key String — this is the per-hit hot path
        let owned = order.remove(&entry.tick).unwrap_or_else(|| key.to_string());
        order.insert(tick, owned);
        entry.tick = tick;
        Some(entry.blob.clone())
    }

    fn remove(&mut self, key: &str) {
        if let Some(e) = self.map.remove(key) {
            self.bytes -= e.blob.len();
            if e.pinned {
                self.pinned_lru.remove(&e.tick);
            } else {
                self.lru.remove(&e.tick);
            }
            self.generation += 1;
        }
    }

    /// Insert `blob` under `key` and evict LRU-first until the budget
    /// holds.  Oversized objects (> budget) are not cached at all.
    fn insert(&mut self, key: &str, blob: Blob, pinned: bool, budget: usize) {
        if blob.len() > budget {
            return;
        }
        self.remove(key);
        let tick = self.next_tick();
        self.bytes += blob.len();
        let order = if pinned { &mut self.pinned_lru } else { &mut self.lru };
        order.insert(tick, key.to_string());
        self.map.insert(key.to_string(), Entry { blob, tick, pinned });
        self.generation += 1;
        while self.bytes > budget {
            let victim = match self.lru.keys().next().copied() {
                Some(t) => self.lru.remove(&t).expect("lru entry"),
                // unpinned exhausted: pinned entries go too rather than
                // blowing the budget
                None => match self.pinned_lru.keys().next().copied() {
                    Some(t) => self.pinned_lru.remove(&t).expect("pinned entry"),
                    None => break,
                },
            };
            let e = self.map.remove(&victim).expect("map entry");
            self.bytes -= e.blob.len();
            self.evictions += 1;
            self.generation += 1;
        }
    }

    /// Top-`k` most-recently-used cached keys (pinned and unpinned
    /// merged by recency, newest first).  O(k) — two reverse BTreeMap
    /// cursors, no allocation beyond the output.
    fn hot_keys(&self, k: usize) -> Vec<String> {
        let mut un = self.lru.iter().rev().peekable();
        let mut pin = self.pinned_lru.iter().rev().peekable();
        let mut out = Vec::with_capacity(k.min(self.map.len()));
        while out.len() < k {
            let take_unpinned = match (un.peek(), pin.peek()) {
                (Some((tu, _)), Some((tp, _))) => tu > tp,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            let (_, key) = if take_unpinned {
                un.next().expect("peeked")
            } else {
                pin.next().expect("peeked")
            };
            out.push(key.clone());
        }
        out
    }
}

/// One in-flight backing fetch; waiters park on `cv` until the leader
/// publishes into `done`.
struct Flight {
    done: Mutex<Option<std::result::Result<Blob, String>>>,
    cv: Condvar,
    /// Set by an invalidation (`put`/`delete`) racing this fetch: the
    /// fetched bytes may be stale, so the leader must not cache them.
    poisoned: AtomicBool,
    /// Callers parked on this flight.  Incremented under the `inflight`
    /// lock at registration, so a publisher holding that lock reads a
    /// final count (lets `put_cas` skip materializing a payload nobody
    /// will read).
    waiters: AtomicU64,
}

impl Flight {
    fn new() -> Flight {
        Flight {
            done: Mutex::new(None),
            cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
            waiters: AtomicU64::new(0),
        }
    }
}

/// Read-through caching decorator over any [`ObjectStore`] backend.
///
/// Lock order (must never be reversed): `inflight` → `state`.
pub struct CachedStore {
    inner: Arc<dyn ObjectStore>,
    budget: usize,
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
    state: Mutex<CacheState>,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

fn is_immutable(key: &str) -> bool {
    key.starts_with("cas/")
}

impl CachedStore {
    /// Wrap `inner` with a cache bounded to `budget_bytes` of payload.
    pub fn new(inner: Arc<dyn ObjectStore>, budget_bytes: usize) -> CachedStore {
        CachedStore {
            inner,
            budget: budget_bytes,
            inflight: Mutex::new(HashMap::new()),
            state: Mutex::new(CacheState::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }

    pub fn stats(&self) -> CacheStats {
        let state = self.state.lock().expect("cache poisoned");
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: state.evictions,
            coalesced: self.coalesced.load(Ordering::Relaxed),
            entries: state.map.len() as u64,
            bytes: state.bytes as u64,
        }
    }

    /// Per-node hot-set summary (DESIGN.md §15): the top-`k`
    /// most-recently-used cached keys, newest first, plus the cache
    /// generation they were sampled at.  This is what the node gossips
    /// on completion reports and what `scheduler::CacheAffinity` feeds
    /// into [`crate::queue::TakeFilter::hot_datasets`].  Keys that no
    /// queued invocation references are harmless noise — the queue's hot
    /// tier is a pure preference.
    pub fn hot_keys(&self, k: usize) -> (Vec<String>, u64) {
        let state = self.state.lock().expect("cache poisoned");
        (state.hot_keys(k), state.generation)
    }

    /// Current cache generation (bumped on every key-set change).
    pub fn generation(&self) -> u64 {
        self.state.lock().expect("cache poisoned").generation
    }

    /// Whether `key` is cache-resident *right now*, without promoting
    /// it, counting a hit, or touching the backing store.  The affinity
    /// hit/miss accounting probes this at fetch time: a dispatch whose
    /// dataset is resident is an affinity hit, one that needs a backing
    /// fetch is a miss (stale-hint degradation, never an error).
    pub fn contains_cached(&self, key: &str) -> bool {
        self.state.lock().expect("cache poisoned").map.contains_key(key)
    }

    /// Drop the cached entry for `key` and poison any fetch of it that is
    /// currently in flight.  Public so operators (and the stale-hint
    /// regression tests) can evict behind the scheduler's back — the
    /// backing object is untouched, so a later `get` refetches.
    pub fn invalidate(&self, key: &str) {
        let inflight = self.inflight.lock().expect("inflight poisoned");
        if let Some(f) = inflight.get(key) {
            f.poisoned.store(true, Ordering::SeqCst);
        }
        self.state.lock().expect("cache poisoned").remove(key);
    }
}

enum Role {
    Leader(Arc<Flight>),
    Waiter(Arc<Flight>),
}

impl ObjectStore for CachedStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.inner.put(key, data)?;
        self.invalidate(key);
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Blob> {
        loop {
            // Fast path: cache hit without touching the single-flight
            // table.
            if let Some(b) = self.state.lock().expect("cache poisoned").lookup(key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(b);
            }
            let role = {
                let mut inflight = self.inflight.lock().expect("inflight poisoned");
                // Re-check under the table lock: a fetch may have
                // completed between the fast path and here.
                if let Some(b) = self.state.lock().expect("cache poisoned").lookup(key) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(b);
                }
                match inflight.get(key) {
                    Some(f) => {
                        f.waiters.fetch_add(1, Ordering::SeqCst);
                        Role::Waiter(f.clone())
                    }
                    None => {
                        let f = Arc::new(Flight::new());
                        inflight.insert(key.to_string(), f.clone());
                        Role::Leader(f)
                    }
                }
            };
            match role {
                Role::Leader(flight) => {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    let fetched = self.inner.get(key);
                    let shared = match &fetched {
                        Ok(b) => Ok(b.clone()),
                        Err(e) => Err(format!("{e:#}")),
                    };
                    {
                        // Publish under the table lock so an invalidation
                        // either sees us in flight (and poisons) or sees
                        // the cached entry (and removes it) — never
                        // neither.
                        let mut inflight =
                            self.inflight.lock().expect("inflight poisoned");
                        if let Ok(b) = &fetched {
                            if !flight.poisoned.load(Ordering::SeqCst) {
                                self.state.lock().expect("cache poisoned").insert(
                                    key,
                                    b.clone(),
                                    is_immutable(key),
                                    self.budget,
                                );
                            }
                        }
                        inflight.remove(key);
                    }
                    *flight.done.lock().expect("flight poisoned") = Some(shared);
                    flight.cv.notify_all();
                    return fetched;
                }
                Role::Waiter(flight) => {
                    self.coalesced.fetch_add(1, Ordering::Relaxed);
                    let mut done = flight.done.lock().expect("flight poisoned");
                    while done.is_none() {
                        done = flight.cv.wait(done).expect("flight poisoned");
                    }
                    let result = done.as_ref().expect("flight published").clone();
                    drop(done);
                    // A write invalidated this fetch while it was in
                    // flight: its result may predate the write, and this
                    // caller may have arrived strictly after the write
                    // completed — retry against the backing store rather
                    // than hand out a stale buffer.
                    if flight.poisoned.load(Ordering::SeqCst) {
                        continue;
                    }
                    return match result {
                        Ok(b) => Ok(b),
                        Err(e) => bail!("coalesced fetch of {key} failed: {e}"),
                    };
                }
            }
        }
    }

    fn exists(&self, key: &str) -> Result<bool> {
        if self.state.lock().expect("cache poisoned").map.contains_key(key) {
            return Ok(true);
        }
        self.inner.exists(key)
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.inner.delete(key)?;
        self.invalidate(key);
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.inner.list(prefix)
    }

    // The decorator owns the CAS key derivation (the trait default's
    // `cas/<sha256>` scheme) instead of delegating to `inner.put_cas`:
    // the race-closing flight below must be registered under the key
    // *before* the backing write, and the pinning logic (`is_immutable`)
    // is keyed to the same `cas/` prefix.  Wrapping a backend with a
    // custom CAS layout under this decorator is unsupported.  Costs one
    // exists+put instead of StoreClient's single put_cas RPC — once per
    // distinct bundle publish, not a hot path.
    fn put_cas(&self, data: &[u8]) -> Result<String> {
        let key = format!("cas/{}", hex_sha256(data));
        if self.state.lock().expect("cache poisoned").lookup(&key).is_some() {
            // content-addressed: a cached entry proves the store has it
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(key);
        }
        // Register in the single-flight table so a racing invalidation
        // (delete of this cas key) poisons us instead of leaving a cache
        // entry for an object the backing store no longer has.  If a get
        // is already fetching this key, skip seeding — its leader will
        // populate the cache.
        let flight = {
            let mut inflight = self.inflight.lock().expect("inflight poisoned");
            match inflight.get(&key) {
                Some(_) => None,
                None => {
                    let f = Arc::new(Flight::new());
                    inflight.insert(key.clone(), f.clone());
                    Some(f)
                }
            }
        };
        let stored: Result<()> = (|| {
            if !self.inner.exists(&key)? {
                self.inner.put(&key, data)?;
            }
            Ok(())
        })();
        if let Some(flight) = flight {
            let blob = {
                let mut inflight = self.inflight.lock().expect("inflight poisoned");
                let cacheable = stored.is_ok()
                    && !flight.poisoned.load(Ordering::SeqCst)
                    && data.len() <= self.budget;
                // Waiter registration happens under the `inflight` lock,
                // and the flight leaves the table below while we still
                // hold it — so this count is final.  Copy the payload
                // into a shared Blob only if the cache or a waiter will
                // actually hold it (an oversized bundle with no waiters
                // costs no copy).
                let waiters = flight.waiters.load(Ordering::SeqCst);
                let blob = if cacheable || (stored.is_ok() && waiters > 0) {
                    Some(Blob::from(data))
                } else {
                    None
                };
                if cacheable {
                    // Immutable, so it pin-caches for free: no read-back
                    // fetch needed.
                    self.state.lock().expect("cache poisoned").insert(
                        &key,
                        blob.clone().expect("blob built when cacheable"),
                        true,
                        self.budget,
                    );
                }
                inflight.remove(&key);
                blob
            };
            // Any get that parked on our flight receives the content we
            // just published (or the error).
            *flight.done.lock().expect("flight poisoned") = Some(match (&stored, blob) {
                (Ok(()), Some(b)) => Ok(b),
                // zero registered waiters: this value is never read
                (Ok(()), None) => Ok(Blob::from(Vec::new())),
                (Err(e), _) => Err(format!("{e:#}")),
            });
            flight.cv.notify_all();
        }
        stored?;
        Ok(key)
    }
}

// ---------------------------------------------------------------------------
// Decoded-input cache
// ---------------------------------------------------------------------------

struct DecodedEntry {
    /// The source buffer the decode came from.  Holding the `Blob` keeps
    /// its allocation alive, so pointer identity is a sound staleness
    /// check: a refetched (invalidated) object can never alias it.
    src: Blob,
    data: Arc<Vec<f32>>,
    /// Budget charge for this entry: decoded bytes plus the pinned
    /// source buffer (which this entry keeps alive even if the raw cache
    /// evicts it) — so the decoded budget bounds *total* retained bytes.
    cost: usize,
    tick: u64,
}

#[derive(Default)]
struct DecodedState {
    map: HashMap<String, DecodedEntry>,
    lru: BTreeMap<u64, String>,
    tick: u64,
    bytes: usize,
    evictions: u64,
}

/// Bytes→f32 decode cache: one decode per distinct dataset buffer per
/// node at steady state.  Keyed by object key, validated by
/// source-buffer identity — feeding a different `Blob` under the same
/// key re-decodes.
///
/// Deliberately no single-flight here: workers released simultaneously
/// by a cold-start stampede may race one redundant decode each (pure
/// bounded CPU, no I/O to coalesce); last insert wins and every later
/// invocation shares that buffer.
pub struct DecodedCache {
    budget: usize,
    state: Mutex<DecodedState>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl DecodedCache {
    /// `budget_bytes` bounds the retained bytes: decoded payloads (4
    /// bytes per f32) *plus* each entry's pinned source `Blob`, so the
    /// documented per-node worst case (raw budget + decoded budget)
    /// holds even when the raw cache has evicted a source buffer.
    pub fn new(budget_bytes: usize) -> DecodedCache {
        DecodedCache {
            budget: budget_bytes,
            state: Mutex::new(DecodedState::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn stats(&self) -> CacheStats {
        let state = self.state.lock().expect("decoded cache poisoned");
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: state.evictions,
            coalesced: 0,
            entries: state.map.len() as u64,
            bytes: state.bytes as u64,
        }
    }

    /// Return the decoded f32 view of `raw`, decoding at most once per
    /// distinct buffer.  The returned `Arc` is shared with every other
    /// worker executing the same dataset.
    pub fn get_or_decode(&self, key: &str, raw: &Blob) -> Arc<Vec<f32>> {
        {
            let mut state = self.state.lock().expect("decoded cache poisoned");
            state.tick += 1;
            let tick = state.tick;
            if let Some(e) = state.map.get_mut(key) {
                if Blob::ptr_eq(&e.src, raw) {
                    let old_tick = e.tick;
                    e.tick = tick;
                    let data = e.data.clone();
                    let owned = state
                        .lru
                        .remove(&old_tick)
                        .unwrap_or_else(|| key.to_string());
                    state.lru.insert(tick, owned);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return data;
                }
            }
        } // decode outside the lock
        let decoded: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let data = Arc::new(decoded);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let cost = data.len() * 4 + raw.len();
        if cost <= self.budget {
            let mut state = self.state.lock().expect("decoded cache poisoned");
            if let Some(old) = state.map.remove(key) {
                state.bytes -= old.cost;
                state.lru.remove(&old.tick);
            }
            state.tick += 1;
            let tick = state.tick;
            state.bytes += cost;
            state.lru.insert(tick, key.to_string());
            state.map.insert(
                key.to_string(),
                DecodedEntry { src: raw.clone(), data: data.clone(), cost, tick },
            );
            while state.bytes > self.budget {
                let Some(t) = state.lru.keys().next().copied() else { break };
                let victim = state.lru.remove(&t).expect("lru entry");
                let e = state.map.remove(&victim).expect("map entry");
                state.bytes -= e.cost;
                state.evictions += 1;
            }
        }
        data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{conformance, FsStore, MemStore};
    use std::time::Duration;

    const MB: usize = 1024 * 1024;

    /// Counts (and optionally delays) backing fetches — the single-flight
    /// assertions hang off this.
    struct CountingStore {
        inner: MemStore,
        gets: AtomicU64,
        delay: Duration,
    }

    impl CountingStore {
        fn new(delay: Duration) -> CountingStore {
            CountingStore { inner: MemStore::new(), gets: AtomicU64::new(0), delay }
        }

        fn fetches(&self) -> u64 {
            self.gets.load(Ordering::SeqCst)
        }
    }

    impl ObjectStore for CountingStore {
        fn put(&self, key: &str, data: &[u8]) -> Result<()> {
            self.inner.put(key, data)
        }
        fn get(&self, key: &str) -> Result<Blob> {
            self.gets.fetch_add(1, Ordering::SeqCst);
            let blob = self.inner.get(key);
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            blob
        }
        fn exists(&self, key: &str) -> Result<bool> {
            self.inner.exists(key)
        }
        fn delete(&self, key: &str) -> Result<()> {
            self.inner.delete(key)
        }
        fn list(&self, prefix: &str) -> Result<Vec<String>> {
            self.inner.list(prefix)
        }
    }

    #[test]
    fn conformance_over_memstore() {
        let s = CachedStore::new(Arc::new(MemStore::new()), 64 * MB);
        conformance::run_all(&s);
    }

    #[test]
    fn conformance_over_fsstore() {
        let dir = std::env::temp_dir()
            .join(format!("hardless-cachedfs-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = CachedStore::new(Arc::new(FsStore::open(&dir).unwrap()), 64 * MB);
        conformance::run_all(&s);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn conformance_with_tiny_budget_still_correct() {
        // A budget too small to hold anything degrades to pass-through —
        // semantics must not depend on residency.
        let s = CachedStore::new(Arc::new(MemStore::new()), 8);
        conformance::run_all(&s);
    }

    #[test]
    fn hit_returns_pointer_equal_blob_without_refetch() {
        let inner = Arc::new(CountingStore::new(Duration::ZERO));
        let s = CachedStore::new(inner.clone(), 64 * MB);
        s.put("datasets/x", b"payload").unwrap();
        let a = s.get("datasets/x").unwrap();
        let b = s.get("datasets/x").unwrap();
        let c = s.get("datasets/x").unwrap();
        assert!(Blob::ptr_eq(&a, &b) && Blob::ptr_eq(&b, &c), "hits share one buffer");
        assert_eq!(inner.fetches(), 1, "one backing fetch for three gets");
        let st = s.stats();
        assert_eq!((st.misses, st.hits), (1, 2));
        assert_eq!(st.entries, 1);
        assert_eq!(st.bytes, 7);
    }

    #[test]
    fn stampede_issues_exactly_one_backing_fetch() {
        let inner = Arc::new(CountingStore::new(Duration::from_millis(100)));
        inner.put("datasets/hot", &vec![7u8; 4096]).unwrap();
        let s = Arc::new(CachedStore::new(inner.clone(), 64 * MB));
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = s.clone();
            let barrier = barrier.clone();
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                s.get("datasets/hot").unwrap()
            }));
        }
        let blobs: Vec<Blob> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(inner.fetches(), 1, "8 concurrent cold gets must coalesce");
        for b in &blobs[1..] {
            assert!(Blob::ptr_eq(&blobs[0], b), "all callers share one buffer");
        }
        let st = s.stats();
        assert_eq!(st.misses, 1);
        assert_eq!(st.coalesced, 7);
    }

    #[test]
    fn coalesced_fetch_propagates_leader_error() {
        let inner = Arc::new(CountingStore::new(Duration::from_millis(50)));
        let s = Arc::new(CachedStore::new(inner.clone(), MB));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || s.get("nope/missing")));
        }
        for h in handles {
            assert!(h.join().unwrap().is_err());
        }
        // errors are not cached: the next get fetches again
        let before = inner.fetches();
        assert!(s.get("nope/missing").is_err());
        assert_eq!(inner.fetches(), before + 1);
    }

    #[test]
    fn put_and_delete_invalidate() {
        let inner = Arc::new(CountingStore::new(Duration::ZERO));
        let s = CachedStore::new(inner.clone(), 64 * MB);
        s.put("datasets/k", b"v1").unwrap();
        assert_eq!(s.get("datasets/k").unwrap(), b"v1");
        s.put("datasets/k", b"v2").unwrap();
        assert_eq!(s.get("datasets/k").unwrap(), b"v2", "overwrite invalidates");
        assert_eq!(inner.fetches(), 2, "second get refetches");
        s.delete("datasets/k").unwrap();
        assert!(s.get("datasets/k").is_err(), "delete invalidates");
        assert!(!s.exists("datasets/k").unwrap());
    }

    #[test]
    fn invalidation_racing_a_fetch_poisons_it() {
        // Leader reads v1, then sleeps inside the backing get; the
        // overwrite lands mid-fetch.  The stale v1 buffer must not be
        // cached, so the next get sees v2.
        let inner = Arc::new(CountingStore::new(Duration::from_millis(100)));
        inner.put("datasets/r", b"v1").unwrap();
        let s = Arc::new(CachedStore::new(inner.clone(), 64 * MB));
        let s2 = s.clone();
        let reader = std::thread::spawn(move || s2.get("datasets/r").unwrap());
        std::thread::sleep(Duration::from_millis(30));
        s.put("datasets/r", b"v2").unwrap();
        let stale = reader.join().unwrap();
        assert_eq!(stale, b"v1", "in-flight read returns what it fetched");
        assert_eq!(
            s.get("datasets/r").unwrap(),
            b"v2",
            "poisoned fetch must not populate the cache"
        );
    }

    #[test]
    fn lru_eviction_respects_bytes_budget() {
        let inner = Arc::new(CountingStore::new(Duration::ZERO));
        let s = CachedStore::new(inner.clone(), 100);
        for k in ["a", "b", "c"] {
            s.put(&format!("datasets/{k}"), &[0u8; 40]).unwrap();
            s.get(&format!("datasets/{k}")).unwrap();
        }
        // 3 × 40 > 100: the oldest (a) was evicted
        let st = s.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.entries, 2);
        assert!(st.bytes <= 100);
        let before = inner.fetches();
        s.get("datasets/a").unwrap(); // miss → refetch
        assert_eq!(inner.fetches(), before + 1);
        s.get("datasets/c").unwrap(); // still resident
        assert_eq!(inner.fetches(), before + 1);
    }

    #[test]
    fn oversized_objects_bypass_the_cache() {
        let inner = Arc::new(CountingStore::new(Duration::ZERO));
        let s = CachedStore::new(inner.clone(), 100);
        s.put("datasets/huge", &[1u8; 500]).unwrap();
        s.get("datasets/huge").unwrap();
        s.get("datasets/huge").unwrap();
        assert_eq!(inner.fetches(), 2, "never cached");
        assert_eq!(s.stats().entries, 0);
    }

    #[test]
    fn cas_entries_pin_and_seed_without_fetch() {
        let inner = Arc::new(CountingStore::new(Duration::ZERO));
        let s = CachedStore::new(inner.clone(), 200);
        let key = s.put_cas(&[9u8; 50]).unwrap();
        // seeded by put_cas: the first get is already a hit
        let a = s.get(&key).unwrap();
        let b = s.get(&key).unwrap();
        assert!(Blob::ptr_eq(&a, &b));
        assert_eq!(inner.fetches(), 0, "cas reads never touched the backing store");
        // re-publishing the same content is a pure cache hit
        assert_eq!(s.put_cas(&[9u8; 50]).unwrap(), key);
        // churn unpinned keys well past the budget: the pinned cas entry
        // survives while unpinned entries cycle
        for i in 0..6 {
            let k = format!("datasets/churn-{i}");
            s.put(&k, &[0u8; 60]).unwrap();
            s.get(&k).unwrap();
        }
        assert!(Blob::ptr_eq(&a, &s.get(&key).unwrap()), "pinned entry survived churn");
        assert_eq!(inner.fetches(), 6, "only the churn keys fetched");
    }

    #[test]
    fn hot_keys_rank_by_recency_with_generation() {
        let s = CachedStore::new(Arc::new(MemStore::new()), 64 * MB);
        let (keys, gen0) = s.hot_keys(8);
        assert!(keys.is_empty());
        for k in ["a", "b", "c"] {
            s.put(&format!("datasets/{k}"), b"xx").unwrap();
            s.get(&format!("datasets/{k}")).unwrap();
        }
        // Re-read "a": it becomes the most recent.
        s.get("datasets/a").unwrap();
        let (keys, gen1) = s.hot_keys(8);
        assert_eq!(keys, vec!["datasets/a", "datasets/c", "datasets/b"]);
        assert!(gen1 > gen0, "inserts bump the generation");
        // Recency bumps alone don't change the key-set generation...
        s.get("datasets/b").unwrap();
        assert_eq!(s.generation(), gen1);
        // ...but k truncates newest-first, and pinned cas entries rank
        // by the same recency order.
        let (keys, _) = s.hot_keys(1);
        assert_eq!(keys, vec!["datasets/b"]);
        let cas = s.put_cas(b"blob").unwrap();
        let (keys, gen2) = s.hot_keys(2);
        assert_eq!(keys, vec![cas.clone(), "datasets/b".to_string()]);
        assert!(gen2 > gen1);
        // Invalidation shrinks the set and bumps the generation.
        s.invalidate(&cas);
        let (keys, gen3) = s.hot_keys(8);
        assert!(!keys.contains(&cas));
        assert!(gen3 > gen2);
    }

    #[test]
    fn contains_cached_probes_without_promotion_or_fetch() {
        let inner = Arc::new(CountingStore::new(Duration::ZERO));
        let s = CachedStore::new(inner.clone(), 64 * MB);
        s.put("datasets/x", b"payload").unwrap();
        assert!(
            !s.contains_cached("datasets/x"),
            "exists in the backing store but not resident"
        );
        assert_eq!(inner.fetches(), 0, "the probe never fetches");
        s.get("datasets/x").unwrap();
        let hits_before = s.stats().hits;
        assert!(s.contains_cached("datasets/x"));
        assert_eq!(s.stats().hits, hits_before, "the probe counts no hit");
        // Invalidate behind the scheduler's back: the probe reports the
        // truth and the next get degrades to a backing refetch.
        s.invalidate("datasets/x");
        assert!(!s.contains_cached("datasets/x"));
        assert_eq!(s.get("datasets/x").unwrap(), b"payload");
        assert_eq!(inner.fetches(), 2);
    }

    #[test]
    fn decoded_cache_decodes_once_per_buffer() {
        let cache = DecodedCache::new(MB);
        let raw: Vec<u8> = [1.0f32, 2.0, 3.0].iter().flat_map(|f| f.to_le_bytes()).collect();
        let blob = Blob::from(raw);
        let a = cache.get_or_decode("datasets/x", &blob);
        let b = cache.get_or_decode("datasets/x", &blob);
        assert_eq!(*a, vec![1.0, 2.0, 3.0]);
        assert!(Arc::ptr_eq(&a, &b), "second call reuses the decode");
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn decoded_cache_redecodes_on_new_buffer() {
        let cache = DecodedCache::new(MB);
        let bytes = |v: f32| -> Blob {
            Blob::from([v].iter().flat_map(|f| f.to_le_bytes()).collect::<Vec<u8>>())
        };
        let b1 = bytes(1.0);
        let b2 = bytes(2.0);
        let a = cache.get_or_decode("datasets/x", &b1);
        assert_eq!(*a, vec![1.0]);
        // same key, different buffer (e.g. after an overwrite+refetch)
        let b = cache.get_or_decode("datasets/x", &b2);
        assert_eq!(*b, vec![2.0], "stale decode must not be served");
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn decoded_cache_eviction_bounded_by_budget() {
        // Each entry charges decoded bytes (16) + pinned source (16) = 32.
        let budget = 2 * 32; // room for two entries
        let cache = DecodedCache::new(budget);
        for i in 0..4 {
            let raw: Vec<u8> =
                (0..4).flat_map(|j| ((i * 4 + j) as f32).to_le_bytes()).collect();
            cache.get_or_decode(&format!("d/{i}"), &Blob::from(raw));
        }
        let st = cache.stats();
        assert!(st.bytes as usize <= budget, "budget respected ({} bytes)", st.bytes);
        assert_eq!(st.entries, 2);
        assert_eq!(st.evictions, 2);
    }

    #[test]
    fn concurrent_mixed_load_is_consistent() {
        let inner = Arc::new(CountingStore::new(Duration::ZERO));
        let s = Arc::new(CachedStore::new(inner, 64 * MB));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = format!("datasets/t{}-{}", t % 4, i % 10);
                    s.put(&key, format!("{t}:{i}").as_bytes()).unwrap();
                    let got = s.get(&key).unwrap();
                    assert!(!got.is_empty());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
