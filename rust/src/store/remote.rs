//! Distributed object store: TCP server + client over [`crate::wire`].
//!
//! This is the deployment shape of the paper's Minio: one `StoreServer`
//! process per cluster, node managers and benchmark clients connect with
//! `StoreClient`.  Payloads travel as raw blob frames (no base64 overhead)
//! — a dataset `get` is one round trip.

use super::{Blob, ObjectStore};
use crate::json::Json;
use crate::wire::{Handler, RpcClient, RpcConfig, RpcServer};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Serves any [`ObjectStore`] backend over TCP.
pub struct StoreServer {
    inner: RpcServer,
}

impl StoreServer {
    pub fn serve(addr: &str, backend: Arc<dyn ObjectStore>) -> Result<StoreServer> {
        StoreServer::serve_with(addr, backend, RpcConfig::default())
    }

    pub fn serve_with(
        addr: &str,
        backend: Arc<dyn ObjectStore>,
        rpc: RpcConfig,
    ) -> Result<StoreServer> {
        let handler: Handler = Arc::new(move |method, params, blob| {
            let key = || -> Result<String> { Ok(params.str_of("key")?.to_string()) };
            match method {
                "put" => {
                    let data = blob.ok_or_else(|| anyhow!("put requires a payload"))?;
                    backend.put(&key()?, &data)?;
                    Ok((Json::obj(), None))
                }
                "put_cas" => {
                    let data = blob.ok_or_else(|| anyhow!("put_cas requires a payload"))?;
                    let k = backend.put_cas(&data)?;
                    Ok((Json::obj().set("key", k), None))
                }
                "get" => {
                    let data = backend.get(&key()?)?;
                    Ok((Json::obj().set("len", data.len()), Some(data)))
                }
                "exists" => Ok((
                    Json::obj().set("exists", backend.exists(&key()?)?),
                    None,
                )),
                "delete" => {
                    backend.delete(&key()?)?;
                    Ok((Json::obj(), None))
                }
                "list" => {
                    let prefix = params.str_of("prefix")?.to_string();
                    let keys: Vec<Json> =
                        backend.list(&prefix)?.into_iter().map(Json::Str).collect();
                    Ok((Json::obj().set("keys", Json::Arr(keys)), None))
                }
                other => Err(anyhow!("unknown store method {other}")),
            }
        });
        Ok(StoreServer { inner: RpcServer::serve_with(addr, handler, rpc)? })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    pub fn shutdown(&mut self) {
        self.inner.shutdown();
    }
}

/// TCP client implementing [`ObjectStore`] — drop-in for the in-process
/// backends anywhere in the node manager or benchmark client.
pub struct StoreClient {
    rpc: RpcClient,
}

impl StoreClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs + std::fmt::Debug) -> Result<StoreClient> {
        Ok(StoreClient { rpc: RpcClient::connect(addr)? })
    }
}

impl ObjectStore for StoreClient {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.rpc
            .call_blob("put", Json::obj().set("key", key), Some(data))?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Blob> {
        let (_, blob) = self.rpc.call_blob("get", Json::obj().set("key", key), None)?;
        blob.map(Blob::from)
            .ok_or_else(|| anyhow!("store get returned no payload"))
    }

    fn exists(&self, key: &str) -> Result<bool> {
        let out = self.rpc.call("exists", Json::obj().set("key", key))?;
        Ok(out.bool_of("exists")?)
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.rpc.call("delete", Json::obj().set("key", key))?;
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let out = self.rpc.call("list", Json::obj().set("prefix", prefix))?;
        Ok(out
            .arr_of("keys")?
            .iter()
            .filter_map(|k| k.as_str().map(|s| s.to_string()))
            .collect())
    }

    fn put_cas(&self, data: &[u8]) -> Result<String> {
        let (out, _) = self.rpc.call_blob("put_cas", Json::obj(), Some(data))?;
        Ok(out.str_of("key")?.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{conformance, MemStore};

    fn server() -> (StoreServer, StoreClient) {
        let backend = Arc::new(MemStore::new());
        let server = StoreServer::serve("127.0.0.1:0", backend).unwrap();
        let client = StoreClient::connect(server.addr()).unwrap();
        (server, client)
    }

    #[test]
    fn conformance_suite_over_tcp() {
        let (_server, client) = server();
        conformance::run_all(&client);
    }

    #[test]
    fn conformance_suite_cached_over_tcp() {
        // The node-deployment shape: CachedStore in front of a TCP store
        // client must preserve the full contract (incl. invalidation).
        let (_server, client) = server();
        let cached =
            crate::store::CachedStore::new(Arc::new(client), 64 * 1024 * 1024);
        conformance::run_all(&cached);
    }

    #[test]
    fn multi_megabyte_dataset_roundtrip() {
        let (_server, client) = server();
        let blob = vec![0x5A; 8 * 1024 * 1024];
        client.put("datasets/big-image-batch", &blob).unwrap();
        assert_eq!(client.get("datasets/big-image-batch").unwrap(), blob);
    }

    #[test]
    fn concurrent_clients_share_backend() {
        let backend = Arc::new(MemStore::new());
        let server = StoreServer::serve("127.0.0.1:0", backend).unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let c = StoreClient::connect(addr).unwrap();
                c.put(&format!("datasets/t{t}"), &[t as u8]).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let c = StoreClient::connect(addr).unwrap();
        assert_eq!(c.list("datasets/").unwrap().len(), 4);
    }

    #[test]
    fn server_side_validation_errors_propagate() {
        let (_server, client) = server();
        let err = client.put("../bad", b"x").unwrap_err();
        assert!(format!("{err}").contains("traversal"), "{err}");
    }
}
