//! In-memory object store backend (tests + single-process experiments).

use super::{validate_key, Blob, ObjectStore};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::RwLock;

/// Thread-safe in-memory blob map with the full [`ObjectStore`] contract.
/// Values are stored as shared [`Blob`]s, so `get` is a refcount bump —
/// N workers reading one dataset share a single allocation.
#[derive(Default)]
pub struct MemStore {
    map: RwLock<BTreeMap<String, Blob>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }

    /// Number of stored objects.
    pub fn len(&self) -> usize {
        self.map.read().expect("memstore poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total stored bytes (capacity accounting in tests).
    pub fn total_bytes(&self) -> usize {
        self.map
            .read()
            .expect("memstore poisoned")
            .values()
            .map(|v| v.len())
            .sum()
    }
}

impl ObjectStore for MemStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        validate_key(key)?;
        self.map
            .write()
            .expect("memstore poisoned")
            .insert(key.to_string(), Blob::from(data));
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Blob> {
        validate_key(key)?;
        match self.map.read().expect("memstore poisoned").get(key) {
            Some(v) => Ok(v.clone()),
            None => bail!("object not found: {key}"),
        }
    }

    fn exists(&self, key: &str) -> Result<bool> {
        validate_key(key)?;
        Ok(self.map.read().expect("memstore poisoned").contains_key(key))
    }

    fn delete(&self, key: &str) -> Result<()> {
        validate_key(key)?;
        self.map.write().expect("memstore poisoned").remove(key);
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let map = self.map.read().expect("memstore poisoned");
        Ok(map
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::conformance;
    use std::sync::Arc;

    #[test]
    fn conformance_suite() {
        conformance::run_all(&MemStore::new());
    }

    #[test]
    fn accounting() {
        let s = MemStore::new();
        assert!(s.is_empty());
        s.put("a/b", &[0u8; 100]).unwrap();
        s.put("a/c", &[0u8; 50]).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.total_bytes(), 150);
    }

    #[test]
    fn concurrent_put_get() {
        let s = Arc::new(MemStore::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let key = format!("t{t}/obj{i}");
                    s.put(&key, format!("{t}:{i}").as_bytes()).unwrap();
                    assert_eq!(s.get(&key).unwrap(), format!("{t}:{i}").as_bytes());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 400);
    }

    #[test]
    fn gets_share_one_allocation() {
        let s = MemStore::new();
        s.put("datasets/z", b"shared-bytes").unwrap();
        let a = s.get("datasets/z").unwrap();
        let b = s.get("datasets/z").unwrap();
        assert!(Blob::ptr_eq(&a, &b), "per-get copies are gone");
        s.put("datasets/z", b"new-bytes").unwrap();
        let c = s.get("datasets/z").unwrap();
        assert!(!Blob::ptr_eq(&a, &c), "overwrite installs a fresh buffer");
        assert_eq!(a, b"shared-bytes", "old readers keep their snapshot");
        assert_eq!(c, b"new-bytes");
    }

    #[test]
    fn list_range_is_prefix_exact() {
        let s = MemStore::new();
        s.put("ab/1", b"x").unwrap();
        s.put("abc/2", b"x").unwrap();
        s.put("b/3", b"x").unwrap();
        assert_eq!(s.list("ab/").unwrap(), vec!["ab/1".to_string()]);
        assert_eq!(s.list("a").unwrap().len(), 2);
    }
}
