//! Runtime instances: the paper's process-per-runtime execution model.
//!
//! §IV-D: *"a runtime instance is a process running on a worker node that
//! can fulfill user invocations using its runtime. We choose processes
//! instead of containers ... to ensure that our system can use every type
//! of accelerator."*  Our isolation unit is a dedicated OS thread owning
//! a non-`Send` executor — same lifecycle semantics (cold start, warm
//! serve, explicit stop), no foreign-isolation assumptions.

use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The compute interface a runtime instance drives.  Implemented by
/// [`super::PjrtExecutor`] (production) and by mock executors in tests.
pub trait Executor {
    /// Run one invocation payload (flattened f32 image) to its output.
    fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>>;

    /// Run a micro-batch of payloads, returning one output per input
    /// (same order) plus device-program accounting.  The default loops
    /// [`infer`] — one device program per input — so every executor is
    /// batch-correct from day one; engines with batched-HLO artifacts
    /// (DESIGN.md §16) specialize it to pack the batch into leading-dim
    /// literals and dispatch one program per planned sub-batch.
    ///
    /// Contract: all-or-nothing.  An error fails the whole batch — the
    /// caller demultiplexes it to every invocation in the batch.
    ///
    /// [`infer`]: Executor::infer
    fn infer_batch(&mut self, inputs: &[Arc<Vec<f32>>]) -> Result<BatchRun> {
        let outputs = inputs
            .iter()
            .map(|input| self.infer(input))
            .collect::<Result<Vec<_>>>()?;
        Ok(BatchRun { outputs, programs: inputs.len(), pad_slots: 0 })
    }

    /// The compiled micro-batch ladder this executor can serve with one
    /// device program per rung (sorted ascending).  `[1]` — the default —
    /// means per-input programs only; the aggregator uses the ladder to
    /// snap its chunk caps to compiled sizes so dispatches don't pad.
    fn compiled_batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }
}

/// What one [`Executor::infer_batch`] call did at the device boundary:
/// per-input outputs plus how many device programs were dispatched and how
/// many padded rows were executed and discarded to serve them.
#[derive(Debug, Clone)]
pub struct BatchRun {
    pub outputs: Vec<Vec<f32>>,
    pub programs: usize,
    pub pad_slots: usize,
}

/// Result of one execution, with the instance-side wall time (the real
/// compute cost, before accelerator pacing).
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    pub output: Vec<f32>,
    pub compute_wall: Duration,
}

/// Result of one batched execution: per-invocation outputs (input order),
/// the wall time of the instance-side dispatch, and the device-program
/// accounting forwarded from [`BatchRun`].
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    pub outputs: Vec<Vec<f32>>,
    pub compute_wall: Duration,
    /// Device programs dispatched to serve the batch.
    pub programs: usize,
    /// Padded rows executed and discarded (batched-HLO engines only).
    pub pad_slots: usize,
}

enum Request {
    /// One device dispatch for N invocations.  A single reply channel per
    /// batch — the caller demuxes outputs by index — instead of the old
    /// one-channel-per-invocation allocation.
    Exec { inputs: Vec<Arc<Vec<f32>>>, reply: mpsc::Sender<Result<BatchOutcome>> },
    Stop,
}

/// A live runtime instance: a worker thread + request channel.
pub struct RuntimeInstance {
    /// Variant this instance serves (e.g. `tinyyolo-gpu`).
    pub variant: String,
    /// Device the instance is pinned to (e.g. `gpu0`).
    pub device_id: String,
    tx: mpsc::Sender<Request>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Wall-clock cost of the cold start (thread + compile + weights).
    pub cold_start_wall: Duration,
    /// The executor's compiled micro-batch ladder, captured at cold start
    /// (the executor itself lives on the instance thread).
    compiled_batch_sizes: Vec<usize>,
    created: Instant,
    executions: std::sync::atomic::AtomicU64,
}

impl RuntimeInstance {
    /// Cold-start an instance: spawn the thread, build the executor inside
    /// it (PJRT handles are not `Send`), wait until it is ready.
    pub fn start(
        variant: impl Into<String>,
        device_id: impl Into<String>,
        factory: super::ExecutorFactory,
    ) -> Result<RuntimeInstance> {
        let variant = variant.into();
        let device_id = device_id.into();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<usize>>>();
        let t0 = Instant::now();
        let thread_name = format!("rt-{variant}-{device_id}");
        let handle = std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || {
                let mut exec = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.compiled_batch_sizes()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Exec { inputs, reply } => {
                            let t = Instant::now();
                            let n = inputs.len();
                            let result = exec.infer_batch(&inputs).and_then(|run| {
                                if run.outputs.len() != n {
                                    return Err(anyhow!(
                                        "executor returned {} outputs for a batch of {n}",
                                        run.outputs.len()
                                    ));
                                }
                                Ok(BatchOutcome {
                                    outputs: run.outputs,
                                    compute_wall: t.elapsed(),
                                    programs: run.programs,
                                    pad_slots: run.pad_slots,
                                })
                            });
                            let _ = reply.send(result);
                        }
                        Request::Stop => break,
                    }
                }
            })?;
        let compiled_batch_sizes = ready_rx
            .recv()
            .map_err(|_| anyhow!("instance thread died during cold start"))??;
        Ok(RuntimeInstance {
            variant,
            device_id,
            tx,
            handle: Some(handle),
            cold_start_wall: t0.elapsed(),
            compiled_batch_sizes,
            created: Instant::now(),
            executions: 0.into(),
        })
    }

    /// Execute one payload (blocking until the instance replies).  Takes
    /// anything convertible to a shared buffer: a plain `Vec<f32>` (owned
    /// call sites) or an `Arc<Vec<f32>>` straight from the node's
    /// decoded-input cache — N workers executing one dataset send the
    /// same allocation, never copies.
    pub fn exec(&self, input: impl Into<Arc<Vec<f32>>>) -> Result<ExecOutcome> {
        let mut batch = self.exec_batch(vec![input.into()])?;
        Ok(ExecOutcome {
            output: batch.outputs.pop().expect("batch of one has one output"),
            compute_wall: batch.compute_wall,
        })
    }

    /// Execute a micro-batch in one instance-thread hop and one device
    /// dispatch.  Outputs come back in input order; the whole batch
    /// shares one reply channel (demuxed by index by the caller) instead
    /// of paying a channel allocation per invocation.  An executor error
    /// fails the whole batch.
    pub fn exec_batch(&self, inputs: Vec<Arc<Vec<f32>>>) -> Result<BatchOutcome> {
        if inputs.is_empty() {
            return Err(anyhow!("empty batch for instance {}", self.variant));
        }
        let n = inputs.len() as u64;
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Exec { inputs, reply: reply_tx })
            .map_err(|_| anyhow!("instance {} is stopped", self.variant))?;
        let out = reply_rx
            .recv()
            .map_err(|_| anyhow!("instance {} died mid-execution", self.variant))??;
        self.executions
            .fetch_add(n, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    pub fn executions(&self) -> u64 {
        self.executions.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The executor's compiled micro-batch ladder (sorted ascending),
    /// captured at cold start.  `[1]` for engines without batched HLO.
    pub fn compiled_batch_sizes(&self) -> &[usize] {
        &self.compiled_batch_sizes
    }

    pub fn age(&self) -> Duration {
        self.created.elapsed()
    }

    /// Stop the worker thread (blocking join).
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        let _ = self.tx.send(Request::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RuntimeInstance {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Convenience: a shareable handle (instances are driven from node worker
/// threads while owned by the pool).
pub type InstanceRef = Arc<RuntimeInstance>;

// ---------------------------------------------------------------------------

/// Mock executor for coordination-plane tests: output = input scaled, with
/// optional fixed compute delay and scripted failures.
pub struct MockExecutor {
    pub scale: f32,
    pub delay: Duration,
    pub fail_after: Option<u64>,
    /// Compiled micro-batch ladder the mock pretends to have.  `None`
    /// (legacy) models a fully amortizing engine: one dispatch delay per
    /// `infer_batch` call regardless of size.  `Some(ladder)` models
    /// batched-HLO artifacts: the batch is planned over the ladder
    /// ([`crate::runtime::plan_batches`]) and the delay is paid once per
    /// planned device program — `Some(vec![1])` therefore models the
    /// per-input PJRT loop a legacy bundle falls back to.
    pub compiled: Option<Vec<usize>>,
    count: u64,
}

impl MockExecutor {
    pub fn new(scale: f32) -> MockExecutor {
        MockExecutor {
            scale,
            delay: Duration::ZERO,
            fail_after: None,
            compiled: None,
            count: 0,
        }
    }

    pub fn with_delay(mut self, d: Duration) -> MockExecutor {
        self.delay = d;
        self
    }

    pub fn failing_after(mut self, n: u64) -> MockExecutor {
        self.fail_after = Some(n);
        self
    }

    /// Give the mock a compiled batch ladder (sorted ascending).
    pub fn with_compiled(mut self, ladder: Vec<usize>) -> MockExecutor {
        self.compiled = Some(ladder);
        self
    }

    /// Factory suited for [`RuntimeInstance::start`].
    pub fn factory(scale: f32, delay: Duration) -> super::ExecutorFactory {
        Box::new(move || Ok(Box::new(MockExecutor::new(scale).with_delay(delay)) as Box<dyn Executor>))
    }

    /// Factory for a mock with batched-HLO artifacts: per-device-program
    /// dispatch delay and a compiled ladder visible to the aggregator.
    pub fn factory_batched(
        scale: f32,
        delay: Duration,
        ladder: Vec<usize>,
    ) -> super::ExecutorFactory {
        Box::new(move || {
            Ok(Box::new(
                MockExecutor::new(scale).with_delay(delay).with_compiled(ladder.clone()),
            ) as Box<dyn Executor>)
        })
    }
}

impl Executor for MockExecutor {
    fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.count += 1;
        if let Some(n) = self.fail_after {
            if self.count > n {
                return Err(anyhow!("mock executor failure injection"));
            }
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(input.iter().map(|x| x * self.scale).collect())
    }

    /// Batched mock semantics: `delay` models per-dispatch overhead.  A
    /// successful legacy batch (`compiled: None`) pays it **once** (the
    /// amortization micro-batching exists for); a batched-HLO mock pays
    /// it once per planned device program.  Mirroring [`infer`]'s
    /// check-then-sleep order, a failed batch pays it not at all.  The
    /// call counter advances for **every** member of the dispatch (no
    /// short-circuit), then the first injected failure fails the batch.
    /// Note that call-count-based failure injection is inherently
    /// batching-sensitive — the node's isolation fallback re-runs
    /// members individually, advancing the counter again — so
    /// batched-vs-serial equivalence tests must use *input-dependent*
    /// failures, not `fail_after`.
    ///
    /// [`infer`]: Executor::infer
    fn infer_batch(&mut self, inputs: &[Arc<Vec<f32>>]) -> Result<BatchRun> {
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut first_err = None;
        for input in inputs {
            self.count += 1;
            if let Some(n) = self.fail_after {
                if self.count > n {
                    first_err
                        .get_or_insert_with(|| anyhow!("mock executor failure injection"));
                    continue;
                }
            }
            outputs.push(input.iter().map(|x| x * self.scale).collect());
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let (programs, pad_slots) = match &self.compiled {
            None => (1, 0),
            Some(ladder) => {
                let plan = crate::runtime::plan_batches(ladder, inputs.len())?;
                (plan.len(), plan.iter().map(|s| s.pad_slots()).sum())
            }
        };
        if !self.delay.is_zero() {
            for _ in 0..programs {
                std::thread::sleep(self.delay);
            }
        }
        Ok(BatchRun { outputs, programs, pad_slots })
    }

    fn compiled_batch_sizes(&self) -> Vec<usize> {
        self.compiled.clone().unwrap_or_else(|| vec![1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_then_exec() {
        let inst = RuntimeInstance::start(
            "mock-gpu",
            "gpu0",
            MockExecutor::factory(2.0, Duration::ZERO),
        )
        .unwrap();
        assert_eq!(inst.variant, "mock-gpu");
        let out = inst.exec(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out.output, vec![2.0, 4.0, 6.0]);
        assert_eq!(inst.executions(), 1);
    }

    #[test]
    fn factory_failure_surfaces_at_start() {
        let factory: crate::runtime::ExecutorFactory =
            Box::new(|| Err(anyhow!("no such artifact")));
        let err = match RuntimeInstance::start("bad", "gpu0", factory) {
            Err(e) => e,
            Ok(_) => panic!("start must fail"),
        };
        assert!(format!("{err}").contains("no such artifact"));
    }

    #[test]
    fn exec_accepts_shared_input_without_copy() {
        let inst = RuntimeInstance::start(
            "mock-gpu",
            "gpu0",
            MockExecutor::factory(2.0, Duration::ZERO),
        )
        .unwrap();
        // the decoded-cache shape: one Arc'd buffer, many executions
        let shared = Arc::new(vec![1.0f32, 2.0]);
        let a = inst.exec(shared.clone()).unwrap();
        let b = inst.exec(shared.clone()).unwrap();
        assert_eq!(a.output, vec![2.0, 4.0]);
        assert_eq!(b.output, vec![2.0, 4.0]);
        assert_eq!(inst.executions(), 2);
    }

    #[test]
    fn exec_batch_returns_per_input_outputs_in_order() {
        let inst = RuntimeInstance::start(
            "mock-gpu",
            "gpu0",
            MockExecutor::factory(2.0, Duration::ZERO),
        )
        .unwrap();
        let inputs: Vec<Arc<Vec<f32>>> =
            (0..5).map(|i| Arc::new(vec![i as f32, 10.0 + i as f32])).collect();
        let out = inst.exec_batch(inputs).unwrap();
        assert_eq!(out.outputs.len(), 5);
        for (i, o) in out.outputs.iter().enumerate() {
            assert_eq!(o, &vec![2.0 * i as f32, 2.0 * (10.0 + i as f32)]);
        }
        assert_eq!(inst.executions(), 5, "counter advances per invocation");
    }

    #[test]
    fn exec_batch_amortizes_dispatch_delay() {
        // Mock delay models per-dispatch overhead: a batch of 8 pays it
        // once (~30 ms), not 8 times (~240 ms).  Generous bound for CI.
        let inst = RuntimeInstance::start(
            "mock",
            "gpu0",
            MockExecutor::factory(1.0, Duration::from_millis(30)),
        )
        .unwrap();
        let t0 = Instant::now();
        let out = inst
            .exec_batch((0..8).map(|i| Arc::new(vec![i as f32])).collect())
            .unwrap();
        assert_eq!(out.outputs.len(), 8);
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "batch of 8 must not pay 8 dispatch delays: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn exec_batch_rejects_empty_and_demuxes_errors() {
        let inst = RuntimeInstance::start(
            "mock",
            "gpu0",
            MockExecutor::factory(1.0, Duration::ZERO),
        )
        .unwrap();
        assert!(inst.exec_batch(Vec::new()).is_err(), "empty batch rejected");
        // A failing executor fails the whole batch (all-or-nothing), and
        // the instance survives to serve the next request.
        let factory: crate::runtime::ExecutorFactory = Box::new(|| {
            Ok(Box::new(MockExecutor::new(1.0).failing_after(2)) as Box<dyn Executor>)
        });
        let flaky = RuntimeInstance::start("flaky", "gpu0", factory).unwrap();
        let err = flaky
            .exec_batch((0..4).map(|_| Arc::new(vec![1.0])).collect())
            .unwrap_err();
        assert!(format!("{err}").contains("failure injection"));
        assert_eq!(flaky.executions(), 0, "failed batch counts no executions");
        assert!(flaky.exec_batch(vec![Arc::new(vec![1.0])]).is_err());
    }

    #[test]
    fn instance_exposes_compiled_ladder() {
        let inst = RuntimeInstance::start(
            "mock",
            "gpu0",
            MockExecutor::factory(1.0, Duration::ZERO),
        )
        .unwrap();
        assert_eq!(inst.compiled_batch_sizes(), &[1], "legacy mock: batch-1 only");
        let inst = RuntimeInstance::start(
            "mock-b",
            "gpu0",
            MockExecutor::factory_batched(1.0, Duration::ZERO, vec![1, 2, 4, 8]),
        )
        .unwrap();
        assert_eq!(inst.compiled_batch_sizes(), &[1, 2, 4, 8]);
    }

    #[test]
    fn batched_mock_counts_programs_and_pad_slots() {
        let inst = RuntimeInstance::start(
            "mock-b",
            "gpu0",
            MockExecutor::factory_batched(2.0, Duration::ZERO, vec![1, 2, 4, 8]),
        )
        .unwrap();
        // 8 rows = one compiled 8-program, no padding.
        let out = inst
            .exec_batch((0..8).map(|i| Arc::new(vec![i as f32])).collect())
            .unwrap();
        assert_eq!(out.programs, 1);
        assert_eq!(out.pad_slots, 0);
        assert_eq!(out.outputs[3], vec![6.0]);
        // 5 rows pad to the 8-program: still one dispatch, 3 pad slots,
        // and exactly 5 outputs (padded rows never surface).
        let out = inst
            .exec_batch((0..5).map(|i| Arc::new(vec![i as f32])).collect())
            .unwrap();
        assert_eq!(out.programs, 1);
        assert_eq!(out.pad_slots, 3);
        assert_eq!(out.outputs.len(), 5);
        // 11 rows = 8 + pad(3 -> 4): two programs, one pad slot.
        let out = inst
            .exec_batch((0..11).map(|i| Arc::new(vec![i as f32])).collect())
            .unwrap();
        assert_eq!(out.programs, 2);
        assert_eq!(out.pad_slots, 1);
        assert_eq!(out.outputs.len(), 11);
    }

    #[test]
    fn loop_mock_pays_dispatch_per_input_batched_mock_per_program() {
        // The per-input loop (ladder [1]) pays 8 dispatch delays for a
        // batch of 8; the batched-HLO ladder pays one.  This is the mock
        // model of exactly the win batched artifacts buy on hardware.
        let looped = RuntimeInstance::start(
            "mock-loop",
            "gpu0",
            MockExecutor::factory_batched(1.0, Duration::from_millis(20), vec![1]),
        )
        .unwrap();
        let t0 = Instant::now();
        let out = looped
            .exec_batch((0..8).map(|i| Arc::new(vec![i as f32])).collect())
            .unwrap();
        assert_eq!(out.programs, 8);
        assert!(t0.elapsed() >= Duration::from_millis(150), "{:?}", t0.elapsed());

        let batched = RuntimeInstance::start(
            "mock-b",
            "gpu0",
            MockExecutor::factory_batched(1.0, Duration::from_millis(20), vec![1, 8]),
        )
        .unwrap();
        let t0 = Instant::now();
        let out = batched
            .exec_batch((0..8).map(|i| Arc::new(vec![i as f32])).collect())
            .unwrap();
        assert_eq!(out.programs, 1);
        assert!(t0.elapsed() < Duration::from_millis(150), "{:?}", t0.elapsed());
    }

    #[test]
    fn exec_measures_compute_wall() {
        let inst = RuntimeInstance::start(
            "mock",
            "gpu0",
            MockExecutor::factory(1.0, Duration::from_millis(20)),
        )
        .unwrap();
        let out = inst.exec(vec![0.0]).unwrap();
        assert!(out.compute_wall >= Duration::from_millis(19), "{:?}", out.compute_wall);
    }

    #[test]
    fn executor_errors_propagate_and_instance_survives() {
        let factory: crate::runtime::ExecutorFactory = Box::new(|| {
            Ok(Box::new(MockExecutor::new(1.0).failing_after(1)) as Box<dyn Executor>)
        });
        let inst = RuntimeInstance::start("flaky", "gpu0", factory).unwrap();
        assert!(inst.exec(vec![1.0]).is_ok());
        assert!(inst.exec(vec![1.0]).is_err(), "second call fails");
        // instance still serves errors rather than hanging
        assert!(inst.exec(vec![1.0]).is_err());
    }

    #[test]
    fn stop_joins_thread() {
        let inst = RuntimeInstance::start(
            "mock",
            "gpu0",
            MockExecutor::factory(1.0, Duration::ZERO),
        )
        .unwrap();
        inst.stop();
        // after stop, a new instance can be created with the same name
        let inst2 = RuntimeInstance::start(
            "mock",
            "gpu0",
            MockExecutor::factory(1.0, Duration::ZERO),
        )
        .unwrap();
        assert!(inst2.exec(vec![1.0]).is_ok());
    }

    #[test]
    fn concurrent_exec_requests_serialize_on_instance() {
        let inst = Arc::new(
            RuntimeInstance::start(
                "mock",
                "gpu0",
                MockExecutor::factory(1.0, Duration::from_millis(5)),
            )
            .unwrap(),
        );
        let mut handles = Vec::new();
        for i in 0..6 {
            let inst = inst.clone();
            handles.push(std::thread::spawn(move || {
                inst.exec(vec![i as f32]).unwrap().output[0]
            }));
        }
        let mut got: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(inst.executions(), 6);
    }

    #[test]
    #[cfg(feature = "pjrt")]
    fn pjrt_instance_end_to_end() {
        use crate::runtime::{artifacts_available, artifacts_dir, PjrtExecutor, RuntimeBundle};
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        let b2 = bundle.clone();
        let factory: crate::runtime::ExecutorFactory = Box::new(move || {
            Ok(Box::new(PjrtExecutor::compile(&b2, "tinyyolo-gpu")?) as Box<dyn Executor>)
        });
        let inst = RuntimeInstance::start("tinyyolo-gpu", "gpu0", factory).unwrap();
        assert!(inst.cold_start_wall > Duration::ZERO);
        let input = vec![0.5f32; 1 * 64 * 64 * 3];
        let out = inst.exec(input).unwrap();
        assert_eq!(out.output.len(), 1 * 2 * 2 * 125);
    }
}
