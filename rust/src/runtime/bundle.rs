//! Runtime bundles: the deployable unit the paper stores in object storage.
//!
//! A bundle = `manifest.json` + one HLO-text artifact per accelerator
//! variant + `weights.bin`.  Produced by `python/compile/aot.py` at build
//! time; published into the object store with [`RuntimeBundle::publish`];
//! fetched and opened by node managers with [`RuntimeBundle::fetch`].

use crate::json::Json;
use crate::store::{keys, Blob, ObjectStore};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One weight tensor's location inside `weights.bin`.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// One compiled model variant (per accelerator kind).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub compute_dtype: String,
    pub tags: Vec<String>,
}

impl ArtifactSpec {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// A parsed runtime bundle with its raw payloads.
#[derive(Clone)]
pub struct RuntimeBundle {
    /// Logical runtime name (`tinyyolo`).
    pub name: String,
    pub manifest: Json,
    pub artifacts: Vec<ArtifactSpec>,
    pub weights: Vec<WeightSpec>,
    /// HLO text per artifact name.
    pub hlo_texts: BTreeMap<String, String>,
    /// The dense little-endian f32 weight blob (shared buffer: fetching
    /// a bundle from a cached store keeps the store's allocation).
    pub weight_blob: Blob,
}

impl RuntimeBundle {
    // ------------------------------------------------------------- parsing

    fn parse_manifest(name: &str, manifest: Json) -> Result<RuntimeBundle> {
        let mut artifacts = Vec::new();
        for a in manifest.arr_of("artifacts")? {
            let shapes = |key: &str| -> Result<Vec<usize>> {
                a.arr_of(key)?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad {key}")))
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: a.str_of("name")?.to_string(),
                file: a.str_of("file")?.to_string(),
                input_shape: shapes("input_shape")?,
                output_shape: shapes("output_shape")?,
                compute_dtype: a.str_of("compute_dtype")?.to_string(),
                tags: a
                    .arr_of("tags")?
                    .iter()
                    .filter_map(|t| t.as_str().map(String::from))
                    .collect(),
            });
        }
        let mut weights = Vec::new();
        for w in manifest.arr_of("weights")? {
            weights.push(WeightSpec {
                name: w.str_of("name")?.to_string(),
                shape: w
                    .arr_of("shape")?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad weight shape")))
                    .collect::<Result<Vec<_>>>()?,
                offset: w.usize_of("offset")?,
                len: w.usize_of("len")?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(RuntimeBundle {
            name: name.to_string(),
            manifest,
            artifacts,
            weights,
            hlo_texts: BTreeMap::new(),
            weight_blob: Blob::from(Vec::new()),
        })
    }

    /// Load a bundle from the local artifacts directory (build output).
    pub fn load_dir(name: &str, dir: impl AsRef<Path>) -> Result<RuntimeBundle> {
        let dir = dir.as_ref();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read manifest in {dir:?}"))?;
        let manifest =
            Json::parse(&manifest_text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let mut bundle = Self::parse_manifest(name, manifest)?;
        for art in bundle.artifacts.clone() {
            let text = std::fs::read_to_string(dir.join(&art.file))
                .with_context(|| format!("read artifact {}", art.file))?;
            bundle.hlo_texts.insert(art.name.clone(), text);
        }
        let weights_file = bundle
            .manifest
            .str_of("weights_file")
            .unwrap_or("weights.bin")
            .to_string();
        bundle.weight_blob = Blob::from(
            std::fs::read(dir.join(&weights_file))
                .with_context(|| format!("read {weights_file}"))?,
        );
        bundle.validate()?;
        Ok(bundle)
    }

    /// Publish this bundle into the object store under
    /// `runtimes/<name>/...` (idempotent; bodies are content-addressed).
    pub fn publish(&self, store: &dyn ObjectStore) -> Result<()> {
        let base = keys::runtime(&self.name);
        store.put(&format!("{base}/manifest.json"), self.manifest.to_string().as_bytes())?;
        for (variant, text) in &self.hlo_texts {
            store.put(&format!("{base}/{variant}.hlo.txt"), text.as_bytes())?;
        }
        store.put(&format!("{base}/weights.bin"), &self.weight_blob)?;
        Ok(())
    }

    /// Fetch a published bundle from the object store — what a node
    /// manager does the first time it sees an event for a runtime it has
    /// not yet materialized locally.
    pub fn fetch(name: &str, store: &dyn ObjectStore) -> Result<RuntimeBundle> {
        let base = keys::runtime(name);
        let manifest_bytes = store
            .get(&format!("{base}/manifest.json"))
            .with_context(|| format!("runtime bundle '{name}' not published"))?;
        let manifest = Json::parse(
            std::str::from_utf8(&manifest_bytes).context("manifest not utf-8")?,
        )
        .map_err(|e| anyhow!("parse manifest: {e}"))?;
        let mut bundle = Self::parse_manifest(name, manifest)?;
        for art in bundle.artifacts.clone() {
            let text = store.get(&format!("{base}/{}.hlo.txt", art.name))?;
            let text = std::str::from_utf8(&text).context("hlo not utf-8")?.to_string();
            bundle.hlo_texts.insert(art.name.clone(), text);
        }
        // shared buffer straight from the store (no copy)
        bundle.weight_blob = store.get(&format!("{base}/weights.bin"))?;
        bundle.validate()?;
        Ok(bundle)
    }

    // ------------------------------------------------------------ contents

    /// Internal consistency: every weight slice in bounds, artifacts have
    /// HLO text, shapes non-empty.
    pub fn validate(&self) -> Result<()> {
        for w in &self.weights {
            let end = w.offset + w.len;
            if end > self.weight_blob.len() {
                bail!("weight {} [{}..{end}) exceeds blob of {} bytes",
                      w.name, w.offset, self.weight_blob.len());
            }
            let elems: usize = w.shape.iter().product::<usize>().max(1);
            if elems * 4 != w.len {
                bail!("weight {} shape {:?} disagrees with byte len {}",
                      w.name, w.shape, w.len);
            }
        }
        for a in &self.artifacts {
            if !self.hlo_texts.contains_key(&a.name) {
                bail!("artifact {} missing HLO text", a.name);
            }
            if a.input_shape.is_empty() || a.output_shape.is_empty() {
                bail!("artifact {} has empty shapes", a.name);
            }
        }
        Ok(())
    }

    pub fn artifact(&self, variant: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == variant)
            .ok_or_else(|| anyhow!("unknown variant '{variant}' in bundle '{}'", self.name))
    }

    pub fn hlo_text(&self, variant: &str) -> Result<&str> {
        self.hlo_texts
            .get(variant)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("no HLO for variant '{variant}'"))
    }

    /// Decode one weight tensor as f32 (little-endian).
    pub fn weight_f32(&self, spec: &WeightSpec) -> Vec<f32> {
        let bytes = &self.weight_blob[spec.offset..spec.offset + spec.len];
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// All weights in manifest order — the order the AOT entry signature
    /// expects after the image parameter.
    pub fn weights_f32(&self) -> Vec<(Vec<usize>, Vec<f32>)> {
        self.weights
            .iter()
            .map(|w| (w.shape.clone(), self.weight_f32(w)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;

    /// A miniature synthetic bundle (no PJRT involved).
    pub(crate) fn tiny_bundle() -> RuntimeBundle {
        let weights: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let blob: Vec<u8> = weights.iter().flat_map(|f| f.to_le_bytes()).collect();
        let manifest = Json::parse(
            r#"{
              "model": "test",
              "weights_file": "weights.bin",
              "weights": [
                {"name": "[w]", "shape": [2, 2], "dtype": "f32", "offset": 0, "len": 16}
              ],
              "artifacts": [
                {"name": "m-gpu", "file": "m-gpu.hlo.txt",
                 "input_shape": [1, 2], "input_dtype": "f32",
                 "output_shape": [1, 2], "output_dtype": "f32",
                 "compute_dtype": "float32", "tags": ["gpu"]}
              ]
            }"#,
        )
        .unwrap();
        let mut b = RuntimeBundle::parse_manifest("m", manifest).unwrap();
        b.hlo_texts.insert("m-gpu".into(), "HloModule fake".into());
        b.weight_blob = Blob::from(blob);
        b.validate().unwrap();
        b
    }

    #[test]
    fn parse_and_accessors() {
        let b = tiny_bundle();
        assert_eq!(b.artifacts.len(), 1);
        let a = b.artifact("m-gpu").unwrap();
        assert_eq!(a.input_len(), 2);
        assert_eq!(a.tags, vec!["gpu".to_string()]);
        assert!(b.artifact("nope").is_err());
        assert_eq!(b.hlo_text("m-gpu").unwrap(), "HloModule fake");
    }

    #[test]
    fn weight_decoding() {
        let b = tiny_bundle();
        let w = b.weights_f32();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].0, vec![2, 2]);
        assert_eq!(w[0].1, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn validation_catches_out_of_bounds() {
        let mut b = tiny_bundle();
        b.weights[0].len = 999;
        assert!(b.validate().is_err());
    }

    #[test]
    fn validation_catches_shape_len_mismatch() {
        let mut b = tiny_bundle();
        b.weights[0].shape = vec![3, 3];
        assert!(b.validate().is_err());
    }

    #[test]
    fn publish_fetch_roundtrip() {
        let store = MemStore::new();
        let b = tiny_bundle();
        b.publish(&store).unwrap();
        assert!(store.exists("runtimes/m/manifest.json").unwrap());
        assert!(store.exists("runtimes/m/m-gpu.hlo.txt").unwrap());
        let fetched = RuntimeBundle::fetch("m", &store).unwrap();
        assert_eq!(fetched.artifacts, b.artifacts);
        assert_eq!(fetched.weights, b.weights);
        assert_eq!(fetched.weight_blob, b.weight_blob);
        assert_eq!(fetched.hlo_text("m-gpu").unwrap(), "HloModule fake");
    }

    #[test]
    fn fetch_missing_bundle_is_informative() {
        let store = MemStore::new();
        let err = match RuntimeBundle::fetch("ghost", &store) {
            Err(e) => e,
            Ok(_) => panic!("fetch of unpublished bundle must fail"),
        };
        assert!(format!("{err:#}").contains("not published"), "{err:#}");
    }

    #[test]
    fn load_real_artifacts_dir_if_present() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let b = RuntimeBundle::load_dir("tinyyolo", crate::runtime::artifacts_dir()).unwrap();
        assert_eq!(b.artifacts.len(), 2, "gpu + vpu variants");
        let gpu = b.artifact("tinyyolo-gpu").unwrap();
        assert_eq!(gpu.input_shape, vec![1, 64, 64, 3]);
        assert_eq!(gpu.output_shape, vec![1, 2, 2, 125]);
        assert_eq!(b.weights.len(), 16);
        assert!(b.hlo_text("tinyyolo-gpu").unwrap().starts_with("HloModule"));
    }
}
