//! Runtime bundles: the deployable unit the paper stores in object storage.
//!
//! A bundle = `manifest.json` + one HLO-text artifact per accelerator
//! variant + `weights.bin`.  Produced by `python/compile/aot.py` at build
//! time; published into the object store with [`RuntimeBundle::publish`];
//! fetched and opened by node managers with [`RuntimeBundle::fetch`].

use crate::json::Json;
use crate::store::{hex_sha256, keys, Blob, ObjectStore};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One weight tensor's location inside `weights.bin`.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// One compiled model variant (per accelerator kind).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub compute_dtype: String,
    pub tags: Vec<String>,
    /// Compiled micro-batch ladder (DESIGN.md §16): one device program per
    /// size, stored under the `.b{N}` stem convention next to `file`.
    /// Sorted ascending, deduped.  Bundles predating batched HLO omit the
    /// manifest field and default to `[input_shape[0]]` (i.e. batch 1).
    pub batch_sizes: Vec<usize>,
}

impl ArtifactSpec {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Elements in ONE input row (leading dim stripped): what each member
    /// of a micro-batch supplies regardless of which program serves it.
    pub fn input_row_len(&self) -> usize {
        self.input_shape[1..].iter().product()
    }

    /// Elements in one output row.
    pub fn output_row_len(&self) -> usize {
        self.output_shape[1..].iter().product()
    }

    /// Storage stem of the batch-`n` program: the batch-1 artifact keeps
    /// its legacy stem (`m-gpu`), batch-N inserts `.b{N}` (`m-gpu.b8`) —
    /// the convention `python/compile/aot.py::hlo_filename` writes.
    pub fn hlo_stem(&self, n: usize) -> String {
        if n == 1 {
            self.name.clone()
        } else {
            format!("{}.b{n}", self.name)
        }
    }
}

/// One device execution of a planned micro-batch: `rows` real inputs
/// served by the compiled batch-`program` artifact (`program - rows` pad
/// slots, zero-filled on the way in and discarded on the way out).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubBatch {
    pub rows: usize,
    pub program: usize,
}

impl SubBatch {
    pub fn pad_slots(&self) -> usize {
        self.program - self.rows
    }
}

/// Decompose a micro-batch of `n` rows into device programs drawn from the
/// compiled ladder (sorted ascending, non-empty, all sizes >= 1).
///
/// Selection rule (DESIGN.md §16): per remaining chunk `r`,
/// - an exact compiled size wins outright;
/// - otherwise pad `r` up to the next compiled size iff the padded program
///   would be at least half full (`2 * (high - r) <= high`) — one dispatch
///   beats a split whenever fewer than half the slots are wasted;
/// - otherwise run the largest compiled size below `r` and recurse on the
///   remainder.  When no compiled size fits below `r` (ladders without a
///   batch-1 rung), padding is unconditional — there is nothing to split to.
pub fn plan_batches(compiled: &[usize], n: usize) -> Result<Vec<SubBatch>> {
    if compiled.is_empty() || compiled[0] == 0 {
        bail!("compiled batch ladder empty or contains 0");
    }
    let mut plan = Vec::new();
    let mut r = n;
    while r > 0 {
        if compiled.binary_search(&r).is_ok() {
            plan.push(SubBatch { rows: r, program: r });
            break;
        }
        let low = compiled.iter().rev().find(|&&c| c < r).copied();
        let high = compiled.iter().find(|&&c| c > r).copied();
        match (low, high) {
            (_, Some(high)) if low.is_none() || 2 * (high - r) <= high => {
                plan.push(SubBatch { rows: r, program: high });
                break;
            }
            (Some(low), _) => {
                plan.push(SubBatch { rows: low, program: low });
                r -= low;
            }
            (None, None) => unreachable!("non-empty ladder has a low or high"),
        }
    }
    Ok(plan)
}

/// Derive the on-disk file of the batch-`n` program from the manifest's
/// batch-1 `file` field: `m-gpu.hlo.txt` -> `m-gpu.b8.hlo.txt`.
fn batch_file(file: &str, n: usize) -> String {
    if n == 1 {
        return file.to_string();
    }
    match file.strip_suffix(".hlo.txt") {
        Some(stem) => format!("{stem}.b{n}.hlo.txt"),
        None => format!("{file}.b{n}"),
    }
}

/// A parsed runtime bundle with its raw payloads.
#[derive(Clone)]
pub struct RuntimeBundle {
    /// Logical runtime name (`tinyyolo`).
    pub name: String,
    pub manifest: Json,
    pub artifacts: Vec<ArtifactSpec>,
    pub weights: Vec<WeightSpec>,
    /// HLO text per storage stem: the batch-1 program under the artifact
    /// name (`m-gpu`), batch-N programs under `{name}.b{N}` (`m-gpu.b8`).
    pub hlo_texts: BTreeMap<String, String>,
    /// The dense little-endian f32 weight blob (shared buffer: fetching
    /// a bundle from a cached store keeps the store's allocation).
    pub weight_blob: Blob,
}

impl RuntimeBundle {
    // ------------------------------------------------------------- parsing

    fn parse_manifest(name: &str, manifest: Json) -> Result<RuntimeBundle> {
        let mut artifacts = Vec::new();
        for a in manifest.arr_of("artifacts")? {
            let shapes = |key: &str| -> Result<Vec<usize>> {
                a.arr_of(key)?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad {key}")))
                    .collect()
            };
            let input_shape = shapes("input_shape")?;
            // Lenient: pre-batching manifests omit the ladder — the only
            // compiled program is the artifact itself.
            let mut batch_sizes = match a.get("batch_sizes").and_then(|v| v.as_arr()) {
                Some(arr) => arr.iter().filter_map(|v| v.as_usize()).collect(),
                None => vec![*input_shape.first().unwrap_or(&1)],
            };
            batch_sizes.sort_unstable();
            batch_sizes.dedup();
            if batch_sizes.first().map_or(true, |&b| b == 0) {
                bail!("artifact batch_sizes empty or contains 0");
            }
            artifacts.push(ArtifactSpec {
                name: a.str_of("name")?.to_string(),
                file: a.str_of("file")?.to_string(),
                input_shape,
                output_shape: shapes("output_shape")?,
                compute_dtype: a.str_of("compute_dtype")?.to_string(),
                tags: a
                    .arr_of("tags")?
                    .iter()
                    .filter_map(|t| t.as_str().map(String::from))
                    .collect(),
                batch_sizes,
            });
        }
        let mut weights = Vec::new();
        for w in manifest.arr_of("weights")? {
            weights.push(WeightSpec {
                name: w.str_of("name")?.to_string(),
                shape: w
                    .arr_of("shape")?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad weight shape")))
                    .collect::<Result<Vec<_>>>()?,
                offset: w.usize_of("offset")?,
                len: w.usize_of("len")?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(RuntimeBundle {
            name: name.to_string(),
            manifest,
            artifacts,
            weights,
            hlo_texts: BTreeMap::new(),
            weight_blob: Blob::from(Vec::new()),
        })
    }

    /// Load a bundle from the local artifacts directory (build output).
    pub fn load_dir(name: &str, dir: impl AsRef<Path>) -> Result<RuntimeBundle> {
        let dir = dir.as_ref();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read manifest in {dir:?}"))?;
        let manifest =
            Json::parse(&manifest_text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let mut bundle = Self::parse_manifest(name, manifest)?;
        for art in bundle.artifacts.clone() {
            for &n in &art.batch_sizes {
                let file = batch_file(&art.file, n);
                let text = std::fs::read_to_string(dir.join(&file))
                    .with_context(|| format!("read artifact {file}"))?;
                bundle.hlo_texts.insert(art.hlo_stem(n), text);
            }
        }
        let weights_file = bundle
            .manifest
            .str_of("weights_file")
            .unwrap_or("weights.bin")
            .to_string();
        bundle.weight_blob = Blob::from(
            std::fs::read(dir.join(&weights_file))
                .with_context(|| format!("read {weights_file}"))?,
        );
        bundle.validate()?;
        Ok(bundle)
    }

    /// Content fingerprint over everything `publish` would upload: the
    /// manifest text, every HLO text in stem order, and the weight blob.
    /// Same digest machinery as the store's CAS path (`hex_sha256`).
    pub fn content_fingerprint(&self) -> String {
        let mut payload: Vec<u8> = Vec::new();
        payload.extend_from_slice(self.manifest.to_string().as_bytes());
        for (stem, text) in &self.hlo_texts {
            payload.extend_from_slice(stem.as_bytes());
            payload.extend_from_slice(text.as_bytes());
        }
        payload.extend_from_slice(&self.weight_blob);
        hex_sha256(&payload)
    }

    /// Publish this bundle into the object store under
    /// `runtimes/<name>/...`.
    ///
    /// Idempotent: uploads are keyed by the bundle's content fingerprint.
    /// A `fingerprint` marker object is written LAST, so a re-publish of
    /// an unchanged bundle is one small GET, while a crash mid-upload
    /// leaves no marker and the next publish re-uploads everything.
    pub fn publish(&self, store: &dyn ObjectStore) -> Result<()> {
        let base = keys::runtime(&self.name);
        let fp = self.content_fingerprint();
        let fp_key = format!("{base}/fingerprint");
        if let Ok(prev) = store.get(&fp_key) {
            if prev.as_ref() == fp.as_bytes() {
                return Ok(());
            }
        }
        store.put(&format!("{base}/manifest.json"), self.manifest.to_string().as_bytes())?;
        for (stem, text) in &self.hlo_texts {
            store.put(&format!("{base}/{stem}.hlo.txt"), text.as_bytes())?;
        }
        store.put(&format!("{base}/weights.bin"), &self.weight_blob)?;
        store.put(&fp_key, fp.as_bytes())?;
        Ok(())
    }

    /// Fetch a published bundle from the object store — what a node
    /// manager does the first time it sees an event for a runtime it has
    /// not yet materialized locally.
    pub fn fetch(name: &str, store: &dyn ObjectStore) -> Result<RuntimeBundle> {
        let base = keys::runtime(name);
        let manifest_bytes = store
            .get(&format!("{base}/manifest.json"))
            .with_context(|| format!("runtime bundle '{name}' not published"))?;
        let manifest = Json::parse(
            std::str::from_utf8(&manifest_bytes).context("manifest not utf-8")?,
        )
        .map_err(|e| anyhow!("parse manifest: {e}"))?;
        let mut bundle = Self::parse_manifest(name, manifest)?;
        for art in bundle.artifacts.clone() {
            for &n in &art.batch_sizes {
                let stem = art.hlo_stem(n);
                let text = store.get(&format!("{base}/{stem}.hlo.txt"))?;
                let text = std::str::from_utf8(&text).context("hlo not utf-8")?.to_string();
                bundle.hlo_texts.insert(stem, text);
            }
        }
        // shared buffer straight from the store (no copy)
        bundle.weight_blob = store.get(&format!("{base}/weights.bin"))?;
        bundle.validate()?;
        Ok(bundle)
    }

    // ------------------------------------------------------------ contents

    /// Internal consistency: every weight slice in bounds, artifacts have
    /// HLO text, shapes non-empty.
    pub fn validate(&self) -> Result<()> {
        for w in &self.weights {
            let end = w.offset + w.len;
            if end > self.weight_blob.len() {
                bail!("weight {} [{}..{end}) exceeds blob of {} bytes",
                      w.name, w.offset, self.weight_blob.len());
            }
            let elems: usize = w.shape.iter().product::<usize>().max(1);
            if elems * 4 != w.len {
                bail!("weight {} shape {:?} disagrees with byte len {}",
                      w.name, w.shape, w.len);
            }
        }
        for a in &self.artifacts {
            for &n in &a.batch_sizes {
                if !self.hlo_texts.contains_key(&a.hlo_stem(n)) {
                    bail!("artifact {} missing HLO text for batch {n}", a.name);
                }
            }
            if a.input_shape.is_empty() || a.output_shape.is_empty() {
                bail!("artifact {} has empty shapes", a.name);
            }
        }
        Ok(())
    }

    pub fn artifact(&self, variant: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == variant)
            .ok_or_else(|| anyhow!("unknown variant '{variant}' in bundle '{}'", self.name))
    }

    pub fn hlo_text(&self, variant: &str) -> Result<&str> {
        self.hlo_texts
            .get(variant)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("no HLO for variant '{variant}'"))
    }

    /// HLO text of the batch-`n` program of `variant`.
    pub fn hlo_text_at(&self, variant: &str, n: usize) -> Result<&str> {
        let art = self.artifact(variant)?;
        self.hlo_texts
            .get(&art.hlo_stem(n))
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("no batch-{n} HLO for variant '{variant}'"))
    }

    /// Plan how a micro-batch of `n` rows maps onto `variant`'s compiled
    /// ladder: largest compiled size <= n per step, padding up to the next
    /// size when the padded program stays at least half full (see
    /// [`plan_batches`]).
    pub fn select_batch_variant(&self, variant: &str, n: usize) -> Result<Vec<SubBatch>> {
        plan_batches(&self.artifact(variant)?.batch_sizes, n)
    }

    /// Decode one weight tensor as f32 (little-endian).
    pub fn weight_f32(&self, spec: &WeightSpec) -> Vec<f32> {
        let bytes = &self.weight_blob[spec.offset..spec.offset + spec.len];
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// All weights in manifest order — the order the AOT entry signature
    /// expects after the image parameter.
    pub fn weights_f32(&self) -> Vec<(Vec<usize>, Vec<f32>)> {
        self.weights
            .iter()
            .map(|w| (w.shape.clone(), self.weight_f32(w)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;

    /// A miniature synthetic bundle (no PJRT involved).
    pub(crate) fn tiny_bundle() -> RuntimeBundle {
        let weights: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let blob: Vec<u8> = weights.iter().flat_map(|f| f.to_le_bytes()).collect();
        let manifest = Json::parse(
            r#"{
              "model": "test",
              "weights_file": "weights.bin",
              "weights": [
                {"name": "[w]", "shape": [2, 2], "dtype": "f32", "offset": 0, "len": 16}
              ],
              "artifacts": [
                {"name": "m-gpu", "file": "m-gpu.hlo.txt",
                 "input_shape": [1, 2], "input_dtype": "f32",
                 "output_shape": [1, 2], "output_dtype": "f32",
                 "compute_dtype": "float32", "tags": ["gpu"]}
              ]
            }"#,
        )
        .unwrap();
        let mut b = RuntimeBundle::parse_manifest("m", manifest).unwrap();
        b.hlo_texts.insert("m-gpu".into(), "HloModule fake".into());
        b.weight_blob = Blob::from(blob);
        b.validate().unwrap();
        b
    }

    /// A synthetic bundle with a compiled batch ladder {1, 2, 4, 8}.
    pub(crate) fn batched_bundle() -> RuntimeBundle {
        let weights: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let blob: Vec<u8> = weights.iter().flat_map(|f| f.to_le_bytes()).collect();
        let manifest = Json::parse(
            r#"{
              "model": "test",
              "weights_file": "weights.bin",
              "weights": [
                {"name": "[w]", "shape": [2, 2], "dtype": "f32", "offset": 0, "len": 16}
              ],
              "artifacts": [
                {"name": "m-gpu", "file": "m-gpu.hlo.txt",
                 "input_shape": [1, 2], "input_dtype": "f32",
                 "output_shape": [1, 2], "output_dtype": "f32",
                 "compute_dtype": "float32", "tags": ["gpu"],
                 "batch_sizes": [1, 2, 4, 8]}
              ]
            }"#,
        )
        .unwrap();
        let mut b = RuntimeBundle::parse_manifest("m", manifest).unwrap();
        for n in [1usize, 2, 4, 8] {
            b.hlo_texts
                .insert(b.artifacts[0].hlo_stem(n), format!("HloModule fake b{n}"));
        }
        b.weight_blob = Blob::from(blob);
        b.validate().unwrap();
        b
    }

    #[test]
    fn parse_and_accessors() {
        let b = tiny_bundle();
        assert_eq!(b.artifacts.len(), 1);
        let a = b.artifact("m-gpu").unwrap();
        assert_eq!(a.input_len(), 2);
        assert_eq!(a.tags, vec!["gpu".to_string()]);
        assert!(b.artifact("nope").is_err());
        assert_eq!(b.hlo_text("m-gpu").unwrap(), "HloModule fake");
    }

    #[test]
    fn weight_decoding() {
        let b = tiny_bundle();
        let w = b.weights_f32();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].0, vec![2, 2]);
        assert_eq!(w[0].1, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn validation_catches_out_of_bounds() {
        let mut b = tiny_bundle();
        b.weights[0].len = 999;
        assert!(b.validate().is_err());
    }

    #[test]
    fn validation_catches_shape_len_mismatch() {
        let mut b = tiny_bundle();
        b.weights[0].shape = vec![3, 3];
        assert!(b.validate().is_err());
    }

    #[test]
    fn publish_fetch_roundtrip() {
        let store = MemStore::new();
        let b = tiny_bundle();
        b.publish(&store).unwrap();
        assert!(store.exists("runtimes/m/manifest.json").unwrap());
        assert!(store.exists("runtimes/m/m-gpu.hlo.txt").unwrap());
        let fetched = RuntimeBundle::fetch("m", &store).unwrap();
        assert_eq!(fetched.artifacts, b.artifacts);
        assert_eq!(fetched.weights, b.weights);
        assert_eq!(fetched.weight_blob, b.weight_blob);
        assert_eq!(fetched.hlo_text("m-gpu").unwrap(), "HloModule fake");
    }

    #[test]
    fn legacy_manifest_defaults_to_own_batch() {
        let b = tiny_bundle();
        assert_eq!(b.artifacts[0].batch_sizes, vec![1]);
        assert_eq!(b.artifacts[0].hlo_stem(1), "m-gpu");
        assert_eq!(b.artifacts[0].input_row_len(), 2);
    }

    #[test]
    fn batch_file_derivation() {
        assert_eq!(batch_file("m-gpu.hlo.txt", 1), "m-gpu.hlo.txt");
        assert_eq!(batch_file("m-gpu.hlo.txt", 8), "m-gpu.b8.hlo.txt");
        assert_eq!(batch_file("odd-name", 4), "odd-name.b4");
    }

    #[test]
    fn plan_exact_sizes_take_one_program() {
        let ladder = [1usize, 2, 4, 8, 16, 32];
        for n in ladder {
            assert_eq!(
                plan_batches(&ladder, n).unwrap(),
                vec![SubBatch { rows: n, program: n }],
            );
        }
    }

    #[test]
    fn plan_pads_when_program_at_least_half_full() {
        let ladder = [1usize, 2, 4, 8, 16, 32];
        // 5 rows in an 8-program: 3 pad slots, 8-program > half full.
        assert_eq!(
            plan_batches(&ladder, 5).unwrap(),
            vec![SubBatch { rows: 5, program: 8 }],
        );
        assert_eq!(plan_batches(&ladder, 5).unwrap()[0].pad_slots(), 3);
        // 7 rows pad to 8 (1 slot) instead of splitting 4+2+1.
        assert_eq!(
            plan_batches(&ladder, 7).unwrap(),
            vec![SubBatch { rows: 7, program: 8 }],
        );
    }

    #[test]
    fn plan_splits_when_padding_would_waste_over_half() {
        // Sparse ladder: 3 rows against {2, 8} — padding to 8 would leave
        // 5 of 8 slots empty, so split 2 + pad 1-to-2.
        assert_eq!(
            plan_batches(&[2, 8], 3).unwrap(),
            vec![
                SubBatch { rows: 2, program: 2 },
                SubBatch { rows: 1, program: 2 },
            ],
        );
        // 40 rows against {1,2,4,8,16,32}: 32 + 8, no padding.
        assert_eq!(
            plan_batches(&[1, 2, 4, 8, 16, 32], 40).unwrap(),
            vec![
                SubBatch { rows: 32, program: 32 },
                SubBatch { rows: 8, program: 8 },
            ],
        );
    }

    #[test]
    fn plan_pads_unconditionally_below_smallest_program() {
        // Ladder without a batch-1 rung: nothing to split down to.
        assert_eq!(
            plan_batches(&[8], 2).unwrap(),
            vec![SubBatch { rows: 2, program: 8 }],
        );
        assert!(plan_batches(&[], 4).is_err());
        assert!(plan_batches(&[0, 2], 4).is_err());
    }

    #[test]
    fn plan_conserves_rows() {
        let ladders: [&[usize]; 4] = [&[1, 2, 4, 8, 16, 32], &[2, 8], &[8], &[1, 3, 5]];
        for ladder in ladders {
            for n in 1..=64usize {
                let plan = plan_batches(ladder, n).unwrap();
                let rows: usize = plan.iter().map(|s| s.rows).sum();
                assert_eq!(rows, n, "ladder {ladder:?} n {n}");
                for s in &plan {
                    assert!(ladder.contains(&s.program), "{ladder:?} {n} -> {s:?}");
                    assert!(s.rows <= s.program);
                }
            }
        }
    }

    #[test]
    fn batched_bundle_publish_fetch_roundtrip() {
        let store = MemStore::new();
        let b = batched_bundle();
        b.publish(&store).unwrap();
        assert!(store.exists("runtimes/m/m-gpu.hlo.txt").unwrap());
        assert!(store.exists("runtimes/m/m-gpu.b8.hlo.txt").unwrap());
        let fetched = RuntimeBundle::fetch("m", &store).unwrap();
        assert_eq!(fetched.artifacts[0].batch_sizes, vec![1, 2, 4, 8]);
        assert_eq!(fetched.hlo_text_at("m-gpu", 8).unwrap(), "HloModule fake b8");
        assert_eq!(fetched.hlo_text("m-gpu").unwrap(), "HloModule fake b1");
        assert_eq!(
            fetched.select_batch_variant("m-gpu", 6).unwrap(),
            vec![SubBatch { rows: 6, program: 8 }],
        );
    }

    /// Store wrapper that counts mutating puts — the idempotence probe.
    struct CountingStore {
        inner: MemStore,
        puts: std::sync::atomic::AtomicUsize,
    }

    impl ObjectStore for CountingStore {
        fn put(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
            self.puts.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            self.inner.put(key, data)
        }
        fn get(&self, key: &str) -> anyhow::Result<Blob> {
            self.inner.get(key)
        }
        fn exists(&self, key: &str) -> anyhow::Result<bool> {
            self.inner.exists(key)
        }
        fn delete(&self, key: &str) -> anyhow::Result<()> {
            self.inner.delete(key)
        }
        fn list(&self, prefix: &str) -> anyhow::Result<Vec<String>> {
            self.inner.list(prefix)
        }
    }

    #[test]
    fn republish_unchanged_bundle_uploads_nothing() {
        let store = CountingStore {
            inner: MemStore::new(),
            puts: std::sync::atomic::AtomicUsize::new(0),
        };
        let b = batched_bundle();
        b.publish(&store).unwrap();
        let first = store.puts.load(std::sync::atomic::Ordering::SeqCst);
        // manifest + 4 ladder programs + weights + fingerprint marker
        assert_eq!(first, 7);
        b.publish(&store).unwrap();
        assert_eq!(
            store.puts.load(std::sync::atomic::Ordering::SeqCst),
            first,
            "re-publishing an unchanged bundle must not re-upload"
        );
        // A changed bundle DOES re-upload (fingerprint mismatch).
        let mut b2 = batched_bundle();
        b2.hlo_texts.insert("m-gpu".into(), "HloModule changed".into());
        b2.publish(&store).unwrap();
        assert!(store.puts.load(std::sync::atomic::Ordering::SeqCst) > first);
        let fetched = RuntimeBundle::fetch("m", &store).unwrap();
        assert_eq!(fetched.hlo_text("m-gpu").unwrap(), "HloModule changed");
    }

    #[test]
    fn fetch_missing_bundle_is_informative() {
        let store = MemStore::new();
        let err = match RuntimeBundle::fetch("ghost", &store) {
            Err(e) => e,
            Ok(_) => panic!("fetch of unpublished bundle must fail"),
        };
        assert!(format!("{err:#}").contains("not published"), "{err:#}");
    }

    #[test]
    fn load_real_artifacts_dir_if_present() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let b = RuntimeBundle::load_dir("tinyyolo", crate::runtime::artifacts_dir()).unwrap();
        assert_eq!(b.artifacts.len(), 2, "gpu + vpu variants");
        let gpu = b.artifact("tinyyolo-gpu").unwrap();
        assert_eq!(gpu.input_shape, vec![1, 64, 64, 3]);
        assert_eq!(gpu.output_shape, vec![1, 2, 2, 125]);
        assert_eq!(b.weights.len(), 16);
        assert!(b.hlo_text("tinyyolo-gpu").unwrap().starts_with("HloModule"));
    }
}
