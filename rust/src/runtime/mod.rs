//! Runtime layer: loading AOT artifacts and running them via PJRT.
//!
//! Paper §IV-D: *"a runtime instance is a process running on a worker node
//! that can fulfill user invocations using its runtime"*, with different
//! instances of the same logical runtime implemented per accelerator type.
//! Here:
//!
//! * [`bundle::RuntimeBundle`] — the runtime implementation package: the
//!   AOT manifest, per-variant HLO text, and the weight blob.  Published
//!   to / fetched from the object store exactly like the paper's runtime
//!   bundles in Minio.
//! * [`pjrt::PjrtExecutor`] — compiles one variant's HLO on a PJRT CPU
//!   client and executes it.  **Python is not involved**: this is the
//!   entire request-path compute stack.
//! * [`instance::RuntimeInstance`] — the process-model wrapper: a
//!   dedicated OS thread owning its executor (PJRT clients are not
//!   `Send`), fed through a channel.  Cold start = thread spawn + HLO
//!   compile + weight upload; warm = channel send.
//! * [`pool::InstancePool`] — the node manager's warm-instance cache.

pub mod bundle;
pub mod instance;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod pool;

pub use bundle::{plan_batches, ArtifactSpec, RuntimeBundle, SubBatch, WeightSpec};
pub use instance::{BatchOutcome, BatchRun, ExecOutcome, Executor, RuntimeInstance};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtExecutor;
pub use pool::InstancePool;

use anyhow::Result;

/// Executor factory: runs *inside* the instance thread (PJRT handles are
/// not `Send`, and the paper's instances are isolated processes anyway).
pub type ExecutorFactory = Box<dyn FnOnce() -> Result<Box<dyn Executor>> + Send>;

/// Resolve the artifacts directory: `$HARDLESS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("HARDLESS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True when `make artifacts` has produced the AOT outputs (integration
/// tests that need real PJRT execution are skipped otherwise).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").is_file()
}
