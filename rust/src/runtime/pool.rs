//! Warm instance pool — the node manager's cold-start avoidance cache.
//!
//! Paper §IV-D: node managers *"minimize setup times and switching costs,
//! in the serverless context typically referred to as cold-starts"* by
//! preferring queued work whose runtime is already warm.  The pool tracks
//! live [`RuntimeInstance`]s per (variant, device), hands idle ones to
//! workers, and evicts least-recently-used instances when capacity is
//! needed for a different variant (the "switching cost" case).

use super::instance::RuntimeInstance;
use anyhow::Result;
use std::sync::{Arc, Mutex};

/// Pool key: a warm instance is specific to a variant *and* a device.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PoolKey {
    variant: String,
    device_id: String,
}

struct Entry {
    instance: Arc<RuntimeInstance>,
    busy: bool,
    last_used_seq: u64,
}

#[derive(Default)]
struct Inner {
    entries: Vec<(PoolKey, Entry)>,
    seq: u64,
    cold_starts: u64,
    warm_hits: u64,
    evictions: u64,
}

/// Pool statistics (exported with node metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub live: usize,
    pub busy: usize,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub evictions: u64,
}

/// Guard marking an instance busy; returns it to the pool on drop.
pub struct PooledInstance {
    pub instance: Arc<RuntimeInstance>,
    pool: Arc<InstancePool>,
    key_variant: String,
    key_device: String,
    /// Whether this checkout was a warm hit (false = freshly cold-started).
    pub warm: bool,
}

impl Drop for PooledInstance {
    fn drop(&mut self) {
        self.pool
            .release(&self.key_variant, &self.key_device);
    }
}

/// The per-node warm pool.
pub struct InstancePool {
    inner: Mutex<Inner>,
    /// Max live instances across all variants/devices on this node.
    capacity: usize,
}

impl InstancePool {
    pub fn new(capacity: usize) -> Arc<InstancePool> {
        assert!(capacity > 0);
        Arc::new(InstancePool { inner: Mutex::new(Inner::default()), capacity })
    }

    /// Variants with at least one idle warm instance — feeds the node's
    /// `TakeFilter::warm` set.
    pub fn warm_variants(&self) -> Vec<String> {
        let inner = self.inner.lock().expect("pool poisoned");
        let mut v: Vec<String> = inner
            .entries
            .iter()
            .filter(|(_, e)| !e.busy)
            .map(|(k, _)| k.variant.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Whether an idle warm instance exists for (variant, device) — the
    /// per-device warmth check the scheduler and placement logic use.
    pub fn has_idle(&self, variant: &str, device_id: &str) -> bool {
        let inner = self.inner.lock().expect("pool poisoned");
        inner
            .entries
            .iter()
            .any(|(k, e)| k.variant == variant && k.device_id == device_id && !e.busy)
    }

    /// Check out a warm idle instance for (variant, device), if any.
    pub fn acquire_warm(
        self: &Arc<InstancePool>,
        variant: &str,
        device_id: &str,
    ) -> Option<PooledInstance> {
        let mut inner = self.inner.lock().expect("pool poisoned");
        inner.seq += 1;
        let seq = inner.seq;
        for (k, e) in inner.entries.iter_mut() {
            if k.variant == variant && k.device_id == device_id && !e.busy {
                e.busy = true;
                e.last_used_seq = seq;
                let inst = e.instance.clone();
                inner.warm_hits += 1;
                return Some(PooledInstance {
                    instance: inst,
                    pool: self.clone(),
                    key_variant: variant.to_string(),
                    key_device: device_id.to_string(),
                    warm: true,
                });
            }
        }
        None
    }

    /// Check out an instance, cold-starting one via `factory` when no warm
    /// instance exists.  Evicts the LRU idle instance if at capacity.
    pub fn acquire_or_start(
        self: &Arc<InstancePool>,
        variant: &str,
        device_id: &str,
        factory: impl FnOnce() -> Result<RuntimeInstance>,
    ) -> Result<PooledInstance> {
        if let Some(warm) = self.acquire_warm(variant, device_id) {
            return Ok(warm);
        }
        // Evict before starting so capacity holds even if factory is slow.
        self.evict_lru_if_full()?;
        let instance = Arc::new(factory()?);
        let mut inner = self.inner.lock().expect("pool poisoned");
        inner.seq += 1;
        let seq = inner.seq;
        inner.cold_starts += 1;
        inner.entries.push((
            PoolKey { variant: variant.to_string(), device_id: device_id.to_string() },
            Entry { instance: instance.clone(), busy: true, last_used_seq: seq },
        ));
        Ok(PooledInstance {
            instance,
            pool: self.clone(),
            key_variant: variant.to_string(),
            key_device: device_id.to_string(),
            warm: false,
        })
    }

    fn evict_lru_if_full(&self) -> Result<()> {
        let mut inner = self.inner.lock().expect("pool poisoned");
        if inner.entries.len() < self.capacity {
            return Ok(());
        }
        // Find the least-recently-used idle entry.
        let victim = inner
            .entries
            .iter()
            .enumerate()
            .filter(|(_, (_, e))| !e.busy)
            .min_by_key(|(_, (_, e))| e.last_used_seq)
            .map(|(i, _)| i);
        match victim {
            Some(i) => {
                inner.entries.remove(i);
                inner.evictions += 1;
                Ok(())
            }
            None => anyhow::bail!(
                "instance pool saturated: {} busy instances at capacity {}",
                inner.entries.len(),
                self.capacity
            ),
        }
    }

    fn release(&self, variant: &str, device_id: &str) {
        let mut inner = self.inner.lock().expect("pool poisoned");
        inner.seq += 1;
        let seq = inner.seq;
        if let Some((_, e)) = inner
            .entries
            .iter_mut()
            .find(|(k, e)| k.variant == variant && k.device_id == device_id && e.busy)
        {
            e.busy = false;
            e.last_used_seq = seq;
        }
    }

    /// Drop all idle instances (node drain / scale-to-zero).
    pub fn drain_idle(&self) -> usize {
        let mut inner = self.inner.lock().expect("pool poisoned");
        let before = inner.entries.len();
        inner.entries.retain(|(_, e)| e.busy);
        before - inner.entries.len()
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().expect("pool poisoned");
        PoolStats {
            live: inner.entries.len(),
            busy: inner.entries.iter().filter(|(_, e)| e.busy).count(),
            cold_starts: inner.cold_starts,
            warm_hits: inner.warm_hits,
            evictions: inner.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::instance::MockExecutor;
    use std::time::Duration;

    fn mock_instance(variant: &str, device: &str) -> Result<RuntimeInstance> {
        RuntimeInstance::start(variant, device, MockExecutor::factory(1.0, Duration::ZERO))
    }

    #[test]
    fn cold_then_warm() {
        let pool = InstancePool::new(4);
        {
            let inst = pool
                .acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0"))
                .unwrap();
            assert!(!inst.warm, "first checkout is a cold start");
        }
        let inst = pool
            .acquire_or_start("v1", "gpu0", || panic!("must not cold start"))
            .unwrap();
        assert!(inst.warm);
        let s = pool.stats();
        assert_eq!((s.cold_starts, s.warm_hits), (1, 1));
    }

    #[test]
    fn busy_instance_not_shared() {
        let pool = InstancePool::new(4);
        let a = pool
            .acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0"))
            .unwrap();
        // same variant+device while busy -> second cold start
        let b = pool
            .acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0"))
            .unwrap();
        assert!(!b.warm);
        drop(a);
        drop(b);
        assert_eq!(pool.stats().live, 2);
    }

    #[test]
    fn warm_keyed_by_device_and_variant() {
        let pool = InstancePool::new(8);
        drop(pool.acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0")).unwrap());
        assert!(pool.acquire_warm("v1", "gpu1").is_none(), "different device");
        assert!(pool.acquire_warm("v2", "gpu0").is_none(), "different variant");
        assert!(pool.acquire_warm("v1", "gpu0").is_some());
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let pool = InstancePool::new(2);
        drop(pool.acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0")).unwrap());
        drop(pool.acquire_or_start("v2", "gpu0", || mock_instance("v2", "gpu0")).unwrap());
        // touch v1 so v2 becomes LRU
        drop(pool.acquire_warm("v1", "gpu0").unwrap());
        drop(pool.acquire_or_start("v3", "gpu0", || mock_instance("v3", "gpu0")).unwrap());
        assert_eq!(pool.stats().evictions, 1);
        assert!(pool.acquire_warm("v2", "gpu0").is_none(), "v2 evicted as LRU");
        assert!(pool.acquire_warm("v1", "gpu0").is_some(), "v1 kept");
    }

    #[test]
    fn saturated_pool_errors() {
        let pool = InstancePool::new(1);
        let _busy = pool
            .acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0"))
            .unwrap();
        let err = match pool.acquire_or_start("v2", "gpu0", || mock_instance("v2", "gpu0")) {
            Err(e) => e,
            Ok(_) => panic!("acquire must fail when saturated"),
        };
        assert!(format!("{err}").contains("saturated"));
    }

    #[test]
    fn warm_variants_reflect_idle_only() {
        let pool = InstancePool::new(4);
        let busy = pool
            .acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0"))
            .unwrap();
        assert!(pool.warm_variants().is_empty(), "busy instance is not warm-available");
        drop(busy);
        assert_eq!(pool.warm_variants(), vec!["v1".to_string()]);
    }

    #[test]
    fn drain_idle_keeps_busy() {
        let pool = InstancePool::new(4);
        let busy = pool
            .acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0"))
            .unwrap();
        drop(pool.acquire_or_start("v2", "gpu0", || mock_instance("v2", "gpu0")).unwrap());
        assert_eq!(pool.drain_idle(), 1);
        assert_eq!(pool.stats().live, 1);
        drop(busy);
    }

    #[test]
    fn release_happens_via_guard_drop_even_on_panic() {
        let pool = InstancePool::new(4);
        let p2 = pool.clone();
        let _ = std::thread::spawn(move || {
            let _inst = p2
                .acquire_or_start("v1", "gpu0", || mock_instance("v1", "gpu0"))
                .unwrap();
            panic!("worker crashed mid-invocation");
        })
        .join();
        assert_eq!(pool.stats().busy, 0, "guard returned instance on panic");
        assert!(pool.acquire_warm("v1", "gpu0").is_some());
    }
}
