//! PJRT executor: compile + run one AOT model variant.
//!
//! The Rust half of the AOT bridge (see `/opt/xla-example/load_hlo` and
//! `python/compile/aot.py`): HLO **text** is parsed with the XLA text
//! parser (`parse_and_return_unverified_module`, which reassigns
//! instruction ids — jax ≥0.5 emits 64-bit ids that xla_extension 0.5.1
//! rejects in proto form), compiled on the PJRT CPU client, and executed
//! with the image plus the bundle's weight literals.
//!
//! `PjrtExecutor` is intentionally **not `Send`** (the underlying client
//! is `Rc`-based); it lives inside its [`super::RuntimeInstance`] thread,
//! mirroring the paper's process-per-instance isolation.

use super::bundle::{plan_batches, RuntimeBundle};
use super::instance::{BatchRun, Executor};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// A compiled model variant bound to a PJRT client: one loaded executable
/// per compiled batch-ladder rung (legacy bundles have exactly one).
pub struct PjrtExecutor {
    /// Loaded executables keyed by leading (batch) dimension.
    exes: BTreeMap<usize, PjRtLoadedExecutable>,
    /// Weight literals in entry-signature order (after the image).
    weight_literals: Vec<Literal>,
    input_shape: Vec<usize>,
    input_len: usize,
    output_len: usize,
    /// Compiled batch ladder (sorted ascending; `[base_batch]` for
    /// pre-batching bundles).
    batch_sizes: Vec<usize>,
    /// The base artifact's own leading dim (1 in practice).
    base_batch: usize,
    variant: String,
}

impl PjrtExecutor {
    /// Compile `variant` from `bundle` on a fresh PJRT CPU client.
    ///
    /// This is the cold-start path: client creation + HLO parse + XLA
    /// compilation (once per batch-ladder rung) + weight literal upload
    /// all happen here.
    pub fn compile(bundle: &RuntimeBundle, variant: &str) -> Result<PjrtExecutor> {
        let art = bundle.artifact(variant)?.clone();
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        for &n in &art.batch_sizes {
            let hlo = bundle.hlo_text_at(variant, n)?;
            let proto = HloModuleProto::parse_and_return_unverified_module(hlo.as_bytes())
                .with_context(|| format!("parse HLO text for {variant} b{n}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("XLA compile {variant} b{n}"))?;
            exes.insert(n, exe);
        }

        let mut weight_literals = Vec::with_capacity(bundle.weights.len());
        for (shape, data) in bundle.weights_f32() {
            weight_literals.push(make_literal(&data, &shape)?);
        }
        Ok(PjrtExecutor {
            exes,
            weight_literals,
            input_len: art.input_len(),
            input_shape: art.input_shape.clone(),
            output_len: art.output_len(),
            batch_sizes: art.batch_sizes.clone(),
            base_batch: *art.input_shape.first().unwrap_or(&1),
            variant: variant.to_string(),
        })
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Execute the batch-`n` program on a packed leading-dim literal and
    /// read back the flat f32 output (length-checked).
    fn execute_program(&self, n: usize, packed: &[f32]) -> Result<Vec<f32>> {
        let exe = self
            .exes
            .get(&n)
            .ok_or_else(|| anyhow!("variant {} has no compiled batch-{n} program", self.variant))?;
        let mut shape: Vec<usize> = vec![n];
        shape.extend_from_slice(&self.input_shape[1..]);
        let img = make_literal(packed, &shape)?;
        let mut args: Vec<&Literal> = Vec::with_capacity(1 + self.weight_literals.len());
        args.push(&img);
        args.extend(self.weight_literals.iter());
        let result = exe.execute::<&Literal>(&args)?;
        let out = result[0][0]
            .to_literal_sync()
            .context("readback")?
            .to_tuple1()
            .context("unwrap 1-tuple (AOT lowers with return_tuple=True)")?;
        let values = out.to_vec::<f32>()?;
        let expect = n * self.output_len / self.base_batch;
        if values.len() != expect {
            bail!(
                "variant {} b{n} produced {} f32s, manifest implies {expect}",
                self.variant,
                values.len(),
            );
        }
        Ok(values)
    }
}

/// Build an f32 literal of `shape` from `data`.
fn make_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let expect: usize = shape.iter().product::<usize>().max(1);
    if expect != data.len() {
        bail!("literal shape {shape:?} wants {expect} elems, got {}", data.len());
    }
    let flat = Literal::vec1(data);
    if shape.len() <= 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

impl Executor for PjrtExecutor {
    /// Execute the variant on one input image (flattened NHWC f32).
    ///
    /// The request-path hot loop: one literal upload, one PJRT execute,
    /// one device-to-host readback.  No Python anywhere.
    fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        if input.len() != self.input_len {
            bail!(
                "input of {} f32s, variant {} expects {}",
                input.len(),
                self.variant,
                self.input_len
            );
        }
        // The AOT signature is (image[1,H,W,3], *weight_leaves).
        self.execute_program(self.base_batch, input)
    }

    /// Batched PJRT execution (DESIGN.md §16).  With batched-HLO
    /// artifacts the micro-batch is planned over the compiled ladder —
    /// largest fit, padding up to the next rung when the padded program
    /// stays at least half full — each sub-batch packed into ONE
    /// leading-dim literal and dispatched as ONE device execution, the
    /// output split back into rows with padded rows discarded before
    /// anyone sees them.  Legacy batch-1-only bundles keep the per-input
    /// loop byte-identically.
    fn infer_batch(&mut self, inputs: &[std::sync::Arc<Vec<f32>>]) -> Result<BatchRun> {
        for input in inputs {
            if input.len() != self.input_len {
                bail!(
                    "batched input of {} f32s, variant {} expects {}",
                    input.len(),
                    self.variant,
                    self.input_len
                );
            }
        }
        if self.batch_sizes == [self.base_batch] {
            let outputs = inputs
                .iter()
                .map(|input| self.infer(input))
                .collect::<Result<Vec<_>>>()?;
            return Ok(BatchRun { outputs, programs: inputs.len(), pad_slots: 0 });
        }
        let plan = plan_batches(&self.batch_sizes, inputs.len())?;
        let row_len = self.input_len / self.base_batch;
        let out_row_len = self.output_len / self.base_batch;
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut offset = 0usize;
        let mut pad_slots = 0usize;
        for sub in &plan {
            let rows = &inputs[offset..offset + sub.rows];
            offset += sub.rows;
            pad_slots += sub.pad_slots();
            // Pack real rows into the program's leading dim; pad slots
            // stay zero-filled (their outputs are never read back out).
            let mut packed = vec![0.0f32; sub.program * row_len];
            for (i, row) in rows.iter().enumerate() {
                packed[i * row_len..(i + 1) * row_len].copy_from_slice(row);
            }
            let values = self.execute_program(sub.program, &packed)?;
            for i in 0..sub.rows {
                outputs.push(values[i * out_row_len..(i + 1) * out_row_len].to_vec());
            }
        }
        Ok(BatchRun { outputs, programs: plan.len(), pad_slots })
    }

    fn compiled_batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};

    fn golden(path: &str) -> Vec<f32> {
        let bytes = std::fs::read(artifacts_dir().join(path)).unwrap();
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn make_literal_validates_shape() {
        assert!(make_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(make_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    #[test]
    fn gpu_variant_matches_python_golden() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        let mut exec = PjrtExecutor::compile(&bundle, "tinyyolo-gpu").unwrap();
        let input = golden("golden_input.bin");
        let expect = golden("tinyyolo-gpu.golden.bin");
        let out = exec.infer(&input).unwrap();
        assert_eq!(out.len(), expect.len());
        let d = max_abs_diff(&out, &expect);
        assert!(d < 1e-3, "rust PJRT output diverges from jax golden by {d}");
    }

    #[test]
    fn vpu_variant_runs_and_approximates_gpu() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        let mut exec = PjrtExecutor::compile(&bundle, "tinyyolo-vpu").unwrap();
        let input = golden("golden_input.bin");
        let expect = golden("tinyyolo-vpu.golden.bin");
        let out = exec.infer(&input).unwrap();
        // bf16 rounding differs between xla_extension 0.5.1 and the jax
        // 0.8 CPU backend (fusion/accumulation order through 8 bf16
        // layers), so exact agreement with the jax bf16 golden is not
        // attainable.  Empirically the jax bf16 golden itself deviates
        // from the f32 golden by mean |Δ| ≈ 0.092 on outputs of mean
        // magnitude ≈ 1.0 — i.e. that is the inherent bf16 noise floor of
        // this network.  Require the rust output to sit inside the same
        // noise ball around *both* goldens.
        let bound = |a: &[f32], b: &[f32], max_tol: f32, mean_tol: f32, what: &str| {
            let worst = max_abs_diff(a, b);
            let mean: f32 =
                a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
            assert!(
                worst < max_tol && mean < mean_tol,
                "{what}: worst {worst}, mean {mean}"
            );
        };
        bound(&out, &expect, 0.75, 0.15, "vs bf16 golden");
        let f32_golden = golden("tinyyolo-gpu.golden.bin");
        bound(&out, &f32_golden, 0.75, 0.15, "vs f32 golden");
    }

    #[test]
    fn batched_artifact_matches_stacked_singles() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        if !bundle.artifact("tinyyolo-gpu").unwrap().batch_sizes.contains(&8) {
            eprintln!("skipping: bundle predates batched HLO (no batch-8 rung)");
            return;
        }
        let mut exec = PjrtExecutor::compile(&bundle, "tinyyolo-gpu").unwrap();
        let input = golden("golden_input.b8.bin");
        let expect = golden("tinyyolo-gpu.b8.golden.bin");
        let row = input.len() / 8;
        let out_row = expect.len() / 8;
        let inputs: Vec<std::sync::Arc<Vec<f32>>> = (0..8)
            .map(|i| std::sync::Arc::new(input[i * row..(i + 1) * row].to_vec()))
            .collect();
        let run = exec.infer_batch(&inputs).unwrap();
        assert_eq!(run.programs, 1, "batch 8 must be ONE device execution");
        assert_eq!(run.pad_slots, 0);
        assert_eq!(run.outputs.len(), 8);
        for i in 0..8 {
            // vs the jax batched golden ...
            let d = max_abs_diff(&run.outputs[i], &expect[i * out_row..(i + 1) * out_row]);
            assert!(d < 1e-3, "row {i} diverges from batched golden by {d}");
            // ... and vs a stacked batch-1 execution of the same row
            let single = exec.infer(&inputs[i]).unwrap();
            let d = max_abs_diff(&run.outputs[i], &single);
            assert!(d < 1e-3, "row {i}: batch-8 vs batch-1 diverge by {d}");
        }
    }

    #[test]
    fn padded_rows_never_surface() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        if !bundle.artifact("tinyyolo-gpu").unwrap().batch_sizes.contains(&8) {
            eprintln!("skipping: bundle predates batched HLO (no batch-8 rung)");
            return;
        }
        let mut exec = PjrtExecutor::compile(&bundle, "tinyyolo-gpu").unwrap();
        let input = golden("golden_input.b8.bin");
        let row = input.len() / 8;
        // 5 rows pad into the 8-program: one dispatch, 3 pad slots, and
        // exactly 5 outputs identical to unbatched runs of those rows.
        let inputs: Vec<std::sync::Arc<Vec<f32>>> = (0..5)
            .map(|i| std::sync::Arc::new(input[i * row..(i + 1) * row].to_vec()))
            .collect();
        let run = exec.infer_batch(&inputs).unwrap();
        assert_eq!(run.programs, 1);
        assert_eq!(run.pad_slots, 3);
        assert_eq!(run.outputs.len(), 5);
        for i in 0..5 {
            let single = exec.infer(&inputs[i]).unwrap();
            let d = max_abs_diff(&run.outputs[i], &single);
            assert!(d < 1e-3, "row {i}: padded batch vs single diverge by {d}");
        }
    }

    #[test]
    fn repeated_inference_is_deterministic() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        let mut exec = PjrtExecutor::compile(&bundle, "tinyyolo-gpu").unwrap();
        let input = golden("golden_input.bin");
        let a = exec.infer(&input).unwrap();
        let b = exec.infer(&input).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn wrong_input_size_rejected() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        let mut exec = PjrtExecutor::compile(&bundle, "tinyyolo-gpu").unwrap();
        assert!(exec.infer(&[0.0; 10]).is_err());
    }

    #[test]
    fn unknown_variant_fails_to_compile() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle = RuntimeBundle::load_dir("tinyyolo", artifacts_dir()).unwrap();
        assert!(PjrtExecutor::compile(&bundle, "tinyyolo-zzz").is_err());
    }
}
