//! Readiness reactor: one event-loop thread owns every socket.
//!
//! The reactor thread multiplexes the listener plus all accepted
//! connections through a [`Poller`] (epoll, or io_uring in poll mode).
//! Sockets are nonblocking; bytes accumulate in per-connection
//! [`FrameBuf`]s and responses drain through per-connection write queues,
//! so a connection costs two buffers and an epoll interest — never a
//! thread.  Handlers run on a bounded worker pool fed over a channel;
//! the reactor itself never blocks on one.  Deferred outcomes
//! ([`Outcome::Park`] — queue long-polls, gateway waits) live in a
//! retry registry that is re-driven whenever a completion lands (a
//! publish on the same server resolves a parked take within the same
//! loop iteration) and on a short fallback tick.

use super::frame::{append_frame, parse_frame, FrameBuf, MAX_FRAME};
use super::stats::RpcCounters;
use super::sys;
use super::{DeferHandler, Outcome, Park, RetryFn};
use crate::json::Json;
use crate::store::Blob;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Poll tick while parks are registered vs. fully idle.
const TICK_PARKED_MS: i32 = 5;
const TICK_IDLE_MS: i32 = 500;

// -- poller abstraction -----------------------------------------------------

#[derive(Debug, Clone, Copy)]
pub(crate) struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Readiness source the reactor runs on.  Implemented by epoll here and
/// by the io_uring poll-mode ring in `wire/uring.rs`; both present
/// identical level-style semantics to the loop above them.
pub(crate) trait Poller: Send {
    fn name(&self) -> &'static str;
    fn add(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()>;
    fn modify(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()>;
    fn remove(&mut self, fd: RawFd) -> Result<()>;
    /// Blocks up to `timeout_ms`; fills `events` with ready tokens.
    fn wait(&mut self, events: &mut Vec<PollEvent>, timeout_ms: i32) -> Result<()>;
}

pub(crate) struct EpollPoller {
    epfd: c_int,
    buf: Vec<sys::epoll_event>,
}

impl EpollPoller {
    pub(crate) fn new() -> Result<EpollPoller> {
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(sys::os_err("epoll_create1"));
        }
        Ok(EpollPoller {
            epfd,
            buf: vec![sys::epoll_event { events: 0, data: 0 }; 256],
        })
    }

    fn ctl(&mut self, op: c_int, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
        let mut ev = sys::epoll_event {
            events: interest_mask(readable, writable),
            data: token,
        };
        if unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) } < 0 {
            return Err(sys::os_err("epoll_ctl"));
        }
        Ok(())
    }
}

pub(crate) fn interest_mask(readable: bool, writable: bool) -> u32 {
    let mut m = sys::EPOLLRDHUP;
    if readable {
        m |= sys::EPOLLIN;
    }
    if writable {
        m |= sys::EPOLLOUT;
    }
    m
}

impl Poller for EpollPoller {
    fn name(&self) -> &'static str {
        "epoll"
    }

    fn add(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, readable, writable)
    }

    fn modify(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, readable, writable)
    }

    fn remove(&mut self, fd: RawFd) -> Result<()> {
        // The event argument is ignored for DEL but must be non-null on
        // old kernels.
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, false, false)
    }

    fn wait(&mut self, events: &mut Vec<PollEvent>, timeout_ms: i32) -> Result<()> {
        events.clear();
        let n = unsafe {
            sys::epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, timeout_ms)
        };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(anyhow::Error::new(e).context("epoll_wait"));
        }
        for i in 0..n as usize {
            // copy packed fields out by value; never reference them
            let raw = self.buf[i];
            let bits = raw.events;
            events.push(PollEvent {
                token: raw.data,
                readable: bits & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLERR | sys::EPOLLRDHUP)
                    != 0,
                writable: bits & (sys::EPOLLOUT | sys::EPOLLERR | sys::EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

impl Drop for EpollPoller {
    fn drop(&mut self) {
        unsafe { sys::close(self.epfd) };
    }
}

// -- wakeup + completion board ----------------------------------------------

/// Nonblocking eventfd the workers (and shutdown) use to interrupt a
/// sleeping `Poller::wait`.
pub(crate) struct Wake {
    fd: c_int,
}

impl Wake {
    pub(crate) fn new() -> Result<Wake> {
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(sys::os_err("eventfd"));
        }
        Ok(Wake { fd })
    }

    pub(crate) fn fd(&self) -> RawFd {
        self.fd
    }

    pub(crate) fn wake(&self) {
        let one: u64 = 1;
        unsafe { sys::write(self.fd, &one as *const u64 as *const c_void, 8) };
    }

    pub(crate) fn drain(&self) {
        let mut val: u64 = 0;
        unsafe { sys::read(self.fd, &mut val as *mut u64 as *mut c_void, 8) };
    }
}

impl Drop for Wake {
    fn drop(&mut self) {
        unsafe { sys::close(self.fd) };
    }
}

type RpcBody = std::result::Result<(Json, Option<Blob>), String>;

enum Completion {
    Respond {
        token: u64,
        req_id: Option<u64>,
        body: RpcBody,
    },
    Park {
        token: u64,
        req_id: Option<u64>,
        park: Park,
    },
}

/// Where workers drop finished handler outcomes for the reactor to pick
/// up; every push tickles the wake eventfd.
struct Board {
    completions: Mutex<Vec<Completion>>,
    wake: Wake,
}

impl Board {
    fn push(&self, c: Completion) {
        self.completions.lock().expect("completion board poisoned").push(c);
        self.wake.wake();
    }
}

// -- reactor ----------------------------------------------------------------

struct Job {
    token: u64,
    req_id: Option<u64>,
    method: String,
    params: Json,
    blob: Option<Vec<u8>>,
}

enum WBuf {
    Owned(Vec<u8>),
    /// Blob payload shared straight from the handler — zero-copy out.
    Shared(Blob),
}

struct WriteChunk {
    buf: WBuf,
    off: usize,
}

impl WriteChunk {
    fn rest(&self) -> &[u8] {
        match &self.buf {
            WBuf::Owned(v) => &v[self.off..],
            WBuf::Shared(b) => &b[self.off..],
        }
    }
}

struct Conn {
    stream: TcpStream,
    rbuf: FrameBuf,
    wq: VecDeque<WriteChunk>,
    /// Envelope awaiting its blob frame (requests with `"blob": true`).
    pending_env: Option<Json>,
    /// An id-less (strict sequential) request is in flight; stop parsing
    /// further frames until it is answered — legacy pipelining semantics.
    busy: bool,
    /// EPOLLOUT currently armed because the last flush hit `WouldBlock`.
    wants_write: bool,
}

struct Deferred {
    token: u64,
    req_id: Option<u64>,
    deadline: Instant,
    retry: RetryFn,
}

struct Reactor {
    listener: TcpListener,
    poller: Box<dyn Poller>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    jobs: mpsc::Sender<Job>,
    board: Arc<Board>,
    deferred: Vec<Deferred>,
    counters: Arc<RpcCounters>,
    stop: Arc<AtomicBool>,
    workers: usize,
}

pub(crate) struct ReactorServer {
    stop: Arc<AtomicBool>,
    board: Arc<Board>,
    reactor_thread: Option<std::thread::JoinHandle<()>>,
    worker_threads: Vec<std::thread::JoinHandle<()>>,
}

impl ReactorServer {
    pub(crate) fn serve(
        listener: TcpListener,
        handler: DeferHandler,
        counters: Arc<RpcCounters>,
        workers: usize,
        poller: Box<dyn Poller>,
    ) -> Result<ReactorServer> {
        let workers = workers.max(1);
        counters.set_backend(poller.name());
        counters.workers.store(workers as u64, Ordering::Relaxed);
        counters.threads.store(1 + workers as u64, Ordering::Relaxed);

        let stop = Arc::new(AtomicBool::new(false));
        let board = Arc::new(Board {
            completions: Mutex::new(Vec::new()),
            wake: Wake::new()?,
        });
        let (jobs_tx, jobs_rx) = mpsc::channel::<Job>();
        let jobs_rx = Arc::new(Mutex::new(jobs_rx));

        let mut worker_threads = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = jobs_rx.clone();
            let handler = handler.clone();
            let board = board.clone();
            let counters = counters.clone();
            worker_threads.push(
                std::thread::Builder::new()
                    .name(format!("rpc-worker-{w}"))
                    .spawn(move || worker_loop(&rx, &handler, &board, &counters))?,
            );
        }

        let mut reactor = Reactor {
            listener,
            poller,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            jobs: jobs_tx,
            board: board.clone(),
            deferred: Vec::new(),
            counters,
            stop: stop.clone(),
            workers,
        };
        reactor
            .poller
            .add(reactor.listener.as_raw_fd(), TOKEN_LISTENER, true, false)?;
        reactor
            .poller
            .add(reactor.board.wake.fd(), TOKEN_WAKE, true, false)?;
        let local = reactor.listener.local_addr()?;
        let reactor_thread = std::thread::Builder::new()
            .name(format!("rpc-reactor-{local}"))
            .spawn(move || reactor.run())?;

        Ok(ReactorServer {
            stop,
            board,
            reactor_thread: Some(reactor_thread),
            worker_threads,
        })
    }

    pub(crate) fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.board.wake.wake();
        // Joining the reactor drops the job sender, which in turn lets
        // every worker's recv() fail and its thread exit.
        if let Some(t) = self.reactor_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn worker_loop(
    rx: &Mutex<mpsc::Receiver<Job>>,
    handler: &DeferHandler,
    board: &Board,
    counters: &RpcCounters,
) {
    loop {
        // Hold the lock across recv: exactly one worker sleeps in recv
        // while the rest queue on the mutex — the standard shared-receiver
        // pattern without an MPMC channel.
        let job = match rx.lock() {
            Ok(g) => g.recv(),
            Err(_) => return,
        };
        let Ok(job) = job else { return };
        counters.worker_queue_depth.fetch_sub(1, Ordering::Relaxed);
        counters.worker_busy.fetch_add(1, Ordering::Relaxed);
        let Job { token, req_id, method, params, blob } = job;
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handler(&method, &params, blob)
        }));
        counters.worker_busy.fetch_sub(1, Ordering::Relaxed);
        let completion = match out {
            Ok(Ok(Outcome::Ready(result, out_blob))) => Completion::Respond {
                token,
                req_id,
                body: Ok((result, out_blob)),
            },
            Ok(Ok(Outcome::Park(park))) => Completion::Park { token, req_id, park },
            Ok(Err(e)) => Completion::Respond { token, req_id, body: Err(format!("{e:#}")) },
            Err(_) => Completion::Respond {
                token,
                req_id,
                body: Err(format!("rpc {method}: handler panicked")),
            },
        };
        board.push(completion);
    }
}

impl Reactor {
    fn run(&mut self) {
        let mut events: Vec<PollEvent> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            let timeout = if self.deferred.is_empty() { TICK_IDLE_MS } else { TICK_PARKED_MS };
            if self.poller.wait(&mut events, timeout).is_err() {
                break;
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKE => self.board.wake.drain(),
                    token => self.conn_ready(token, ev.readable, ev.writable),
                }
            }
            self.drain_completions();
            self.retry_deferred();
        }
        // Deterministic shutdown: close every live connection now rather
        // than letting peers discover a dead server by timeout.
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.close_conn(t);
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() || stream.set_nodelay(true).is_err() {
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.add(stream.as_raw_fd(), token, true, false).is_err() {
                        continue;
                    }
                    self.conns.insert(
                        token,
                        Conn {
                            stream,
                            rbuf: FrameBuf::new(),
                            wq: VecDeque::new(),
                            pending_env: None,
                            busy: false,
                            wants_write: false,
                        },
                    );
                    self.counters.conns_accepted.fetch_add(1, Ordering::Relaxed);
                    self.counters.conns_active.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn conn_ready(&mut self, token: u64, readable: bool, writable: bool) {
        if writable && !self.flush_writes(token) {
            self.close_conn(token);
            return;
        }
        if !readable {
            return;
        }
        let mut closed = false;
        {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            loop {
                match conn.rbuf.read_from(&mut conn.stream) {
                    Ok(0) => {
                        closed = true;
                        break;
                    }
                    Ok(n) => {
                        self.counters.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        closed = true;
                        break;
                    }
                }
            }
        }
        if closed || !self.parse_conn(token) {
            self.close_conn(token);
        }
    }

    /// Lift complete frames out of a connection's receive buffer into
    /// worker jobs.  Returns false when the stream can never realign
    /// (oversized frame, bad JSON, malformed id) and must be dropped.
    fn parse_conn(&mut self, token: u64) -> bool {
        let mut jobs: Vec<Job> = Vec::new();
        let keep = 'parse: {
            let Some(conn) = self.conns.get_mut(&token) else { break 'parse true };
            loop {
                if conn.busy {
                    break 'parse true;
                }
                let frame = match conn.rbuf.try_frame() {
                    Ok(Some(f)) => f,
                    Ok(None) => break 'parse true,
                    Err(_) => break 'parse false,
                };
                self.counters.frames_in.fetch_add(1, Ordering::Relaxed);
                if let Some(env) = conn.pending_env.take() {
                    // this frame is the blob payload for the parked envelope
                    let blob = frame.to_vec();
                    if !stage(&mut jobs, conn, token, env, Some(blob)) {
                        break 'parse false;
                    }
                    continue;
                }
                let Ok(env) = parse_frame(frame) else { break 'parse false };
                if env.get("blob").and_then(|b| b.as_bool()).unwrap_or(false) {
                    conn.pending_env = Some(env);
                    continue;
                }
                if !stage(&mut jobs, conn, token, env, None) {
                    break 'parse false;
                }
            }
        };
        for job in jobs {
            self.dispatch(job);
        }
        keep
    }

    fn dispatch(&mut self, job: Job) {
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.counters.in_flight.fetch_add(1, Ordering::Relaxed);
        let depth = self.counters.worker_queue_depth.fetch_add(1, Ordering::Relaxed);
        if depth >= self.workers as u64 {
            self.counters.saturated.fetch_add(1, Ordering::Relaxed);
        }
        // send fails only when workers are gone, i.e. during shutdown
        let _ = self.jobs.send(job);
    }

    fn drain_completions(&mut self) {
        let pending: Vec<Completion> = {
            let mut g = self.board.completions.lock().expect("completion board poisoned");
            std::mem::take(&mut *g)
        };
        for c in pending {
            match c {
                Completion::Respond { token, req_id, body } => self.respond(token, req_id, body),
                Completion::Park { token, req_id, park } => {
                    if self.conns.contains_key(&token) {
                        self.counters.parked.fetch_add(1, Ordering::Relaxed);
                        let Park { deadline, retry } = park;
                        self.deferred.push(Deferred { token, req_id, deadline, retry });
                    } else {
                        // connection vanished while the handler ran; the
                        // request still leaves the in-flight gauge
                        self.counters.in_flight.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }

    fn retry_deferred(&mut self) {
        if self.deferred.is_empty() {
            return;
        }
        let now = Instant::now();
        let stopping = self.stop.load(Ordering::SeqCst);
        let mut deferred = std::mem::take(&mut self.deferred);
        let mut i = 0;
        while i < deferred.len() {
            if !self.conns.contains_key(&deferred[i].token) {
                deferred.swap_remove(i);
                self.counters.parked.fetch_sub(1, Ordering::Relaxed);
                self.counters.in_flight.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            let outcome: Option<RpcBody> = match (deferred[i].retry)() {
                Ok(Some(x)) => Some(Ok(x)),
                Err(e) => Some(Err(format!("{e:#}"))),
                Ok(None) if now >= deferred[i].deadline || stopping => {
                    Some(Ok((Json::Null, None)))
                }
                Ok(None) => None,
            };
            match outcome {
                Some(body) => {
                    let d = deferred.swap_remove(i);
                    self.counters.parked.fetch_sub(1, Ordering::Relaxed);
                    self.respond(d.token, d.req_id, body);
                }
                None => i += 1,
            }
        }
        deferred.append(&mut self.deferred);
        self.deferred = deferred;
    }

    fn respond(&mut self, token: u64, req_id: Option<u64>, body: RpcBody) {
        self.counters.in_flight.fetch_sub(1, Ordering::Relaxed);
        let seq = req_id.is_none();
        let staged = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            let body = match body {
                Ok((_, Some(b))) if b.len() as u64 > MAX_FRAME as u64 => {
                    Err("response blob exceeds MAX_FRAME".to_string())
                }
                x => x,
            };
            let (resp, out_blob) = match body {
                Ok((result, b)) => (
                    Json::obj().set("ok", true).set("result", result).set("blob", b.is_some()),
                    b,
                ),
                Err(msg) => (Json::obj().set("ok", false).set("error", msg), None),
            };
            let resp = match req_id {
                Some(id) => resp.set("id", id),
                None => resp,
            };
            let text = resp.to_string();
            let mut head = Vec::with_capacity(text.len() + 8);
            if append_frame(&mut head, text.as_bytes()).is_err() {
                // envelope itself oversized — replace with a small error
                let err = Json::obj().set("ok", false).set("error", "response exceeds MAX_FRAME");
                let err = match req_id {
                    Some(id) => err.set("id", id),
                    None => err,
                };
                head.clear();
                append_frame(&mut head, err.to_string().as_bytes())
                    .expect("error envelope fits any frame limit");
                conn.wq.push_back(WriteChunk { buf: WBuf::Owned(head), off: 0 });
                self.counters.frames_out.fetch_add(1, Ordering::Relaxed);
            } else {
                self.counters.frames_out.fetch_add(1, Ordering::Relaxed);
                if let Some(b) = out_blob {
                    // blob frame: its length prefix rides the owned chunk,
                    // the payload is shared zero-copy
                    head.extend_from_slice(&(b.len() as u32).to_le_bytes());
                    conn.wq.push_back(WriteChunk { buf: WBuf::Owned(head), off: 0 });
                    conn.wq.push_back(WriteChunk { buf: WBuf::Shared(b), off: 0 });
                    self.counters.frames_out.fetch_add(1, Ordering::Relaxed);
                } else {
                    conn.wq.push_back(WriteChunk { buf: WBuf::Owned(head), off: 0 });
                }
            }
            if seq {
                conn.busy = false;
            }
            true
        };
        if !staged {
            return;
        }
        if !self.flush_writes(token) {
            self.close_conn(token);
            return;
        }
        // A sequential connection may have the next request already
        // buffered; parse it now that the slot is free.
        if seq && !self.parse_conn(token) {
            self.close_conn(token);
        }
    }

    /// Drain a connection's write queue as far as the socket allows.
    /// Arms EPOLLOUT on WouldBlock, disarms once the queue empties.
    /// Returns false when the connection is dead.
    fn flush_writes(&mut self, token: u64) -> bool {
        let fd: RawFd = match self.conns.get(&token) {
            Some(c) => c.stream.as_raw_fd(),
            None => return true,
        };
        let mut rearm: Option<bool> = None;
        let alive = {
            let Some(conn) = self.conns.get_mut(&token) else { return true };
            'flush: {
                loop {
                    let Some(chunk) = conn.wq.front_mut() else { break };
                    let rest = chunk.rest();
                    if rest.is_empty() {
                        conn.wq.pop_front();
                        continue;
                    }
                    match conn.stream.write(rest) {
                        Ok(0) => break 'flush false,
                        Ok(n) => {
                            chunk.off += n;
                            self.counters.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
                            if chunk.rest().is_empty() {
                                conn.wq.pop_front();
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            if !conn.wants_write {
                                conn.wants_write = true;
                                rearm = Some(true);
                            }
                            break;
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(_) => break 'flush false,
                    }
                }
                if conn.wq.is_empty() && conn.wants_write {
                    conn.wants_write = false;
                    rearm = Some(false);
                }
                true
            }
        };
        if alive {
            if let Some(w) = rearm {
                if self.poller.modify(fd, token, true, w).is_err() {
                    return false;
                }
            }
        }
        alive
    }

    fn close_conn(&mut self, token: u64) {
        let Some(conn) = self.conns.remove(&token) else { return };
        let _ = self.poller.remove(conn.stream.as_raw_fd());
        let _ = conn.stream.shutdown(Shutdown::Both);
        self.counters.conns_active.fetch_sub(1, Ordering::Relaxed);
        let mut i = 0;
        while i < self.deferred.len() {
            if self.deferred[i].token == token {
                self.deferred.swap_remove(i);
                self.counters.parked.fetch_sub(1, Ordering::Relaxed);
                self.counters.in_flight.fetch_sub(1, Ordering::Relaxed);
            } else {
                i += 1;
            }
        }
    }
}

/// Turn a parsed envelope (+ optional blob payload) into a staged job.
/// Returns false on a malformed id — the stream is suspect, drop it.
fn stage(jobs: &mut Vec<Job>, conn: &mut Conn, token: u64, env: Json, blob: Option<Vec<u8>>) -> bool {
    let req_id = match env.get("id") {
        None => None,
        Some(v) => match v.as_u64() {
            Some(id) => Some(id),
            None => return false,
        },
    };
    if req_id.is_none() {
        conn.busy = true;
    }
    jobs.push(Job {
        token,
        req_id,
        method: env.str_of("method").unwrap_or("").to_string(),
        params: env.get("params").cloned().unwrap_or(Json::Null),
        blob,
    });
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoll_poller_reports_eventfd_readability() {
        let mut p = EpollPoller::new().unwrap();
        let wake = Wake::new().unwrap();
        p.add(wake.fd(), 7, true, false).unwrap();
        let mut events = Vec::new();
        p.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "nothing written yet");
        wake.wake();
        p.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        wake.drain();
        p.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "drained eventfd is quiet again");
    }
}
