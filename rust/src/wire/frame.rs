//! Frame codec: `u32 little-endian length || payload`.
//!
//! The payload of a JSON frame is UTF-8 JSON text; blob frames carry raw
//! bytes (datasets, results) with no base64 overhead.  Everything above
//! this layer — blocking RPC clients, the reactor's nonblocking
//! connections — shares these helpers so a frame is a frame on every
//! transport.

use crate::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{IoSlice, Read, Write};

/// Upper bound on a single frame (64 MiB) — guards against corrupt length
/// prefixes taking the process down.
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// How much a [`FrameBuf`] asks the kernel for per nonblocking read.
const READ_CHUNK: usize = 64 * 1024;

/// Compact the receive buffer once this many consumed bytes accumulate
/// at its front.
const COMPACT_AT: usize = 64 * 1024;

/// Write one JSON frame (allocates a fresh serialization buffer; the RPC
/// hot paths use [`write_frame_buf`] with a reused one).
pub fn write_frame(stream: &mut impl Write, v: &Json) -> Result<()> {
    let mut scratch = String::new();
    write_frame_buf(stream, v, &mut scratch)
}

/// Write one JSON frame, serializing into `scratch` (cleared, then
/// reused) — no per-message `String` allocation on persistent
/// connections.
pub fn write_frame_buf(stream: &mut impl Write, v: &Json, scratch: &mut String) -> Result<()> {
    use std::fmt::Write as _;
    scratch.clear();
    write!(scratch, "{v}").expect("fmt to String cannot fail");
    write_blob(stream, scratch.as_bytes())
}

/// Write one raw frame (used for dataset/result payloads).  The length
/// prefix and payload go out in a single vectored write — one syscall
/// per frame instead of two, and no payload copy.
pub fn write_blob(stream: &mut impl Write, data: &[u8]) -> Result<()> {
    let len = u32::try_from(data.len()).context("frame too large")?;
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds MAX_FRAME");
    }
    let header = len.to_le_bytes();
    let total = header.len() + data.len();
    let mut written = 0usize;
    while written < total {
        let res = if written < header.len() {
            stream.write_vectored(&[IoSlice::new(&header[written..]), IoSlice::new(data)])
        } else {
            stream.write(&data[written - header.len()..])
        };
        match res {
            Ok(0) => bail!("connection closed mid-frame ({written}/{total} bytes written)"),
            Ok(n) => written += n,
            // transparent retry, as write_all did before this loop
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    stream.flush()?;
    Ok(())
}

/// Serialize one frame (length prefix + payload) onto the end of `out`.
/// The reactor uses this to stage responses in a per-connection write
/// queue instead of writing to the socket directly.
pub fn append_frame(out: &mut Vec<u8>, data: &[u8]) -> Result<()> {
    let len = u32::try_from(data.len()).context("frame too large")?;
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds MAX_FRAME");
    }
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(data);
    Ok(())
}

/// Read one JSON frame.
pub fn read_frame(stream: &mut impl Read) -> Result<Json> {
    let data = read_blob(stream)?;
    parse_frame(&data)
}

/// Read one JSON frame into a reused receive buffer — the allocation-free
/// twin of [`read_frame`] for persistent connections (client hot paths,
/// the threaded server loop).
pub fn read_frame_buf(stream: &mut impl Read, buf: &mut Vec<u8>) -> Result<Json> {
    read_blob_buf(stream, buf)?;
    parse_frame(buf)
}

/// Parse one frame payload as JSON.
pub fn parse_frame(data: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(data).context("frame is not utf-8")?;
    Json::parse(text).map_err(|e| anyhow!("bad frame json: {e}"))
}

/// Read one raw frame.
pub fn read_blob(stream: &mut impl Read) -> Result<Vec<u8>> {
    let mut data = Vec::new();
    read_blob_buf(stream, &mut data)?;
    Ok(data)
}

/// Read one raw frame into a reused buffer: capacity is retained across
/// frames, so a persistent connection pays zero allocations once its
/// buffer has grown to the workload's frame size.
pub fn read_blob_buf(stream: &mut impl Read, buf: &mut Vec<u8>) -> Result<()> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("incoming frame of {len} bytes exceeds MAX_FRAME");
    }
    buf.clear();
    buf.resize(len as usize, 0);
    stream.read_exact(buf)?;
    Ok(())
}

/// Incremental frame accumulator for nonblocking sockets.
///
/// Bytes arrive in whatever chunks the kernel delivers; [`FrameBuf`]
/// buffers them and yields complete frames without per-frame allocation
/// (one growable buffer per connection, compacted as frames are
/// consumed).  A length prefix exceeding [`MAX_FRAME`] is a protocol
/// error — the caller should drop the connection, since the stream can
/// never realign.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    start: usize,
}

impl FrameBuf {
    pub fn new() -> FrameBuf {
        FrameBuf::default()
    }

    /// Append raw bytes (tests and in-memory replays; sockets use
    /// [`FrameBuf::read_from`]).
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pull one readiness-sized chunk from `stream` into the buffer.
    /// Returns `Ok(0)` on EOF, mirrors `Read::read` otherwise
    /// (`WouldBlock` when the socket is drained).
    pub fn read_from(&mut self, stream: &mut impl Read) -> std::io::Result<usize> {
        self.compact();
        let old = self.buf.len();
        self.buf.resize(old + READ_CHUNK, 0);
        match stream.read(&mut self.buf[old..]) {
            Ok(n) => {
                self.buf.truncate(old + n);
                Ok(n)
            }
            Err(e) => {
                self.buf.truncate(old);
                Err(e)
            }
        }
    }

    fn compact(&mut self) {
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > COMPACT_AT {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// Extract the next complete frame, if one is fully buffered.  The
    /// returned slice borrows the buffer — parse or copy it before the
    /// next call.
    pub fn try_frame(&mut self) -> Result<Option<&[u8]>> {
        let avail = self.buf.len() - self.start;
        if avail < 4 {
            return Ok(None);
        }
        let b = &self.buf[self.start..];
        let len = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        if len > MAX_FRAME {
            bail!("incoming frame of {len} bytes exceeds MAX_FRAME");
        }
        let total = 4 + len as usize;
        if avail < total {
            return Ok(None);
        }
        let body = self.start + 4;
        self.start += total;
        Ok(Some(&self.buf[body..body + len as usize]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::util::Rng;

    fn random_frames(rng: &mut Rng) -> Vec<Vec<u8>> {
        let n = 1 + rng.below(8) as usize;
        (0..n)
            .map(|_| {
                let len = rng.below(2000) as usize;
                let mut f = vec![0u8; len];
                rng.fill_bytes(&mut f);
                f
            })
            .collect()
    }

    fn serialize(frames: &[Vec<u8>]) -> Vec<u8> {
        let mut out = Vec::new();
        for f in frames {
            append_frame(&mut out, f).unwrap();
        }
        out
    }

    #[test]
    fn frame_buf_reassembles_frames_across_arbitrary_chunking() {
        // A frame is a frame no matter how the kernel slices the byte
        // stream: re-chunk at random boundaries, recover every frame
        // intact and in order, never mis-align.
        prop::check(
            "framebuf-chunking",
            60,
            |rng: &mut Rng| {
                let frames = random_frames(rng);
                let stream = serialize(&frames);
                let mut cuts: Vec<usize> =
                    (0..6).map(|_| rng.below(stream.len() as u64 + 1) as usize).collect();
                cuts.push(0);
                cuts.push(stream.len());
                cuts.sort_unstable();
                (frames, stream, cuts)
            },
            |(frames, stream, cuts)| {
                let mut fb = FrameBuf::new();
                let mut got: Vec<Vec<u8>> = Vec::new();
                for w in cuts.windows(2) {
                    fb.extend(&stream[w[0]..w[1]]);
                    while let Some(f) = fb.try_frame().unwrap() {
                        got.push(f.to_vec());
                    }
                }
                got == *frames
            },
        );
    }

    #[test]
    fn truncated_streams_never_yield_a_frame_early() {
        // Every strict prefix of a single-frame stream yields nothing
        // (FrameBuf) and errors cleanly (read_blob) — no partial frames,
        // no panic, no hang.
        let mut frame = vec![0xABu8; 300];
        frame[0] = 1;
        let mut stream = Vec::new();
        append_frame(&mut stream, &frame).unwrap();
        for cut in 0..stream.len() {
            let mut fb = FrameBuf::new();
            fb.extend(&stream[..cut]);
            assert!(fb.try_frame().unwrap().is_none(), "cut at {cut}");
            let mut cursor = std::io::Cursor::new(&stream[..cut]);
            assert!(read_blob(&mut cursor).is_err(), "cut at {cut}");
        }
        let mut cursor = std::io::Cursor::new(&stream[..]);
        assert_eq!(read_blob(&mut cursor).unwrap(), frame);
    }

    #[test]
    fn oversized_length_prefix_is_a_clean_error_everywhere() {
        let mut bytes = (MAX_FRAME + 1).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 16]);
        let mut cursor = std::io::Cursor::new(bytes.clone());
        assert!(read_blob(&mut cursor).is_err());
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        assert!(fb.try_frame().is_err());
    }

    #[test]
    fn random_noise_never_panics_the_codec() {
        // Arbitrary bytes through every decode path: any outcome is fine
        // except a panic or a mis-sized frame.
        prop::check(
            "codec-noise",
            150,
            |rng: &mut Rng| {
                let len = rng.below(64) as usize;
                let mut noise = vec![0u8; len];
                rng.fill_bytes(&mut noise);
                noise
            },
            |noise| {
                let mut cursor = std::io::Cursor::new(noise.clone());
                let _ = read_frame(&mut cursor);
                let mut cursor = std::io::Cursor::new(noise.clone());
                if let Ok(b) = read_blob(&mut cursor) {
                    assert!(b.len() + 4 <= noise.len());
                }
                let mut fb = FrameBuf::new();
                fb.extend(noise);
                while let Ok(Some(f)) = fb.try_frame() {
                    assert!(f.len() + 4 <= noise.len());
                }
                true
            },
        );
    }

    #[test]
    fn read_frame_buf_reuses_the_receive_buffer() {
        let big = Json::obj().set("pad", "x".repeat(1000));
        let mut wire = Vec::new();
        write_frame(&mut wire, &big).unwrap();
        write_frame(&mut wire, &Json::obj().set("k", "v")).unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let mut buf = Vec::new();
        read_frame_buf(&mut cursor, &mut buf).unwrap();
        let grown = buf.capacity();
        assert!(grown >= 1000);
        let out = read_frame_buf(&mut cursor, &mut buf).unwrap();
        assert_eq!(out.str_of("k").unwrap(), "v");
        assert_eq!(buf.capacity(), grown, "small frame reuses the grown buffer");
    }
}
