//! io_uring transport backend, in poll mode.
//!
//! Implements the reactor's [`Poller`] trait on a raw io_uring: each
//! interest is a one-shot `IORING_OP_POLL_ADD` re-armed at the top of
//! every wait, timed waits ride an `IORING_OP_TIMEOUT` sqe (a plain
//! blocking enter would sleep forever on an idle server), and the sq/cq
//! rings are driven through hand-rolled mmap + atomics — no liburing in
//! the dependency closure.  `probe()` decides at runtime whether this
//! backend exists at all: setup or a self-test failing for ANY reason
//! (ENOSYS on old kernels, seccomp, missing features) falls back to
//! epoll, which is exactly the graceful degradation the `auto` backend
//! promises.
//!
//! Poll event bits share epoll's numeric values on Linux, so the
//! `EPOLL*` constants double as `POLL*` masks here.

use super::reactor::{interest_mask, PollEvent, Poller, Wake};
use super::sys;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::os::raw::{c_int, c_long, c_void};
use std::os::unix::io::RawFd;
use std::sync::atomic::{AtomicU32, Ordering};

/// cqe user_data sentinels; real polls carry their fd (small, no clash).
const TIMEOUT_TOKEN: u64 = u64::MAX;
const REMOVE_TOKEN: u64 = u64::MAX - 1;

const ENTRIES: u32 = 256;

struct Interest {
    token: u64,
    readable: bool,
    writable: bool,
    /// A POLL_ADD for this fd is currently registered with the kernel.
    armed: bool,
}

pub(crate) struct UringPoller {
    ring_fd: c_int,
    ring: *mut u8,
    ring_len: usize,
    sqes: *mut sys::io_uring_sqe,
    sqes_len: usize,
    sq_head: *const AtomicU32,
    sq_tail: *const AtomicU32,
    sq_mask: u32,
    sq_entries: u32,
    sq_array: *mut u32,
    cq_head: *const AtomicU32,
    cq_tail: *const AtomicU32,
    cq_mask: u32,
    cqes: *const sys::io_uring_cqe,
    to_submit: u32,
    ts: sys::kernel_timespec,
    interests: HashMap<RawFd, Interest>,
}

// The rings are only ever touched by the single thread that owns the
// poller (the reactor); the raw pointers make the type !Send by default.
unsafe impl Send for UringPoller {}

impl UringPoller {
    /// Runtime probe: build a ring and pass a poll self-test, or report
    /// that this kernel can't (caller falls back to epoll).
    /// `force_fail` exercises the fallback path deterministically in CI.
    pub(crate) fn probe(force_fail: bool) -> Option<UringPoller> {
        if force_fail {
            return None;
        }
        let mut p = UringPoller::new().ok()?;
        p.self_test().ok()?;
        Some(p)
    }

    fn new() -> Result<UringPoller> {
        let mut params = sys::io_uring_params::default();
        let ring_fd = unsafe {
            sys::syscall(
                sys::SYS_IO_URING_SETUP,
                ENTRIES as c_long,
                &mut params as *mut sys::io_uring_params as c_long,
            )
        } as c_int;
        if ring_fd < 0 {
            return Err(sys::os_err("io_uring_setup"));
        }
        if params.features & sys::IORING_FEAT_SINGLE_MMAP == 0 {
            unsafe { sys::close(ring_fd) };
            bail!("io_uring lacks IORING_FEAT_SINGLE_MMAP (pre-5.4 kernel)");
        }
        let sq_len = params.sq_off.array as usize + params.sq_entries as usize * 4;
        let cq_len = params.cq_off.cqes as usize
            + params.cq_entries as usize * std::mem::size_of::<sys::io_uring_cqe>();
        let ring_len = sq_len.max(cq_len);
        let ring = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                ring_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                ring_fd,
                sys::IORING_OFF_SQ_RING,
            )
        };
        if ring == sys::MAP_FAILED {
            let e = sys::os_err("mmap sq/cq ring");
            unsafe { sys::close(ring_fd) };
            return Err(e);
        }
        let sqes_len = params.sq_entries as usize * std::mem::size_of::<sys::io_uring_sqe>();
        let sqes = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                sqes_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                ring_fd,
                sys::IORING_OFF_SQES,
            )
        };
        if sqes == sys::MAP_FAILED {
            let e = sys::os_err("mmap sqes");
            unsafe {
                sys::munmap(ring, ring_len);
                sys::close(ring_fd);
            }
            return Err(e);
        }
        let ring = ring as *mut u8;
        let at = |off: u32| unsafe { ring.add(off as usize) };
        let sq_mask = unsafe { *(at(params.sq_off.ring_mask) as *const u32) };
        let cq_mask = unsafe { *(at(params.cq_off.ring_mask) as *const u32) };
        Ok(UringPoller {
            ring_fd,
            ring,
            ring_len,
            sqes: sqes as *mut sys::io_uring_sqe,
            sqes_len,
            sq_head: at(params.sq_off.head) as *const AtomicU32,
            sq_tail: at(params.sq_off.tail) as *const AtomicU32,
            sq_mask,
            sq_entries: params.sq_entries,
            sq_array: at(params.sq_off.array) as *mut u32,
            cq_head: at(params.cq_off.head) as *const AtomicU32,
            cq_tail: at(params.cq_off.tail) as *const AtomicU32,
            cq_mask,
            cqes: at(params.cq_off.cqes) as *const sys::io_uring_cqe,
            to_submit: 0,
            ts: sys::kernel_timespec::default(),
            interests: HashMap::new(),
        })
    }

    /// End-to-end check that polls actually complete on this kernel: arm
    /// an eventfd, fire it, expect the readiness cqe back.
    fn self_test(&mut self) -> Result<()> {
        let wake = Wake::new()?;
        self.add(wake.fd(), 42, true, false)?;
        wake.wake();
        let mut events = Vec::new();
        self.wait(&mut events, 1000)?;
        ensure!(
            events.iter().any(|e| e.token == 42 && e.readable),
            "io_uring self-test: poll completion never arrived"
        );
        self.remove(wake.fd())?;
        let mut scratch = Vec::new();
        let _ = self.wait(&mut scratch, 0); // reap the cancellation cqe
        Ok(())
    }

    fn enter(&self, to_submit: u32, min_complete: u32, flags: u32) -> c_long {
        unsafe {
            sys::syscall(
                sys::SYS_IO_URING_ENTER,
                self.ring_fd as c_long,
                to_submit as c_long,
                min_complete as c_long,
                flags as c_long,
                0 as c_long,
                0 as c_long,
            )
        }
    }

    /// Hand pending sqes to the kernel without waiting for completions.
    fn flush(&mut self) -> Result<()> {
        while self.to_submit > 0 {
            let r = self.enter(self.to_submit, 0, 0);
            if r < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(anyhow::Error::new(e).context("io_uring_enter (submit)"));
            }
            self.to_submit -= (r as u32).min(self.to_submit);
        }
        Ok(())
    }

    fn push_sqe(&mut self, sqe: sys::io_uring_sqe) -> Result<()> {
        loop {
            let head = unsafe { (*self.sq_head).load(Ordering::Acquire) };
            let tail = unsafe { (*self.sq_tail).load(Ordering::Relaxed) };
            if tail.wrapping_sub(head) < self.sq_entries {
                let idx = tail & self.sq_mask;
                unsafe {
                    *self.sqes.add(idx as usize) = sqe;
                    *self.sq_array.add(idx as usize) = idx;
                    (*self.sq_tail).store(tail.wrapping_add(1), Ordering::Release);
                }
                self.to_submit += 1;
                return Ok(());
            }
            self.flush()?;
        }
    }

    fn push_poll(&mut self, fd: RawFd, readable: bool, writable: bool) -> Result<()> {
        let sqe = sys::io_uring_sqe {
            opcode: sys::IORING_OP_POLL_ADD,
            fd,
            op_flags: interest_mask(readable, writable),
            user_data: fd as u64,
            ..Default::default()
        };
        self.push_sqe(sqe)
    }

    fn push_cancel(&mut self, fd: RawFd) -> Result<()> {
        let sqe = sys::io_uring_sqe {
            opcode: sys::IORING_OP_POLL_REMOVE,
            fd: -1,
            addr: fd as u64,
            user_data: REMOVE_TOKEN,
            ..Default::default()
        };
        self.push_sqe(sqe)
    }

    fn drain_cqes(&mut self, events: &mut Vec<PollEvent>) {
        let tail = unsafe { (*self.cq_tail).load(Ordering::Acquire) };
        let mut head = unsafe { (*self.cq_head).load(Ordering::Relaxed) };
        while head != tail {
            let cqe = unsafe { *self.cqes.add((head & self.cq_mask) as usize) };
            head = head.wrapping_add(1);
            self.handle_cqe(cqe, events);
        }
        unsafe { (*self.cq_head).store(head, Ordering::Release) };
    }

    fn handle_cqe(&mut self, cqe: sys::io_uring_cqe, events: &mut Vec<PollEvent>) {
        if cqe.user_data == TIMEOUT_TOKEN || cqe.user_data == REMOVE_TOKEN {
            return;
        }
        let fd = cqe.user_data as RawFd;
        let Some(interest) = self.interests.get_mut(&fd) else { return };
        // one-shot poll consumed (completed or cancelled) either way
        interest.armed = false;
        if cqe.res < 0 {
            return;
        }
        let bits = cqe.res as u32;
        let readable =
            bits & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLERR | sys::EPOLLRDHUP) != 0;
        let writable = bits & (sys::EPOLLOUT | sys::EPOLLERR | sys::EPOLLHUP) != 0;
        if readable || writable {
            events.push(PollEvent { token: interest.token, readable, writable });
        }
    }
}

impl Poller for UringPoller {
    fn name(&self) -> &'static str {
        "uring"
    }

    fn add(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
        self.interests.insert(fd, Interest { token, readable, writable, armed: false });
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
        let armed = match self.interests.get_mut(&fd) {
            Some(i) => {
                let was = i.armed;
                i.token = token;
                i.readable = readable;
                i.writable = writable;
                i.armed = false;
                was
            }
            None => {
                self.interests.insert(fd, Interest { token, readable, writable, armed: false });
                false
            }
        };
        if armed {
            // cancel the stale-mask poll; the new mask re-arms next wait
            self.push_cancel(fd)?;
        }
        Ok(())
    }

    fn remove(&mut self, fd: RawFd) -> Result<()> {
        if let Some(i) = self.interests.remove(&fd) {
            if i.armed {
                self.push_cancel(fd)?;
            }
        }
        Ok(())
    }

    fn wait(&mut self, events: &mut Vec<PollEvent>, timeout_ms: i32) -> Result<()> {
        events.clear();
        // re-arm every interest whose one-shot poll was consumed
        let unarmed: Vec<(RawFd, bool, bool)> = self
            .interests
            .iter()
            .filter(|(_, i)| !i.armed)
            .map(|(fd, i)| (*fd, i.readable, i.writable))
            .collect();
        for (fd, r, w) in unarmed {
            self.push_poll(fd, r, w)?;
            if let Some(i) = self.interests.get_mut(&fd) {
                i.armed = true;
            }
        }
        self.drain_cqes(events);
        if !events.is_empty() {
            self.flush()?;
            return Ok(());
        }
        // Nothing ready: sleep in the kernel under a count-1 timeout so
        // either the first completion or the deadline wakes us.
        let ms = timeout_ms.max(0) as i64;
        self.ts = sys::kernel_timespec {
            tv_sec: ms / 1000,
            tv_nsec: (ms % 1000) * 1_000_000,
        };
        let sqe = sys::io_uring_sqe {
            opcode: sys::IORING_OP_TIMEOUT,
            fd: -1,
            addr: &self.ts as *const sys::kernel_timespec as u64,
            len: 1,
            off: 1, // count: complete after 1 cqe or when the timer fires
            user_data: TIMEOUT_TOKEN,
            ..Default::default()
        };
        self.push_sqe(sqe)?;
        let r = self.enter(self.to_submit, 1, sys::IORING_ENTER_GETEVENTS);
        if r < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(anyhow::Error::new(e).context("io_uring_enter (wait)"));
        }
        self.to_submit -= (r as u32).min(self.to_submit);
        self.drain_cqes(events);
        Ok(())
    }
}

impl Drop for UringPoller {
    fn drop(&mut self) {
        unsafe {
            sys::munmap(self.ring as *mut c_void, self.ring_len);
            sys::munmap(self.sqes as *mut c_void, self.sqes_len);
            sys::close(self.ring_fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uring_probe_passes_its_self_test_or_skips_cleanly() {
        // On uring-capable kernels this exercises setup + poll + cancel
        // end to end; elsewhere the probe declining IS the correct
        // behavior (the auto backend falls back to epoll).
        match UringPoller::probe(false) {
            Some(p) => assert_eq!(p.name(), "uring"),
            None => eprintln!("io_uring unavailable here; probe declined (fallback path)"),
        }
    }

    #[test]
    fn forced_probe_failure_declines_without_touching_the_kernel() {
        assert!(UringPoller::probe(true).is_none());
    }
}
