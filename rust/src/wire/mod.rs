//! Wire substrate: length-prefixed JSON frames over TCP plus a
//! reactor-based request/response RPC layer.
//!
//! Used by the distributed deployments of the invocation queue
//! ([`crate::queue::remote`]), the object store ([`crate::store::remote`])
//! and the gateway — the roles Bedrock and Minio play in the paper's
//! prototype.  Frame layout: `u32 little-endian length || payload`,
//! payload is UTF-8 JSON; binary blobs ride base64-free in a second raw
//! frame.
//!
//! Serving model: one reactor thread owns every socket through a
//! readiness [`reactor::Poller`] (epoll, or io_uring behind a runtime
//! probe), handlers run on a bounded worker pool, and long-polls park as
//! reactor registrations instead of blocked threads.  Request envelopes
//! may carry an `id` field for connection multiplexing; id-less frames
//! run in strict sequential mode so pre-reactor peers interop unchanged.
//! Non-Linux hosts fall back to the legacy thread-per-connection
//! transport — every backend passes the identical test suite below.

mod client;
mod frame;
mod stats;
mod threaded;

#[cfg(target_os = "linux")]
mod reactor;
#[cfg(target_os = "linux")]
mod sys;
#[cfg(target_os = "linux")]
mod uring;

pub use client::{ClientConfig, RpcClient};
pub use frame::{
    append_frame, parse_frame, read_blob, read_blob_buf, read_frame, read_frame_buf, write_blob,
    write_frame, write_frame_buf, FrameBuf, MAX_FRAME,
};
pub use stats::{RpcCounters, RpcStats};

use crate::json::Json;
use crate::store::Blob;
use anyhow::{Context, Result};
use std::net::TcpListener;
use std::str::FromStr;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default client read timeout.  Generous — server-side blocking calls
/// cap their chunks at [`LONG_POLL_CHUNK`] — but finite, so a server that
/// dies mid-call surfaces a clean error instead of hanging the caller
/// forever.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Cap on one server-side blocking chunk (gateway `wait`, queue
/// long-poll).  Must stay well below [`DEFAULT_READ_TIMEOUT`] so a
/// deliberately parked RPC never looks like a dead server; clients loop
/// via [`poll_chunked`] until their own deadline.
pub const LONG_POLL_CHUNK: Duration = Duration::from_secs(10);

/// Client side of a chunked server-blocking call: issue `call(chunk_ms)`
/// until it yields a value or `timeout` elapses.  Each chunk is capped at
/// [`LONG_POLL_CHUNK`], enforcing the read-timeout invariant in one place
/// for every long-polling client (queue take, gateway wait).
pub fn poll_chunked<T>(
    timeout: Duration,
    mut call: impl FnMut(u64) -> Result<Option<T>>,
) -> Result<Option<T>> {
    let deadline = Instant::now() + timeout;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        let chunk = remaining.min(LONG_POLL_CHUNK);
        // Sub-ms budgets round UP to one server-side millisecond: the
        // wire carries whole ms, and truncating to 0 would turn a short
        // park (the micro-batch linger window) into a non-blocking
        // probe.
        let chunk_ms = if chunk.is_zero() {
            0
        } else {
            (chunk.as_millis() as u64).max(1)
        };
        if let Some(v) = call(chunk_ms)? {
            return Ok(Some(v));
        }
        if remaining <= chunk {
            return Ok(None);
        }
    }
}

/// Handler invoked per request: `(method, params, blob)` → `(result, blob)`.
/// `blob` carries raw payload bytes when the request/response has any
/// (methods set `"blob": true` in their envelope).  The response payload
/// is a shared [`Blob`] so a handler can return a cached/stored buffer
/// straight to the socket writer without copying it.
pub type Handler =
    Arc<dyn Fn(&str, &Json, Option<Vec<u8>>) -> Result<(Json, Option<Blob>)> + Send + Sync>;

/// Handler that may defer: return [`Outcome::Park`] to release the worker
/// and have the server retry the closure until it yields, errors, or the
/// deadline passes (then the caller gets `null`).  This is how queue
/// long-polls and gateway waits cost a registration instead of a thread.
pub type DeferHandler = Arc<dyn Fn(&str, &Json, Option<Vec<u8>>) -> Result<Outcome> + Send + Sync>;

/// What a deferrable handler produced.
pub enum Outcome {
    /// Respond now.
    Ready(Json, Option<Blob>),
    /// Park the request; the transport re-polls `retry` until it
    /// resolves or the deadline passes (response: `null`).
    Park(Park),
}

pub(crate) type RetryFn = Box<dyn FnMut() -> Result<Option<(Json, Option<Blob>)>> + Send>;

/// A parked request: a deadline plus a poll closure.
pub struct Park {
    pub(crate) deadline: Instant,
    pub(crate) retry: RetryFn,
}

impl Park {
    pub fn new(
        deadline: Instant,
        retry: impl FnMut() -> Result<Option<(Json, Option<Blob>)>> + Send + 'static,
    ) -> Park {
        Park { deadline, retry: Box::new(retry) }
    }
}

/// Transport backend selection for [`RpcServer`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// epoll reactor on Linux, thread-per-connection elsewhere.
    /// io_uring stays opt-in (`Backend::Uring`) — its probe still guards
    /// the fallback, but the default path sticks to the universally
    /// deployed readiness API.
    #[default]
    Auto,
    Epoll,
    /// io_uring if the runtime probe passes, epoll otherwise.
    Uring,
    /// Legacy thread-per-connection transport.
    Threaded,
}

impl FromStr for Backend {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Backend> {
        match s {
            "auto" => Ok(Backend::Auto),
            "epoll" => Ok(Backend::Epoll),
            "uring" => Ok(Backend::Uring),
            "threaded" => Ok(Backend::Threaded),
            other => anyhow::bail!("unknown rpc backend {other:?} (auto|epoll|uring|threaded)"),
        }
    }
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct RpcConfig {
    pub backend: Backend,
    /// Bounded handler pool size (reactor backends).
    pub workers: usize,
    /// Share a counter block with the server (so e.g. the gateway's own
    /// `stats` handler can report the transport it runs inside).
    pub counters: Option<Arc<RpcCounters>>,
    /// Test hook: make the io_uring probe decline, exercising the
    /// uring→epoll fallback deterministically even on capable kernels.
    pub force_uring_fallback: bool,
}

impl Default for RpcConfig {
    fn default() -> RpcConfig {
        RpcConfig {
            backend: Backend::Auto,
            workers: 4,
            counters: None,
            force_uring_fallback: false,
        }
    }
}

enum ServerImpl {
    #[cfg(target_os = "linux")]
    Reactor(reactor::ReactorServer),
    Threaded(threaded::ThreadedServer),
}

/// A TCP RPC server on the configured transport backend.
pub struct RpcServer {
    addr: std::net::SocketAddr,
    counters: Arc<RpcCounters>,
    imp: ServerImpl,
}

impl RpcServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn serve(addr: &str, handler: Handler) -> Result<RpcServer> {
        RpcServer::serve_with(addr, handler, RpcConfig::default())
    }

    pub fn serve_with(addr: &str, handler: Handler, cfg: RpcConfig) -> Result<RpcServer> {
        let deferrable: DeferHandler = Arc::new(move |method, params, blob| {
            handler(method, params, blob).map(|(j, b)| Outcome::Ready(j, b))
        });
        RpcServer::serve_deferrable(addr, deferrable, cfg)
    }

    /// Serve a handler that may park requests ([`Outcome::Park`]).
    pub fn serve_deferrable(addr: &str, handler: DeferHandler, cfg: RpcConfig) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let counters = cfg.counters.clone().unwrap_or_default();
        let imp = build_backend(listener, handler, counters.clone(), &cfg)?;
        Ok(RpcServer { addr: local, counters, imp })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Snapshot of this server's RPC counters.
    pub fn stats(&self) -> RpcStats {
        self.counters.snapshot()
    }

    pub fn shutdown(&mut self) {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            ServerImpl::Reactor(s) => s.shutdown(),
            ServerImpl::Threaded(s) => s.shutdown(),
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(target_os = "linux")]
fn build_backend(
    listener: TcpListener,
    handler: DeferHandler,
    counters: Arc<RpcCounters>,
    cfg: &RpcConfig,
) -> Result<ServerImpl> {
    let poller: Option<Box<dyn reactor::Poller>> = match cfg.backend {
        Backend::Threaded => None,
        Backend::Auto | Backend::Epoll => Some(Box::new(reactor::EpollPoller::new()?)),
        Backend::Uring => match uring::UringPoller::probe(cfg.force_uring_fallback) {
            Some(p) => Some(Box::new(p)),
            // graceful degradation: old kernel, seccomp, failed self-test
            None => Some(Box::new(reactor::EpollPoller::new()?)),
        },
    };
    match poller {
        Some(p) => Ok(ServerImpl::Reactor(reactor::ReactorServer::serve(
            listener,
            handler,
            counters,
            cfg.workers,
            p,
        )?)),
        None => {
            counters.set_backend("threaded");
            Ok(ServerImpl::Threaded(threaded::ThreadedServer::serve(listener, handler, counters)?))
        }
    }
}

#[cfg(not(target_os = "linux"))]
fn build_backend(
    listener: TcpListener,
    handler: DeferHandler,
    counters: Arc<RpcCounters>,
    cfg: &RpcConfig,
) -> Result<ServerImpl> {
    match cfg.backend {
        Backend::Epoll | Backend::Uring => {
            anyhow::bail!("rpc backend {:?} requires linux; use auto or threaded", cfg.backend)
        }
        Backend::Auto | Backend::Threaded => {
            counters.set_backend("threaded");
            Ok(ServerImpl::Threaded(threaded::ThreadedServer::serve(listener, handler, counters)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn echo_handler() -> Handler {
        Arc::new(|method, params, blob| match method {
            "echo" => Ok((params.clone(), blob.map(Blob::from))),
            "add" => {
                let a = params.f64_of("a")?;
                let b = params.f64_of("b")?;
                Ok((Json::obj().set("sum", a + b), None))
            }
            "boom" => Err(anyhow!("intentional failure")),
            other => Err(anyhow!("unknown method {other}")),
        })
    }

    fn echo_server() -> RpcServer {
        RpcServer::serve("127.0.0.1:0", echo_handler()).unwrap()
    }

    #[test]
    fn roundtrip_json_call() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client
            .call("add", Json::obj().set("a", 2.0).set("b", 40.0))
            .unwrap();
        assert_eq!(out.f64_of("sum").unwrap(), 42.0);
    }

    #[test]
    fn blob_roundtrip() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        let payload = vec![7u8; 100_000];
        let (out, blob) = client
            .call_blob("echo", Json::obj().set("k", "v"), Some(&payload))
            .unwrap();
        assert_eq!(out.str_of("k").unwrap(), "v");
        assert_eq!(blob.unwrap(), payload);
    }

    #[test]
    fn error_propagates() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        let err = client.call("boom", Json::Null).unwrap_err();
        assert!(format!("{err}").contains("intentional failure"));
    }

    #[test]
    fn unknown_method_is_error_not_hang() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        assert!(client.call("nope", Json::Null).is_err());
    }

    #[test]
    fn sequential_calls_on_one_connection() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        for i in 0..50 {
            let out = client
                .call("add", Json::obj().set("a", i as f64).set("b", 1.0))
                .unwrap();
            assert_eq!(out.f64_of("sum").unwrap(), i as f64 + 1.0);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr();
        let mut handles = Vec::new();
        for t in 0..8 {
            handles.push(std::thread::spawn(move || {
                let client = RpcClient::connect(addr).unwrap();
                for i in 0..20 {
                    let out = client
                        .call("add", Json::obj().set("a", t as f64).set("b", i as f64))
                        .unwrap();
                    assert_eq!(out.f64_of("sum").unwrap(), (t + i) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn write_blob_survives_partial_writes() {
        // A writer that accepts at most 3 bytes per call exercises every
        // resume point of the vectored header+payload write.
        struct Dribble(Vec<u8>);
        impl Write for Dribble {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                let n = buf.len().min(3);
                self.0.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let payload: Vec<u8> = (0..=255u8).collect();
        let mut w = Dribble(Vec::new());
        write_blob(&mut w, &payload).unwrap();
        let mut cursor = std::io::Cursor::new(w.0);
        assert_eq!(read_blob(&mut cursor).unwrap(), payload);
    }

    #[test]
    fn frame_size_guard() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_blob(&mut cursor).is_err());
    }

    #[test]
    fn stalled_server_times_out_cleanly() {
        // A server that accepts but never replies: the client must return
        // a clean error within its read timeout instead of blocking
        // forever (a dead gateway must not wedge every node).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (keep_tx, keep_rx) = std::sync::mpsc::channel::<()>();
        let hold = std::thread::spawn(move || {
            let conn = listener.accept().unwrap().0;
            // hold the connection open, silently, until the test is done
            let _ = keep_rx.recv_timeout(Duration::from_secs(30));
            drop(conn);
        });
        let client =
            RpcClient::connect_with_timeout(addr, Duration::from_millis(200)).unwrap();
        let t0 = std::time::Instant::now();
        let err = client.call("ping", Json::Null).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "did not hang");
        assert!(
            format!("{err:#}").contains("no response within"),
            "{err:#}"
        );
        // the connection is poisoned: later calls fail fast, no new hang
        let t1 = std::time::Instant::now();
        let err2 = client.call("ping", Json::Null).unwrap_err();
        assert!(t1.elapsed() < Duration::from_millis(50));
        assert!(format!("{err2}").contains("broken"), "{err2}");
        drop(keep_tx);
        hold.join().unwrap();
    }

    #[test]
    fn server_death_mid_call_errors_instead_of_hanging() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let killer = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            drop(conn); // server "crashes" before answering
        });
        let client = RpcClient::connect(addr).unwrap();
        let err = client.call("ping", Json::Null).unwrap_err();
        assert!(format!("{err:#}").contains("rpc ping"), "{err:#}");
        killer.join().unwrap();
    }

    #[test]
    fn server_reported_errors_do_not_poison_the_connection() {
        let server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        assert!(client.call("boom", Json::Null).is_err());
        // framing stayed aligned: the next call succeeds
        let out = client
            .call("add", Json::obj().set("a", 1.0).set("b", 2.0))
            .unwrap();
        assert_eq!(out.f64_of("sum").unwrap(), 3.0);
    }

    #[test]
    fn server_shutdown_is_clean() {
        let mut server = echo_server();
        let addr = server.addr();
        server.shutdown();
        std::thread::sleep(Duration::from_millis(50));
        // New connections should fail or be ignored after shutdown.
        let r = RpcClient::connect(addr)
            .and_then(|c| c.call("add", Json::obj().set("a", 1.0).set("b", 2.0)));
        assert!(r.is_err() || r.is_ok()); // must not hang — reaching here is the test
    }

    // -- reactor-era tests --------------------------------------------------

    #[test]
    fn shutdown_closes_live_connections_deterministically() {
        let mut server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        client.call("add", Json::obj().set("a", 1.0).set("b", 1.0)).unwrap();
        server.shutdown();
        // the live connection was closed by shutdown, not left to rot
        // until a read timeout: the next call errors promptly
        let t0 = Instant::now();
        assert!(client.call("add", Json::obj().set("a", 1.0).set("b", 1.0)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5), "shutdown did not close the conn");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn mux_socket_sustains_64_in_flight() {
        // One multiplexed socket, 64 concurrent calls, every response
        // demuxed to its caller.  The handler parks until all 64 have
        // arrived, so this cannot pass by accident of sequencing — and
        // with only 2 workers it also proves parks don't hold the pool.
        let arrived = Arc::new(AtomicUsize::new(0));
        let gate = arrived.clone();
        let handler: DeferHandler = Arc::new(move |method, params, _| {
            anyhow::ensure!(method == "gather", "unexpected method {method}");
            let n = params.u64_of("n")?;
            gate.fetch_add(1, Ordering::SeqCst);
            let gate = gate.clone();
            Ok(Outcome::Park(Park::new(
                Instant::now() + Duration::from_secs(20),
                move || {
                    if gate.load(Ordering::SeqCst) >= 64 {
                        Ok(Some((Json::obj().set("n", n), None)))
                    } else {
                        Ok(None)
                    }
                },
            )))
        });
        let cfg = RpcConfig { backend: Backend::Epoll, workers: 2, ..RpcConfig::default() };
        let server = RpcServer::serve_deferrable("127.0.0.1:0", handler, cfg).unwrap();
        let client = Arc::new(RpcClient::connect_mux(server.addr()).unwrap());
        let mut handles = Vec::new();
        for i in 0..64u64 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                let out = c.call("gather", Json::obj().set("n", i)).unwrap();
                assert_eq!(out.u64_of("n").unwrap(), i, "response demuxed to the wrong caller");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(client.calls_issued(), 64);
        assert_eq!(server.stats().conns_accepted, 1, "all calls shared one socket");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn parked_long_polls_do_not_cost_threads() {
        // N idle long-pollers must cost epoll interests, not OS threads.
        // 128 fits default CI fd limits; HARDLESS_RPC_SCALE_TEST=1 runs
        // the full 512 of the acceptance criterion.
        let n: usize = if std::env::var("HARDLESS_RPC_SCALE_TEST").is_ok() { 512 } else { 128 };
        let handler: DeferHandler = Arc::new(move |method, _params, _| match method {
            "park" => Ok(Outcome::Park(Park::new(
                Instant::now() + Duration::from_secs(60),
                || Ok(None),
            ))),
            "ping" => Ok(Outcome::Ready(Json::obj().set("pong", true), None)),
            other => Err(anyhow!("unknown method {other}")),
        });
        let cfg = RpcConfig { backend: Backend::Epoll, workers: 2, ..RpcConfig::default() };
        let server = RpcServer::serve_deferrable("127.0.0.1:0", handler, cfg).unwrap();
        let mut socks = Vec::with_capacity(n);
        for i in 0..n {
            let mut s = TcpStream::connect(server.addr()).unwrap();
            let req = Json::obj()
                .set("method", "park")
                .set("params", Json::obj())
                .set("blob", false)
                .set("id", i as u64);
            write_frame(&mut s, &req).unwrap();
            socks.push(s);
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        while (server.stats().parked as usize) < n {
            assert!(
                Instant::now() < deadline,
                "only {} of {n} long-polls parked",
                server.stats().parked
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = server.stats();
        assert_eq!(stats.conns_active as usize, n);
        assert!(
            stats.threads <= 2 + stats.workers,
            "{} threads for {n} parked connections (workers={})",
            stats.threads,
            stats.workers
        );
        // and the server still answers fresh work promptly
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client.call("ping", Json::Null).unwrap();
        assert!(out.bool_of("pong").unwrap());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn forced_uring_fallback_serves_on_epoll() {
        let cfg = RpcConfig {
            backend: Backend::Uring,
            force_uring_fallback: true,
            ..RpcConfig::default()
        };
        let server = RpcServer::serve_with("127.0.0.1:0", echo_handler(), cfg).unwrap();
        assert_eq!(server.stats().backend, "epoll", "probe decline must fall back");
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client.call("add", Json::obj().set("a", 20.0).set("b", 22.0)).unwrap();
        assert_eq!(out.f64_of("sum").unwrap(), 42.0);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn uring_backend_serves_when_available() {
        let cfg = RpcConfig { backend: Backend::Uring, ..RpcConfig::default() };
        let server = RpcServer::serve_with("127.0.0.1:0", echo_handler(), cfg).unwrap();
        let backend = server.stats().backend;
        if backend == "epoll" {
            eprintln!("io_uring unavailable on this kernel; fallback path exercised instead");
        }
        // whatever the probe chose must serve the full protocol
        let client = RpcClient::connect(server.addr()).unwrap();
        for i in 0..10 {
            let out = client
                .call("add", Json::obj().set("a", i as f64).set("b", 1.0))
                .unwrap();
            assert_eq!(out.f64_of("sum").unwrap(), i as f64 + 1.0);
        }
        let payload = vec![9u8; 50_000];
        let (_, blob) = client
            .call_blob("echo", Json::obj().set("k", "v"), Some(&payload))
            .unwrap();
        assert_eq!(blob.unwrap(), payload);
    }

    #[test]
    fn threaded_backend_passes_the_same_roundtrips() {
        let cfg = RpcConfig { backend: Backend::Threaded, ..RpcConfig::default() };
        let server = RpcServer::serve_with("127.0.0.1:0", echo_handler(), cfg).unwrap();
        assert_eq!(server.stats().backend, "threaded");
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client.call("add", Json::obj().set("a", 40.0).set("b", 2.0)).unwrap();
        assert_eq!(out.f64_of("sum").unwrap(), 42.0);
        assert!(client.call("boom", Json::Null).is_err());
        let payload = vec![3u8; 10_000];
        let (_, blob) = client.call_blob("echo", Json::Null, Some(&payload)).unwrap();
        assert_eq!(blob.unwrap(), payload);
    }

    #[test]
    fn parked_requests_expire_to_null_on_every_backend() {
        let handler: DeferHandler = Arc::new(|_m, _p, _b| {
            Ok(Outcome::Park(Park::new(
                Instant::now() + Duration::from_millis(100),
                || Ok(None),
            )))
        });
        for backend in [Backend::Auto, Backend::Threaded] {
            let cfg = RpcConfig { backend, ..RpcConfig::default() };
            let server = RpcServer::serve_deferrable("127.0.0.1:0", handler.clone(), cfg).unwrap();
            let client = RpcClient::connect(server.addr()).unwrap();
            let t0 = Instant::now();
            let out = client.call("wait", Json::Null).unwrap();
            assert!(matches!(out, Json::Null), "expired park answers null");
            assert!(t0.elapsed() >= Duration::from_millis(90), "park actually waited");
            assert!(t0.elapsed() < Duration::from_secs(5));
        }
    }

    #[test]
    fn legacy_idless_frames_interop_with_the_reactor() {
        // A pre-reactor peer: hand-rolled envelopes with no id field,
        // strictly sequential — including two pipelined requests, which
        // must come back in order with no id on the responses.
        let server = echo_server();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        for i in 0..5 {
            let req = Json::obj()
                .set("method", "add")
                .set("params", Json::obj().set("a", i as f64).set("b", 1.0))
                .set("blob", false);
            write_frame(&mut s, &req).unwrap();
            let resp = read_frame(&mut s).unwrap();
            assert!(resp.get("ok").unwrap().as_bool().unwrap());
            assert!(resp.get("id").is_none(), "legacy responses must not grow an id");
            assert_eq!(
                resp.get("result").unwrap().f64_of("sum").unwrap(),
                i as f64 + 1.0
            );
        }
        // two pipelined id-less requests answer strictly in order
        for a in [10.0f64, 20.0] {
            let req = Json::obj()
                .set("method", "add")
                .set("params", Json::obj().set("a", a).set("b", 1.0))
                .set("blob", false);
            write_frame(&mut s, &req).unwrap();
        }
        for a in [10.0f64, 20.0] {
            let resp = read_frame(&mut s).unwrap();
            assert_eq!(resp.get("result").unwrap().f64_of("sum").unwrap(), a + 1.0);
        }
    }

    #[test]
    fn reconnect_reaches_a_restarted_server() {
        let mut server = echo_server();
        let addr = server.addr();
        let cfg = ClientConfig {
            reconnect: true,
            read_timeout: Duration::from_secs(2),
            ..ClientConfig::default()
        };
        let client = RpcClient::connect_with(addr, cfg).unwrap();
        client.call("add", Json::obj().set("a", 1.0).set("b", 1.0)).unwrap();
        server.shutdown();
        // the dead server breaks the channel (and idempotent retry can't
        // save it — nothing is listening)
        assert!(client
            .call_idem("add", Json::obj().set("a", 1.0).set("b", 1.0))
            .is_err());
        // restart on the same port; do NOT rebuild the client
        let addr_str = addr.to_string();
        let deadline = Instant::now() + Duration::from_secs(10);
        let _server2 = loop {
            match RpcServer::serve(&addr_str, echo_handler()) {
                Ok(s) => break s,
                Err(e) => {
                    assert!(Instant::now() < deadline, "could not rebind {addr_str}: {e:#}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        let out = client
            .call_idem("add", Json::obj().set("a", 20.0).set("b", 22.0))
            .unwrap();
        assert_eq!(out.f64_of("sum").unwrap(), 42.0, "client re-reached the restarted server");
    }

    #[test]
    fn non_reconnect_clients_still_fail_fast_forever() {
        let mut server = echo_server();
        let client = RpcClient::connect(server.addr()).unwrap();
        client.call("add", Json::obj().set("a", 1.0).set("b", 1.0)).unwrap();
        server.shutdown();
        assert!(client.call("add", Json::obj().set("a", 1.0).set("b", 1.0)).is_err());
        let err = client
            .call("add", Json::obj().set("a", 1.0).set("b", 1.0))
            .unwrap_err();
        assert!(format!("{err}").contains("broken"), "{err}");
    }

    #[test]
    fn garbage_from_server_fails_mux_calls_cleanly() {
        // A byzantine peer answers a mux call with garbage: the demux
        // reader must fail every in-flight call promptly — no panic, no
        // hang, no mis-routed response.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            conn.write_all(&[0xFF; 32]).unwrap(); // length prefix > MAX_FRAME
            std::thread::sleep(Duration::from_millis(200));
        });
        let cfg = ClientConfig {
            mux: true,
            read_timeout: Duration::from_secs(5),
            ..ClientConfig::default()
        };
        let client = RpcClient::connect_with(addr, cfg).unwrap();
        let t0 = Instant::now();
        assert!(client.call("ping", Json::Null).is_err());
        assert!(t0.elapsed() < Duration::from_secs(4), "garbage failed fast, not by timeout");
        t.join().unwrap();
    }

    #[test]
    fn mux_demux_ignores_unknown_response_ids() {
        // A response for an id nobody is waiting on (e.g. a waiter that
        // already timed out) is dropped; the real response still lands.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let req = read_frame(&mut conn).unwrap();
            let id = req.get("id").and_then(|v| v.as_u64()).unwrap();
            let stray = Json::obj()
                .set("ok", true)
                .set("result", Json::obj().set("stray", true))
                .set("blob", false)
                .set("id", 999_999u64);
            write_frame(&mut conn, &stray).unwrap();
            let real = Json::obj()
                .set("ok", true)
                .set("result", Json::obj().set("stray", false))
                .set("blob", false)
                .set("id", id);
            write_frame(&mut conn, &real).unwrap();
        });
        let client = RpcClient::connect_mux(addr).unwrap();
        let out = client.call("ping", Json::Null).unwrap();
        assert!(!out.bool_of("stray").unwrap(), "got the stray response");
        t.join().unwrap();
    }
}
