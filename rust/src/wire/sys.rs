//! Raw Linux syscall bindings for the reactor.
//!
//! The dependency closure has no `libc` crate, so the handful of calls
//! the event loop needs — epoll, eventfd, io_uring setup/enter, mmap —
//! are declared by hand.  Everything here is Linux-only and gated at the
//! module level (`wire/mod.rs`); other platforms fall back to the
//! threaded transport.  Errno is read through
//! `std::io::Error::last_os_error()`, which shares the same thread-local
//! the C library sets.
#![allow(dead_code)]
#![allow(non_camel_case_types)]

use std::os::raw::{c_int, c_long, c_uint, c_void};

// -- epoll ------------------------------------------------------------------

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

pub const EPOLL_CTL_ADD: c_int = 1;
pub const EPOLL_CTL_DEL: c_int = 2;
pub const EPOLL_CTL_MOD: c_int = 3;
pub const EPOLL_CLOEXEC: c_int = 0o2000000;

/// The kernel packs this struct on x86-64 only (a 12-byte layout); other
/// architectures use natural alignment.  Mirrors the libc definition —
/// always copy fields out by value, never take references into it.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct epoll_event {
    pub events: u32,
    pub data: u64,
}

// -- eventfd ----------------------------------------------------------------

pub const EFD_CLOEXEC: c_int = 0o2000000;
pub const EFD_NONBLOCK: c_int = 0o4000;

// -- io_uring ---------------------------------------------------------------
//
// Syscall numbers are from the unified (asm-generic) table, identical on
// x86-64 and aarch64 — the only kernels CI and deployments run on.

pub const SYS_IO_URING_SETUP: c_long = 425;
pub const SYS_IO_URING_ENTER: c_long = 426;

pub const IORING_OP_POLL_ADD: u8 = 6;
pub const IORING_OP_POLL_REMOVE: u8 = 7;
pub const IORING_OP_TIMEOUT: u8 = 11;

pub const IORING_ENTER_GETEVENTS: c_uint = 1;
pub const IORING_FEAT_SINGLE_MMAP: u32 = 1;

pub const IORING_OFF_SQ_RING: i64 = 0;
pub const IORING_OFF_SQES: i64 = 0x1000_0000;

pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;
pub const MAP_SHARED: c_int = 0x01;
pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

#[repr(C)]
#[derive(Clone, Copy, Default)]
pub struct io_sqring_offsets {
    pub head: u32,
    pub tail: u32,
    pub ring_mask: u32,
    pub ring_entries: u32,
    pub flags: u32,
    pub dropped: u32,
    pub array: u32,
    pub resv1: u32,
    pub resv2: u64,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
pub struct io_cqring_offsets {
    pub head: u32,
    pub tail: u32,
    pub ring_mask: u32,
    pub ring_entries: u32,
    pub overflow: u32,
    pub cqes: u32,
    pub flags: u32,
    pub resv1: u32,
    pub resv2: u64,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
pub struct io_uring_params {
    pub sq_entries: u32,
    pub cq_entries: u32,
    pub flags: u32,
    pub sq_thread_cpu: u32,
    pub sq_thread_idle: u32,
    pub features: u32,
    pub wq_fd: u32,
    pub resv: [u32; 3],
    pub sq_off: io_sqring_offsets,
    pub cq_off: io_cqring_offsets,
}

/// One submission-queue entry (64 bytes).  The trailing union soup of
/// the kernel header collapses to the fields the poll/timeout opcodes
/// use plus padding.
#[repr(C)]
#[derive(Clone, Copy, Default)]
pub struct io_uring_sqe {
    pub opcode: u8,
    pub flags: u8,
    pub ioprio: u16,
    pub fd: i32,
    pub off: u64,
    pub addr: u64,
    pub len: u32,
    pub op_flags: u32,
    pub user_data: u64,
    pub pad: [u64; 3],
}

/// One completion-queue entry (16 bytes).
#[repr(C)]
#[derive(Clone, Copy, Default)]
pub struct io_uring_cqe {
    pub user_data: u64,
    pub res: i32,
    pub flags: u32,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
pub struct kernel_timespec {
    pub tv_sec: i64,
    pub tv_nsec: i64,
}

// -- bindings ---------------------------------------------------------------

extern "C" {
    pub fn epoll_create1(flags: c_int) -> c_int;
    pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut epoll_event) -> c_int;
    pub fn epoll_wait(
        epfd: c_int,
        events: *mut epoll_event,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    pub fn close(fd: c_int) -> c_int;
    pub fn syscall(num: c_long, ...) -> c_long;
    pub fn mmap(
        addr: *mut c_void,
        len: usize,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: i64,
    ) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
}

/// The current thread's errno as an `io::Error`.
pub fn os_err(what: &str) -> anyhow::Error {
    anyhow::Error::new(std::io::Error::last_os_error()).context(what.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binding_struct_layouts_match_the_abi() {
        assert_eq!(std::mem::size_of::<io_uring_sqe>(), 64);
        assert_eq!(std::mem::size_of::<io_uring_cqe>(), 16);
        assert_eq!(std::mem::size_of::<io_uring_params>(), 120);
        assert_eq!(std::mem::size_of::<kernel_timespec>(), 16);
        #[cfg(target_arch = "x86_64")]
        assert_eq!(std::mem::size_of::<epoll_event>(), 12);
    }

    #[test]
    fn eventfd_write_read_roundtrip() {
        unsafe {
            let fd = eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK);
            assert!(fd >= 0, "eventfd: {}", std::io::Error::last_os_error());
            let one: u64 = 1;
            let n = write(fd, &one as *const u64 as *const c_void, 8);
            assert_eq!(n, 8);
            let mut val: u64 = 0;
            let n = read(fd, &mut val as *mut u64 as *mut c_void, 8);
            assert_eq!(n, 8);
            assert_eq!(val, 1);
            close(fd);
        }
    }
}
