//! RPC client: persistent connections in two flavors.
//!
//! *Sequential* (the default) is byte-identical to the pre-reactor wire:
//! one in-flight call at a time, no `id` field, so it interops with old
//! peers.  *Multiplexed* tags every request envelope with a `u64` id and
//! runs a demux reader thread, letting one socket carry many concurrent
//! in-flight calls.  Either flavor can opt into transparent reconnect:
//! a broken channel is redialed on the next call, and *idempotent* calls
//! (`call_idem`) additionally retry once after a mid-call transport
//! failure — non-idempotent ones (publish, ack) never retry, since the
//! server may have applied them before the connection died.

use super::frame::{read_blob, read_frame_buf, write_blob, write_frame_buf};
use super::DEFAULT_READ_TIMEOUT;
use crate::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::Duration;

/// Connection behavior knobs; [`ClientConfig::default`] reproduces the
/// legacy client exactly (sequential, fail-fast on a broken channel).
#[derive(Debug, Clone)]
pub struct ClientConfig {
    pub read_timeout: Duration,
    /// Redial a broken channel on the next call instead of failing fast
    /// forever; `call_idem` additionally retries once after reconnect.
    pub reconnect: bool,
    /// Multiplex calls over one socket with id-tagged envelopes.
    pub mux: bool,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig { read_timeout: DEFAULT_READ_TIMEOUT, reconnect: false, mux: false }
    }
}

/// Client side: a persistent connection issuing RPCs.
pub struct RpcClient {
    /// Resolved at connect so reconnect can redial without re-resolving.
    peers: Vec<SocketAddr>,
    desc: String,
    cfg: ClientConfig,
    chan: RwLock<Arc<Channel>>,
    /// Wire round trips attempted (batching assertions, diagnostics).
    calls: AtomicU64,
}

impl RpcClient {
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<RpcClient> {
        RpcClient::connect_with(addr, ClientConfig::default())
    }

    /// Connect with an explicit per-read timeout (tests, impatient CLIs).
    pub fn connect_with_timeout(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        read_timeout: Duration,
    ) -> Result<RpcClient> {
        RpcClient::connect_with(addr, ClientConfig { read_timeout, ..ClientConfig::default() })
    }

    /// Connect a multiplexed client (many in-flight calls, one socket).
    pub fn connect_mux(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<RpcClient> {
        RpcClient::connect_with(addr, ClientConfig { mux: true, ..ClientConfig::default() })
    }

    pub fn connect_with(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        cfg: ClientConfig,
    ) -> Result<RpcClient> {
        let desc = format!("{addr:?}");
        let peers: Vec<SocketAddr> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {desc}"))?
            .collect();
        if peers.is_empty() {
            bail!("no addresses for {desc}");
        }
        let chan = Arc::new(dial(&peers, &desc, &cfg)?);
        Ok(RpcClient {
            peers,
            desc,
            cfg,
            chan: RwLock::new(chan),
            calls: AtomicU64::new(0),
        })
    }

    /// How many RPC round trips this client has issued on the wire
    /// (fast-failed calls on a broken connection are not counted).
    pub fn calls_issued(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Issue `method(params)`; returns the result value.
    pub fn call(&self, method: &str, params: Json) -> Result<Json> {
        Ok(self.call_inner(method, &params, None, false)?.0)
    }

    /// Issue a call that may carry / return a raw payload.
    pub fn call_blob(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>)> {
        self.call_inner(method, &params, blob, false)
    }

    /// Issue an *idempotent* call: with `reconnect` enabled, a transport
    /// failure redials and retries exactly once.  Only safe for methods
    /// whose duplicate delivery is harmless (stats, status, take polls).
    pub fn call_idem(&self, method: &str, params: Json) -> Result<Json> {
        Ok(self.call_inner(method, &params, None, true)?.0)
    }

    fn call_inner(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
        idem: bool,
    ) -> Result<(Json, Option<Vec<u8>>)> {
        let mut retried = false;
        loop {
            let chan = self.chan.read().expect("rpc channel lock poisoned").clone();
            if chan.is_broken() {
                if !self.cfg.reconnect {
                    bail!(
                        "rpc {method}: connection is broken after an earlier mid-call failure; reconnect"
                    );
                }
                self.redial(&chan)?;
                continue;
            }
            match chan.exchange(method, params, blob, self.cfg.read_timeout, &self.calls) {
                Ok(Ok(x)) => return Ok(x),
                // server-reported error: the connection stays healthy
                Ok(Err(server_err)) => return Err(server_err),
                Err(Xfail::Preflight) => {
                    // another thread broke the channel while we waited on
                    // its lock; same recovery as the entry check
                    if !self.cfg.reconnect {
                        bail!(
                            "rpc {method}: connection is broken after an earlier mid-call failure; reconnect"
                        );
                    }
                    self.redial(&chan)?;
                    continue;
                }
                Err(Xfail::Transport(e)) => {
                    let decorated = decorate(e, method, self.cfg.read_timeout);
                    if self.cfg.reconnect && idem && !retried && self.redial(&chan).is_ok() {
                        retried = true;
                        continue;
                    }
                    return Err(decorated);
                }
            }
        }
    }

    /// Replace the broken channel with a fresh dial — unless another
    /// caller already did (pointer-compare under the write lock).
    fn redial(&self, old: &Arc<Channel>) -> Result<()> {
        let mut g = self.chan.write().expect("rpc channel lock poisoned");
        if !Arc::ptr_eq(&g, old) {
            return Ok(());
        }
        let fresh = dial(&self.peers, &self.desc, &self.cfg)
            .with_context(|| format!("rpc reconnect to {}", self.desc))?;
        *g = Arc::new(fresh);
        Ok(())
    }
}

/// Why an exchange failed without producing a server response.
enum Xfail {
    /// The channel was already broken when we reached its lock — nothing
    /// was sent, the call is not counted.
    Preflight,
    /// IO died mid-call; the channel marked itself broken.
    Transport(anyhow::Error),
}

fn decorate(e: anyhow::Error, method: &str, read_timeout: Duration) -> anyhow::Error {
    let timed_out = e
        .downcast_ref::<std::io::Error>()
        .map(|ioe| {
            matches!(ioe.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
        })
        .unwrap_or(false);
    if timed_out {
        e.context(format!(
            "rpc {method}: no response within {read_timeout:?} — server down or unreachable"
        ))
    } else {
        e.context(format!("rpc {method}: connection failed"))
    }
}

fn dial(peers: &[SocketAddr], desc: &str, cfg: &ClientConfig) -> Result<Channel> {
    let stream = TcpStream::connect(peers).with_context(|| format!("connect {desc}"))?;
    stream.set_nodelay(true)?;
    if !cfg.mux {
        stream.set_read_timeout(Some(cfg.read_timeout))?;
        return Ok(Channel::Seq(SeqChan {
            io: Mutex::new(SeqIo { stream, scratch: String::new(), rbuf: Vec::new() }),
            broken: AtomicBool::new(false),
        }));
    }
    // Mux: the reader blocks with no read timeout; liveness is enforced
    // per-call by recv_timeout, and Drop unblocks the reader by shutting
    // the socket down.
    stream.set_read_timeout(None)?;
    let reader_stream = stream.try_clone().context("clone mux stream")?;
    let shared = Arc::new(MuxShared {
        pending: Mutex::new(HashMap::new()),
        broken: AtomicBool::new(false),
    });
    let shared2 = shared.clone();
    let reader = std::thread::Builder::new()
        .name(format!("rpc-mux-reader-{desc}"))
        .spawn(move || mux_reader(reader_stream, &shared2))?;
    Ok(Channel::Mux(MuxChan {
        writer: Mutex::new(MuxWriter {
            stream: stream.try_clone().context("clone mux stream")?,
            scratch: String::new(),
            next_id: 0,
        }),
        shared,
        stream,
        reader: Mutex::new(Some(reader)),
    }))
}

enum Channel {
    Seq(SeqChan),
    Mux(MuxChan),
}

type ExchangeResult = std::result::Result<Result<(Json, Option<Vec<u8>>)>, Xfail>;

impl Channel {
    fn is_broken(&self) -> bool {
        match self {
            Channel::Seq(c) => c.broken.load(Ordering::SeqCst),
            Channel::Mux(c) => c.shared.broken.load(Ordering::SeqCst),
        }
    }

    /// One request/response exchange.  `Err(Xfail)` = transport-level
    /// failure; `Ok(Err)` = server-reported error (connection healthy);
    /// `Ok(Ok)` = result + optional payload.
    fn exchange(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
        timeout: Duration,
        calls: &AtomicU64,
    ) -> ExchangeResult {
        match self {
            Channel::Seq(c) => c.exchange(method, params, blob, calls),
            Channel::Mux(c) => c.exchange(method, params, blob, timeout, calls),
        }
    }
}

/// The serialized state of one sequential connection: the socket plus
/// reused request-serialization and receive buffers (no per-call
/// allocation).
struct SeqIo {
    stream: TcpStream,
    scratch: String,
    rbuf: Vec<u8>,
}

struct SeqChan {
    io: Mutex<SeqIo>,
    /// Set when a call died mid-frame: request/response framing may be
    /// desynchronized, so every later call fails fast (or redials).
    broken: AtomicBool,
}

impl SeqChan {
    fn exchange(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
        calls: &AtomicU64,
    ) -> ExchangeResult {
        let mut io = self.io.lock().expect("rpc client poisoned");
        // Checked under the lock: a caller that was blocked on the mutex
        // while another thread's call died mid-frame must not write onto
        // the now-desynchronized stream.
        if self.broken.load(Ordering::SeqCst) {
            return Err(Xfail::Preflight);
        }
        calls.fetch_add(1, Ordering::Relaxed);
        match seq_roundtrip(&mut io, method, params, blob) {
            Ok(inner) => Ok(inner),
            Err(e) => {
                self.broken.store(true, Ordering::SeqCst);
                Err(Xfail::Transport(e))
            }
        }
    }
}

#[allow(clippy::type_complexity)]
fn seq_roundtrip(
    io: &mut SeqIo,
    method: &str,
    params: &Json,
    blob: Option<&[u8]>,
) -> Result<Result<(Json, Option<Vec<u8>>)>> {
    let req = Json::obj()
        .set("method", method)
        .set("params", params.clone())
        .set("blob", blob.is_some());
    write_frame_buf(&mut io.stream, &req, &mut io.scratch)?;
    if let Some(b) = blob {
        write_blob(&mut io.stream, b)?;
    }
    let resp = read_frame_buf(&mut io.stream, &mut io.rbuf)?;
    if !resp.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
        return Ok(Err(anyhow!(
            "rpc {method} failed: {}",
            resp.get("error").and_then(|e| e.as_str()).unwrap_or("unknown")
        )));
    }
    let out_blob = if resp.get("blob").and_then(|b| b.as_bool()).unwrap_or(false) {
        Some(read_blob(&mut io.stream)?)
    } else {
        None
    };
    Ok(Ok((resp.get("result").cloned().unwrap_or(Json::Null), out_blob)))
}

type MuxReply = std::result::Result<(Json, Option<Vec<u8>>), MuxErr>;

enum MuxErr {
    Server(String),
    Transport(String),
}

struct MuxShared {
    pending: Mutex<HashMap<u64, mpsc::Sender<MuxReply>>>,
    broken: AtomicBool,
}

struct MuxWriter {
    stream: TcpStream,
    scratch: String,
    next_id: u64,
}

struct MuxChan {
    writer: Mutex<MuxWriter>,
    shared: Arc<MuxShared>,
    /// Original socket handle, kept to shut the reader down on drop.
    stream: TcpStream,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl MuxChan {
    fn exchange(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
        timeout: Duration,
        calls: &AtomicU64,
    ) -> ExchangeResult {
        let (tx, rx) = mpsc::channel::<MuxReply>();
        let id;
        {
            let mut w = self.writer.lock().expect("mux writer poisoned");
            if self.shared.broken.load(Ordering::SeqCst) {
                return Err(Xfail::Preflight);
            }
            id = w.next_id;
            w.next_id += 1;
            // register before writing so the reader can never race the
            // response past us
            self.shared.pending.lock().expect("mux pending poisoned").insert(id, tx);
            calls.fetch_add(1, Ordering::Relaxed);
            let req = Json::obj()
                .set("method", method)
                .set("params", params.clone())
                .set("blob", blob.is_some())
                .set("id", id);
            let sent = write_frame_buf(&mut w.stream, &req, &mut w.scratch).and_then(|()| {
                match blob {
                    Some(b) => write_blob(&mut w.stream, b),
                    None => Ok(()),
                }
            });
            if let Err(e) = sent {
                self.shared.pending.lock().expect("mux pending poisoned").remove(&id);
                self.shared.broken.store(true, Ordering::SeqCst);
                return Err(Xfail::Transport(e));
            }
        }
        match rx.recv_timeout(timeout) {
            Ok(Ok(x)) => Ok(Ok(x)),
            Ok(Err(MuxErr::Server(msg))) => Ok(Err(anyhow!("rpc {method} failed: {msg}"))),
            Ok(Err(MuxErr::Transport(msg))) => {
                Err(Xfail::Transport(anyhow!("mux connection failed: {msg}")))
            }
            Err(_) => {
                // our response never came; the socket may still be
                // delivering other calls, but this caller's contract is
                // the same as a sequential read timeout
                self.shared.pending.lock().expect("mux pending poisoned").remove(&id);
                self.shared.broken.store(true, Ordering::SeqCst);
                Err(Xfail::Transport(anyhow::Error::new(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "mux response timed out",
                ))))
            }
        }
    }
}

impl Drop for MuxChan {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
        let handle = self.reader.lock().ok().and_then(|mut g| g.take());
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

/// Demux loop: route each id-tagged response (and its optional blob
/// frame, which the server always sends back-to-back) to its waiter.  On
/// any transport error every in-flight waiter fails and the channel is
/// marked broken.
fn mux_reader(mut stream: TcpStream, shared: &MuxShared) {
    let mut rbuf: Vec<u8> = Vec::new();
    loop {
        let resp = match read_frame_buf(&mut stream, &mut rbuf) {
            Ok(r) => r,
            Err(e) => {
                fail_all(shared, &format!("{e:#}"));
                return;
            }
        };
        let Some(id) = resp.get("id").and_then(|v| v.as_u64()) else {
            // a mux client only ever sends id-tagged requests, so an
            // id-less response means the stream is not ours to trust
            fail_all(shared, "response missing mux id");
            return;
        };
        let reply: MuxReply = if resp.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
            let out_blob = if resp.get("blob").and_then(|b| b.as_bool()).unwrap_or(false) {
                match read_blob(&mut stream) {
                    Ok(b) => Some(b),
                    Err(e) => {
                        fail_all(shared, &format!("{e:#}"));
                        return;
                    }
                }
            } else {
                None
            };
            Ok((resp.get("result").cloned().unwrap_or(Json::Null), out_blob))
        } else {
            Err(MuxErr::Server(
                resp.get("error").and_then(|e| e.as_str()).unwrap_or("unknown").to_string(),
            ))
        };
        let waiter = shared.pending.lock().expect("mux pending poisoned").remove(&id);
        if let Some(tx) = waiter {
            // the waiter may have timed out and gone; that's fine
            let _ = tx.send(reply);
        }
    }
}

fn fail_all(shared: &MuxShared, msg: &str) {
    shared.broken.store(true, Ordering::SeqCst);
    let mut pending = shared.pending.lock().expect("mux pending poisoned");
    for (_, tx) in pending.drain() {
        let _ = tx.send(Err(MuxErr::Transport(msg.to_string())));
    }
}
