//! Thread-per-connection transport: the portable fallback backend.
//!
//! This is the pre-reactor serving model (one blocking OS thread per
//! accepted socket), kept as an explicit backend for non-Linux hosts and
//! as a behavioral reference: both backends run the identical test
//! suite.  Deferred handlers ([`Outcome::Park`]) are resolved with a
//! millisecond retry loop — on this backend a parked long-poll *does*
//! cost its connection thread, which is exactly the scaling wall the
//! reactor removes.

use super::frame::{read_blob, read_frame_buf, write_blob, write_frame_buf};
use super::stats::RpcCounters;
use super::{DeferHandler, Outcome};
use crate::json::Json;
use crate::store::Blob;
use anyhow::Result;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub(crate) struct ThreadedServer {
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Duplicated handles of every accepted socket, so shutdown can close
    /// live connections deterministically instead of waiting out their
    /// next 200 ms timeout poll.
    conns: Arc<Mutex<Vec<TcpStream>>>,
    conn_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl ThreadedServer {
    pub(crate) fn serve(
        listener: TcpListener,
        handler: DeferHandler,
        counters: Arc<RpcCounters>,
    ) -> Result<ThreadedServer> {
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let conn_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        counters.threads.store(1, Ordering::Relaxed);
        let accept_thread = {
            let stop = stop.clone();
            let conns = conns.clone();
            let conn_threads = conn_threads.clone();
            let local = listener.local_addr()?;
            std::thread::Builder::new()
                .name(format!("rpc-accept-{local}"))
                .spawn(move || {
                    // Exponential backoff while idle: an idle cluster runs
                    // gateway + queue + store accept loops, and three
                    // threads spinning at 2 ms would burn CPU for nothing.
                    const IDLE_FLOOR: Duration = Duration::from_millis(2);
                    const IDLE_CAP: Duration = Duration::from_millis(50);
                    let mut idle_wait = IDLE_FLOOR;
                    while !stop.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                idle_wait = IDLE_FLOOR;
                                if let Ok(dup) = stream.try_clone() {
                                    conns.lock().expect("conn registry poisoned").push(dup);
                                }
                                counters.conns_accepted.fetch_add(1, Ordering::Relaxed);
                                counters.conns_active.fetch_add(1, Ordering::Relaxed);
                                counters.threads.fetch_add(1, Ordering::Relaxed);
                                let h = handler.clone();
                                let stop2 = stop.clone();
                                let counters2 = counters.clone();
                                let t = std::thread::spawn(move || {
                                    let _ = serve_conn(stream, h, stop2, &counters2);
                                    counters2.conns_active.fetch_sub(1, Ordering::Relaxed);
                                    counters2.threads.fetch_sub(1, Ordering::Relaxed);
                                });
                                conn_threads.lock().expect("threads poisoned").push(t);
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(idle_wait);
                                idle_wait = (idle_wait * 2).min(IDLE_CAP);
                            }
                            Err(_) => break,
                        }
                    }
                })?
        };
        Ok(ThreadedServer {
            stop,
            accept_thread: Some(accept_thread),
            conns,
            conn_threads,
        })
    }

    pub(crate) fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Close live sockets so connection threads unblock immediately.
        for c in self.conns.lock().expect("conn registry poisoned").drain(..) {
            let _ = c.shutdown(Shutdown::Both);
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let threads: Vec<_> =
            self.conn_threads.lock().expect("threads poisoned").drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
    }
}

fn serve_conn(
    mut stream: TcpStream,
    handler: DeferHandler,
    stop: Arc<AtomicBool>,
    counters: &RpcCounters,
) -> Result<()> {
    // Clients disable Nagle at connect; mirror it on the accept side so
    // small response frames (leases, acks) flush immediately instead of
    // waiting out a delayed-ACK round.
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    // Serialization + receive buffers, reused across this connection's
    // requests (no per-frame allocation on the hot path).
    let mut scratch = String::new();
    let mut rbuf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let req = match read_frame_buf(&mut stream, &mut rbuf) {
            Ok(r) => r,
            Err(e) => {
                // timeouts poll the stop flag; EOF/parse errors end the conn
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        continue;
                    }
                }
                return Ok(());
            }
        };
        counters.frames_in.fetch_add(1, Ordering::Relaxed);
        counters.bytes_in.fetch_add(rbuf.len() as u64 + 4, Ordering::Relaxed);
        let method = req.str_of("method").unwrap_or("").to_string();
        let params = req.get("params").cloned().unwrap_or(Json::Null);
        let req_id = req.get("id").and_then(|v| v.as_u64());
        let has_blob = req.get("blob").and_then(|b| b.as_bool()).unwrap_or(false);
        let blob = if has_blob {
            // blob frames follow the envelope immediately; block until read
            stream.set_read_timeout(Some(Duration::from_secs(30)))?;
            let b = read_blob(&mut stream)?;
            stream.set_read_timeout(Some(Duration::from_millis(200)))?;
            counters.frames_in.fetch_add(1, Ordering::Relaxed);
            counters.bytes_in.fetch_add(b.len() as u64 + 4, Ordering::Relaxed);
            Some(b)
        } else {
            None
        };
        counters.requests.fetch_add(1, Ordering::Relaxed);
        counters.in_flight.fetch_add(1, Ordering::Relaxed);
        let resolved = resolve(handler(&method, &params, blob), &stop, counters);
        counters.in_flight.fetch_sub(1, Ordering::Relaxed);
        respond(&mut stream, &mut scratch, req_id, resolved, counters)?;
    }
}

/// Run a handler outcome to completion.  Parked outcomes retry on a
/// millisecond loop until they produce a value, error, or expire — this
/// backend has no reactor to register with, so the park rides the
/// connection thread it already owns.
fn resolve(
    outcome: Result<Outcome>,
    stop: &AtomicBool,
    counters: &RpcCounters,
) -> Result<(Json, Option<Blob>)> {
    match outcome {
        Ok(Outcome::Ready(result, blob)) => Ok((result, blob)),
        Ok(Outcome::Park(mut park)) => {
            counters.parked.fetch_add(1, Ordering::Relaxed);
            let out = loop {
                match (park.retry)() {
                    Ok(Some(x)) => break Ok(x),
                    Ok(None) => {
                        if Instant::now() >= park.deadline || stop.load(Ordering::SeqCst) {
                            break Ok((Json::Null, None));
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(e) => break Err(e),
                }
            };
            counters.parked.fetch_sub(1, Ordering::Relaxed);
            out
        }
        Err(e) => Err(e),
    }
}

fn respond(
    stream: &mut TcpStream,
    scratch: &mut String,
    req_id: Option<u64>,
    resolved: Result<(Json, Option<Blob>)>,
    counters: &RpcCounters,
) -> Result<()> {
    let (mut resp, out_blob) = match resolved {
        Ok((result, out_blob)) => (
            Json::obj().set("ok", true).set("result", result).set("blob", out_blob.is_some()),
            out_blob,
        ),
        Err(e) => (Json::obj().set("ok", false).set("error", format!("{e:#}")), None),
    };
    if let Some(id) = req_id {
        resp = resp.set("id", id);
    }
    write_frame_buf(stream, &resp, scratch)?;
    counters.frames_out.fetch_add(1, Ordering::Relaxed);
    counters.bytes_out.fetch_add(scratch.len() as u64 + 4, Ordering::Relaxed);
    if let Some(b) = out_blob {
        write_blob(stream, &b)?;
        counters.frames_out.fetch_add(1, Ordering::Relaxed);
        counters.bytes_out.fetch_add(b.len() as u64 + 4, Ordering::Relaxed);
    }
    Ok(())
}
