//! RPC-plane observability: live per-server counters and their wire
//! snapshot.
//!
//! [`RpcCounters`] is the shared atomic block every transport backend
//! updates; [`RpcStats`] is the snapshot that rides `ClusterStats.rpc`
//! over the stats RPC (lenient JSON, `merge`-able across gateways like
//! every other stats section).

use crate::json::Json;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Live counters, shared between the serving backend and whoever reports
/// stats (the gateway injects one via `RpcConfig::counters` so its own
/// `stats` handler can snapshot the server it runs inside).
#[derive(Debug, Default)]
pub struct RpcCounters {
    /// Transport backend name, recorded by the server at startup so any
    /// holder of the counters can produce a complete snapshot.
    backend: Mutex<String>,
    pub conns_accepted: AtomicU64,
    pub conns_active: AtomicU64,
    pub requests: AtomicU64,
    pub in_flight: AtomicU64,
    pub parked: AtomicU64,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub worker_queue_depth: AtomicU64,
    pub worker_busy: AtomicU64,
    pub saturated: AtomicU64,
    pub threads: AtomicU64,
    pub workers: AtomicU64,
}

impl RpcCounters {
    pub fn set_backend(&self, name: &str) {
        *self.backend.lock().expect("backend name poisoned") = name.to_string();
    }

    pub fn snapshot(&self) -> RpcStats {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        RpcStats {
            backend: self.backend.lock().expect("backend name poisoned").clone(),
            workers: g(&self.workers),
            threads: g(&self.threads),
            conns_accepted: g(&self.conns_accepted),
            conns_active: g(&self.conns_active),
            requests: g(&self.requests),
            in_flight: g(&self.in_flight),
            parked: g(&self.parked),
            frames_in: g(&self.frames_in),
            frames_out: g(&self.frames_out),
            bytes_in: g(&self.bytes_in),
            bytes_out: g(&self.bytes_out),
            worker_queue_depth: g(&self.worker_queue_depth),
            worker_busy: g(&self.worker_busy),
            saturated: g(&self.saturated),
        }
    }
}

/// Snapshot of one RPC server's counters (or a fleet's, after
/// [`RpcStats::merge`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RpcStats {
    /// Transport backend actually serving ("epoll", "uring", "threaded";
    /// empty when no RPC server reported).
    pub backend: String,
    /// Bounded handler pool size.
    pub workers: u64,
    /// OS threads the server owns (reactor + workers) — the number that
    /// stays flat as connections grow.
    pub threads: u64,
    pub conns_accepted: u64,
    pub conns_active: u64,
    pub requests: u64,
    pub in_flight: u64,
    /// Long-polls currently parked as reactor registrations (costing a
    /// waiter entry, not a thread).
    pub parked: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub worker_queue_depth: u64,
    pub worker_busy: u64,
    /// Requests enqueued while every worker was already busy — a rising
    /// rate means the pool (`--rpc-workers`) is the bottleneck.
    pub saturated: u64,
}

impl RpcStats {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("backend", self.backend.as_str())
            .set("workers", self.workers)
            .set("threads", self.threads)
            .set("conns_accepted", self.conns_accepted)
            .set("conns_active", self.conns_active)
            .set("requests", self.requests)
            .set("in_flight", self.in_flight)
            .set("parked", self.parked)
            .set("frames_in", self.frames_in)
            .set("frames_out", self.frames_out)
            .set("bytes_in", self.bytes_in)
            .set("bytes_out", self.bytes_out)
            .set("worker_queue_depth", self.worker_queue_depth)
            .set("worker_busy", self.worker_busy)
            .set("saturated", self.saturated)
    }

    /// Lenient parse: absent or malformed fields default (the section
    /// postdates the stats wire format), unknown fields are ignored.
    pub fn from_json(j: &Json) -> Result<RpcStats> {
        let g = |k: &str| j.u64_of(k).unwrap_or(0);
        Ok(RpcStats {
            backend: j.str_of("backend").unwrap_or_default().to_string(),
            workers: g("workers"),
            threads: g("threads"),
            conns_accepted: g("conns_accepted"),
            conns_active: g("conns_active"),
            requests: g("requests"),
            in_flight: g("in_flight"),
            parked: g("parked"),
            frames_in: g("frames_in"),
            frames_out: g("frames_out"),
            bytes_in: g("bytes_in"),
            bytes_out: g("bytes_out"),
            worker_queue_depth: g("worker_queue_depth"),
            worker_busy: g("worker_busy"),
            saturated: g("saturated"),
        })
    }

    /// Fold another server's snapshot in: counters sum, the backend name
    /// keeps the last non-empty reporter (mixed fleets are visible in
    /// per-gateway views, not the merged one).
    pub fn merge(&mut self, other: &RpcStats) {
        if !other.backend.is_empty() {
            self.backend = other.backend.clone();
        }
        self.workers += other.workers;
        self.threads += other.threads;
        self.conns_accepted += other.conns_accepted;
        self.conns_active += other.conns_active;
        self.requests += other.requests;
        self.in_flight += other.in_flight;
        self.parked += other.parked;
        self.frames_in += other.frames_in;
        self.frames_out += other.frames_out;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        self.worker_queue_depth += other.worker_queue_depth;
        self.worker_busy += other.worker_busy;
        self.saturated += other.saturated;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RpcStats {
        RpcStats {
            backend: "epoll".into(),
            workers: 4,
            threads: 5,
            conns_accepted: 100,
            conns_active: 12,
            requests: 5000,
            in_flight: 3,
            parked: 9,
            frames_in: 5100,
            frames_out: 5050,
            bytes_in: 1 << 20,
            bytes_out: 2 << 20,
            worker_queue_depth: 1,
            worker_busy: 2,
            saturated: 17,
        }
    }

    #[test]
    fn rpc_stats_json_roundtrip() {
        let s = sample();
        assert_eq!(RpcStats::from_json(&s.to_json()).unwrap(), s);
    }

    #[test]
    fn rpc_stats_parse_is_lenient() {
        // Absent fields default; unknown fields from newer peers are
        // ignored — the lenient-wire convention every stats section
        // follows.
        let parsed = RpcStats::from_json(&Json::obj().set("zzz_future", 7u64)).unwrap();
        assert_eq!(parsed, RpcStats::default());
        let j = sample().to_json().set("zzz_future", Json::obj().set("nested", true));
        assert_eq!(RpcStats::from_json(&j).unwrap(), sample());
    }

    #[test]
    fn rpc_stats_merge_sums_counters_and_keeps_last_backend() {
        let mut fleet = RpcStats::default();
        fleet.merge(&sample());
        let mut other = sample();
        other.backend = String::new(); // an old peer reporting no backend
        fleet.merge(&other);
        assert_eq!(fleet.backend, "epoll", "empty backend never overwrites");
        assert_eq!(fleet.requests, 10000);
        assert_eq!(fleet.conns_active, 24);
        assert_eq!(fleet.threads, 10);
    }
}
