//! Invocation pipelines: coordinator-tracked DAGs with CAS result
//! chaining.
//!
//! The paper's programming model (§IV) stops at independent single
//! invocations, but real accelerator applications are pipelines — decode
//! → classify → postprocess.  The Berkeley serverless critique
//! (PAPERS.md, arxiv 1902.03383) names the forced round-trip of
//! intermediate data through the client as a core FaaS limitation;
//! Hardless already has both halves of the fix: a content-addressed
//! store with node-local caching (DESIGN.md §9) and per-runtime-class
//! queue lanes (§7).  A [`PipelineSpec`] names stages (each with its own
//! runtime class and free-form config) and `after` edges; the
//! coordinator-side [`DagTracker`] publishes each stage the moment its
//! parents complete, with the completed parent's **result key as the
//! stage's dataset** — intermediate data flows node-to-node through the
//! store/cache and never back through the client, and cache affinity
//! keeps it warm (zero gateway round trips between stages; pinned by
//! `rust/tests/integration_gateway.rs`).
//!
//! Fan-in stages receive *every* parent's result key as an ordered
//! dataset list (`EventSpec::datasets`, in `after` order — the legacy
//! `dataset` field mirrors the first entry) and, redundantly, under
//! `config.inputs` (stage name → result key) for runtimes that want
//! named lookup.  A failed stage fails exactly its descendants — other
//! branches keep running — and the pipeline reports `PartialFailure`.

use crate::events::{EventSpec, Invocation, Priority, Status};
use crate::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// One stage of a pipeline: a runtime class plus DAG edges.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    /// Stage name, unique within the pipeline (the DAG node id).
    pub name: String,
    /// Runtime class the stage's invocation rides (queue lane).
    pub runtime: String,
    /// Parent stage names.  Empty = root stage (runs on the pipeline's
    /// input dataset).  Order matters: the stage's ordered input list
    /// (`EventSpec::datasets`) is the parents' result keys in exactly
    /// this order, and the first-listed parent's result doubles as the
    /// legacy single `dataset`.
    pub after: Vec<String>,
    /// Free-form run configuration forwarded to the runtime.  Parented
    /// stages additionally receive `config.inputs` (parent name →
    /// result key) at launch time.
    pub config: Json,
}

impl StageSpec {
    pub fn new(name: impl Into<String>, runtime: impl Into<String>) -> StageSpec {
        StageSpec {
            name: name.into(),
            runtime: runtime.into(),
            after: Vec::new(),
            config: Json::obj(),
        }
    }

    pub fn after(mut self, parents: impl IntoIterator<Item = impl Into<String>>) -> StageSpec {
        self.after = parents.into_iter().map(Into::into).collect();
        self
    }

    pub fn with_config(mut self, config: Json) -> StageSpec {
        self.config = config;
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("runtime", self.runtime.as_str())
            .set(
                "after",
                Json::Arr(self.after.iter().map(|p| Json::from(p.as_str())).collect()),
            )
            .set("config", self.config.clone())
    }

    pub fn from_json(j: &Json) -> Result<StageSpec> {
        let after = j
            .get("after")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        Ok(StageSpec {
            name: j.str_of("name")?.to_string(),
            runtime: j.str_of("runtime")?.to_string(),
            after,
            config: j.get("config").cloned().unwrap_or_else(Json::obj),
        })
    }
}

/// A whole pipeline submission: the DAG, its input, and its QoS class.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSpec {
    pub stages: Vec<StageSpec>,
    /// Object-store key of the input dataset fed to every root stage.
    pub dataset: String,
    /// QoS lane every stage invocation rides (see [`Priority`]).
    pub priority: Priority,
}

impl PipelineSpec {
    pub fn new(dataset: impl Into<String>) -> PipelineSpec {
        PipelineSpec {
            stages: Vec::new(),
            dataset: dataset.into(),
            priority: Priority::default(),
        }
    }

    pub fn stage(mut self, stage: StageSpec) -> PipelineSpec {
        self.stages.push(stage);
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> PipelineSpec {
        self.priority = priority;
        self
    }

    /// Structural validation: non-empty, unique stage names, every
    /// parent exists (and isn't the stage itself), and the edge set is
    /// acyclic.  Returns each stage's parent indices (in `after` order).
    pub fn validate(&self) -> Result<Vec<Vec<usize>>> {
        if self.stages.is_empty() {
            bail!("pipeline has no stages");
        }
        let mut index: HashMap<&str, usize> = HashMap::new();
        for (i, s) in self.stages.iter().enumerate() {
            if s.name.is_empty() {
                bail!("stage {i} has an empty name");
            }
            if index.insert(s.name.as_str(), i).is_some() {
                bail!("duplicate stage name '{}'", s.name);
            }
        }
        let mut parents: Vec<Vec<usize>> = Vec::with_capacity(self.stages.len());
        for (i, s) in self.stages.iter().enumerate() {
            let mut ps = Vec::with_capacity(s.after.len());
            for p in &s.after {
                let &pi = index
                    .get(p.as_str())
                    .with_context(|| format!("stage '{}': unknown parent '{p}'", s.name))?;
                if pi == i {
                    bail!("stage '{}' lists itself as a parent", s.name);
                }
                if ps.contains(&pi) {
                    bail!("stage '{}' lists parent '{p}' twice", s.name);
                }
                ps.push(pi);
            }
            parents.push(ps);
        }
        // Kahn's algorithm: every stage must be reachable from the roots.
        let n = self.stages.len();
        let mut indegree: Vec<usize> = parents.iter().map(|p| p.len()).collect();
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, ps) in parents.iter().enumerate() {
            for &p in ps {
                children[p].push(i);
            }
        }
        let mut ready: Vec<usize> =
            (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut seen = 0;
        while let Some(i) = ready.pop() {
            seen += 1;
            for &c in &children[i] {
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    ready.push(c);
                }
            }
        }
        if seen != n {
            bail!("pipeline stage graph has a cycle");
        }
        Ok(parents)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set(
                "stages",
                Json::Arr(self.stages.iter().map(|s| s.to_json()).collect()),
            )
            .set("dataset", self.dataset.as_str())
            .set("priority", self.priority.as_str())
    }

    pub fn from_json(j: &Json) -> Result<PipelineSpec> {
        let stages = j
            .get("stages")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().map(StageSpec::from_json).collect::<Result<Vec<_>>>())
            .transpose()?
            .unwrap_or_default();
        Ok(PipelineSpec {
            stages,
            dataset: j.str_of("dataset")?.to_string(),
            // Lenient: absent/unknown = Interactive (pre-QoS peers).
            priority: j
                .get("priority")
                .and_then(|v| v.as_str())
                .and_then(|s| Priority::parse(s).ok())
                .unwrap_or_default(),
        })
    }
}

/// Lifecycle of one stage inside a tracked pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum StageStatus {
    /// Waiting on parents.
    Pending,
    /// Invocation published (queued or executing somewhere).
    Running,
    Succeeded,
    Failed(String),
    /// Never ran: an ancestor failed.
    Skipped,
}

impl StageStatus {
    pub fn as_str(&self) -> &str {
        match self {
            StageStatus::Pending => "pending",
            StageStatus::Running => "running",
            StageStatus::Succeeded => "succeeded",
            StageStatus::Failed(_) => "failed",
            StageStatus::Skipped => "skipped",
        }
    }

    fn is_terminal(&self) -> bool {
        matches!(
            self,
            StageStatus::Succeeded | StageStatus::Failed(_) | StageStatus::Skipped
        )
    }

    fn to_json(&self) -> Json {
        match self {
            StageStatus::Failed(reason) => Json::obj().set("failed", reason.as_str()),
            s => Json::Str(s.as_str().to_string()),
        }
    }

    fn from_json(j: &Json) -> StageStatus {
        match j {
            Json::Str(s) => match s.as_str() {
                "pending" => StageStatus::Pending,
                "running" => StageStatus::Running,
                "succeeded" => StageStatus::Succeeded,
                "skipped" => StageStatus::Skipped,
                other => StageStatus::Failed(format!("unknown stage status {other}")),
            },
            obj => StageStatus::Failed(
                obj.str_of("failed").unwrap_or("unknown").to_string(),
            ),
        }
    }
}

/// Aggregate pipeline state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineState {
    Running,
    Succeeded,
    /// All stages settled, at least one failed or was skipped.
    PartialFailure,
}

impl PipelineState {
    pub fn as_str(&self) -> &'static str {
        match self {
            PipelineState::Running => "running",
            PipelineState::Succeeded => "succeeded",
            PipelineState::PartialFailure => "partial_failure",
        }
    }
}

/// Per-stage view in a [`PipelineStatus`] report.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    pub name: String,
    pub runtime: String,
    pub status: StageStatus,
    /// Invocation id once the stage launched.
    pub invocation_id: Option<String>,
    /// Resolved input key the stage ran on (the CAS chaining evidence).
    pub dataset: Option<String>,
    /// Result key once the stage succeeded.
    pub result_key: Option<String>,
}

/// Client-facing pipeline snapshot (travels the gateway wire as JSON).
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineStatus {
    pub id: String,
    pub state: PipelineState,
    pub stages: Vec<StageReport>,
}

impl PipelineStatus {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("id", self.id.as_str())
            .set("state", self.state.as_str())
            .set(
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|s| {
                            let opt = |v: &Option<String>| {
                                v.as_ref()
                                    .map(|s| Json::from(s.as_str()))
                                    .unwrap_or(Json::Null)
                            };
                            Json::obj()
                                .set("name", s.name.as_str())
                                .set("runtime", s.runtime.as_str())
                                .set("status", s.status.to_json())
                                .set("invocation_id", opt(&s.invocation_id))
                                .set("dataset", opt(&s.dataset))
                                .set("result_key", opt(&s.result_key))
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(j: &Json) -> Result<PipelineStatus> {
        let state = match j.str_of("state")? {
            "succeeded" => PipelineState::Succeeded,
            "partial_failure" => PipelineState::PartialFailure,
            // Lenient: unknown states from newer peers read as running.
            _ => PipelineState::Running,
        };
        let stages = j
            .get("stages")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .map(|s| {
                        let opt = |k: &str| {
                            s.get(k).and_then(|v| v.as_str()).map(String::from)
                        };
                        Ok(StageReport {
                            name: s.str_of("name")?.to_string(),
                            runtime: s.str_of("runtime")?.to_string(),
                            status: s
                                .get("status")
                                .map(StageStatus::from_json)
                                .unwrap_or(StageStatus::Pending),
                            invocation_id: opt("invocation_id"),
                            dataset: opt("dataset"),
                            result_key: opt("result_key"),
                        })
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .transpose()?
            .unwrap_or_default();
        Ok(PipelineStatus { id: j.str_of("id")?.to_string(), state, stages })
    }

    /// One line per stage, for the CLI.
    pub fn describe(&self) -> String {
        let mut out = format!("{} [{}]", self.id, self.state.as_str());
        for s in &self.stages {
            out.push_str(&format!(
                "\n  {:<16} {:<10} {}{}",
                s.name,
                s.status.as_str(),
                s.invocation_id.as_deref().unwrap_or("-"),
                s.dataset
                    .as_deref()
                    .map(|d| format!(" <- {d}"))
                    .unwrap_or_default(),
            ));
        }
        out
    }
}

struct StageRun {
    spec: StageSpec,
    parents: Vec<usize>,
    children: Vec<usize>,
    remaining_parents: usize,
    status: StageStatus,
    invocation_id: Option<String>,
    dataset: Option<String>,
    result_key: Option<String>,
}

struct PipelineRun {
    dataset: String,
    priority: Priority,
    stages: Vec<StageRun>,
}

#[derive(Default)]
struct Inner {
    runs: HashMap<String, PipelineRun>,
    /// In-flight stage invocations: invocation id → (pipeline, stage).
    /// Entries are removed on terminal completion, which also makes
    /// duplicate completion reports idempotent.
    by_invocation: HashMap<String, (String, usize)>,
}

/// Coordinator-side DAG tracker.
///
/// The tracker owns the DAG bookkeeping only; actually *submitting* a
/// stage is the caller's business, passed in as a `launch` closure
/// (`EventSpec -> invocation id`).  Both [`DagTracker::submit`] and
/// [`DagTracker::on_completion`] run their launches under the tracker
/// lock, so a stage's invocation-id mapping is always registered before
/// any completion for it can be processed — no lost-advance race even
/// with instantaneous workers.
#[derive(Default)]
pub struct DagTracker {
    inner: Mutex<Inner>,
}

impl DagTracker {
    pub fn new() -> DagTracker {
        DagTracker::default()
    }

    /// Validate `spec`, register the pipeline under `id`, and launch its
    /// root stages.
    pub fn submit(
        &self,
        id: &str,
        spec: PipelineSpec,
        mut launch: impl FnMut(EventSpec) -> Result<String>,
    ) -> Result<()> {
        let parents = spec.validate()?;
        let n = spec.stages.len();
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, ps) in parents.iter().enumerate() {
            for &p in ps {
                children[p].push(i);
            }
        }
        let mut run = PipelineRun {
            dataset: spec.dataset,
            priority: spec.priority,
            stages: spec
                .stages
                .into_iter()
                .zip(parents)
                .enumerate()
                .map(|(i, (s, ps))| StageRun {
                    remaining_parents: ps.len(),
                    parents: ps,
                    children: std::mem::take(&mut children[i]),
                    spec: s,
                    status: StageStatus::Pending,
                    invocation_id: None,
                    dataset: None,
                    result_key: None,
                })
                .collect(),
        };
        let mut inner = self.inner.lock().expect("dag tracker poisoned");
        if inner.runs.contains_key(id) {
            bail!("duplicate pipeline id {id}");
        }
        let roots: Vec<usize> = (0..n).filter(|&i| run.stages[i].parents.is_empty()).collect();
        for i in roots {
            launch_stage(id, &mut run, i, &mut inner.by_invocation, &mut launch);
        }
        inner.runs.insert(id.to_string(), run);
        Ok(())
    }

    /// Advance the DAG on a terminal invocation: mark the stage, launch
    /// children whose parents are all done, cascade-skip descendants of
    /// a failure.  Non-pipeline invocations are ignored; duplicate
    /// reports are no-ops.
    pub fn on_completion(
        &self,
        inv: &Invocation,
        mut launch: impl FnMut(EventSpec) -> Result<String>,
    ) {
        if !inv.is_terminal() {
            return;
        }
        let mut inner = self.inner.lock().expect("dag tracker poisoned");
        let Inner { runs, by_invocation } = &mut *inner;
        let Some((pid, idx)) = by_invocation.remove(&inv.id) else {
            return;
        };
        let Some(run) = runs.get_mut(&pid) else {
            return;
        };
        match &inv.status {
            Status::Succeeded => {
                // Workers persist results under `results/<invocation id>`
                // (`store::keys::result`); fall back to that convention
                // if a reporter omitted the key.
                let key = inv
                    .result_key
                    .clone()
                    .unwrap_or_else(|| crate::store::keys::result(&inv.id));
                run.stages[idx].status = StageStatus::Succeeded;
                run.stages[idx].result_key = Some(key);
                let children = run.stages[idx].children.clone();
                for c in children {
                    run.stages[c].remaining_parents -= 1;
                    if run.stages[c].remaining_parents == 0
                        && run.stages[c].status == StageStatus::Pending
                    {
                        launch_stage(&pid, run, c, by_invocation, &mut launch);
                    }
                }
            }
            Status::Failed(reason) => {
                run.stages[idx].status = StageStatus::Failed(reason.clone());
                skip_descendants(run, idx);
            }
            _ => unreachable!("guarded by is_terminal"),
        }
    }

    /// Snapshot one pipeline.
    pub fn status(&self, id: &str) -> Option<PipelineStatus> {
        let inner = self.inner.lock().expect("dag tracker poisoned");
        let run = inner.runs.get(id)?;
        let stages: Vec<StageReport> = run
            .stages
            .iter()
            .map(|s| StageReport {
                name: s.spec.name.clone(),
                runtime: s.spec.runtime.clone(),
                status: s.status.clone(),
                invocation_id: s.invocation_id.clone(),
                dataset: s.dataset.clone(),
                result_key: s.result_key.clone(),
            })
            .collect();
        let state = if stages.iter().all(|s| s.status == StageStatus::Succeeded) {
            PipelineState::Succeeded
        } else if stages.iter().all(|s| s.status.is_terminal()) {
            PipelineState::PartialFailure
        } else {
            PipelineState::Running
        };
        Some(PipelineStatus { id: id.to_string(), state, stages })
    }

    /// Number of tracked pipelines (gauge for `ClusterStats`).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("dag tracker poisoned").runs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Resolve a ready stage's inputs and publish it: the stage's ordered
/// dataset list is every parent's result key in `after` order (the CAS
/// chain links — the pipeline's own input for roots), with the legacy
/// single `dataset` mirroring the first entry; fan-in stages also get
/// every parent's result under `config.inputs` for named lookup.  A
/// launch error fails the stage and skips its descendants (other
/// branches keep running).
fn launch_stage(
    pipeline_id: &str,
    run: &mut PipelineRun,
    idx: usize,
    by_invocation: &mut HashMap<String, (String, usize)>,
    launch: &mut impl FnMut(EventSpec) -> Result<String>,
) {
    let parents = run.stages[idx].parents.clone();
    let datasets: Vec<String> = if parents.is_empty() {
        vec![run.dataset.clone()]
    } else {
        parents
            .iter()
            .map(|&p| {
                run.stages[p]
                    .result_key
                    .clone()
                    .expect("launch_stage only called once every parent succeeded")
            })
            .collect()
    };
    let mut config = match &run.stages[idx].spec.config {
        Json::Obj(_) => run.stages[idx].spec.config.clone(),
        _ => Json::obj(),
    };
    if !parents.is_empty() {
        let mut inputs = Json::obj();
        for &p in &parents {
            let key = run.stages[p].result_key.clone().unwrap_or_default();
            inputs = inputs.set(&run.stages[p].spec.name, key.as_str());
        }
        config = config.set("inputs", inputs);
    }
    let spec = EventSpec::new(&run.stages[idx].spec.runtime, &datasets[0])
        .with_datasets(datasets.clone())
        .with_config(config)
        .with_priority(run.priority);
    run.stages[idx].dataset = Some(datasets[0].clone());
    match launch(spec) {
        Ok(inv_id) => {
            by_invocation.insert(inv_id.clone(), (pipeline_id.to_string(), idx));
            run.stages[idx].status = StageStatus::Running;
            run.stages[idx].invocation_id = Some(inv_id);
        }
        Err(e) => {
            run.stages[idx].status = StageStatus::Failed(format!("launch failed: {e:#}"));
            skip_descendants(run, idx);
        }
    }
}

/// Mark every not-yet-launched descendant of `idx` as [`StageStatus::Skipped`].
fn skip_descendants(run: &mut PipelineRun, idx: usize) {
    let mut stack = run.stages[idx].children.clone();
    while let Some(c) = stack.pop() {
        if run.stages[c].status == StageStatus::Pending {
            run.stages[c].status = StageStatus::Skipped;
        }
        // Recurse regardless of state: a diamond may reach a node first
        // through an already-skipped sibling path.
        let mut grand = run.stages[c].children.clone();
        stack.append(&mut grand);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::keys;
    use std::collections::HashSet;

    fn chain3() -> PipelineSpec {
        PipelineSpec::new("datasets/in")
            .stage(StageSpec::new("decode", "dec"))
            .stage(StageSpec::new("classify", "cls").after(["decode"]))
            .stage(StageSpec::new("post", "pp").after(["classify"]))
    }

    /// A tiny deterministic harness: `launch` hands out inv-ids and
    /// records specs; `complete` reports a terminal invocation back.
    struct Sim {
        tracker: DagTracker,
        next: u64,
        /// Launched-but-uncompleted invocation ids.
        pending: Vec<String>,
        specs: HashMap<String, EventSpec>,
    }

    impl Sim {
        fn new() -> Sim {
            Sim {
                tracker: DagTracker::new(),
                next: 0,
                pending: Vec::new(),
                specs: HashMap::new(),
            }
        }

        fn submit(&mut self, id: &str, spec: PipelineSpec) -> Result<()> {
            let (next, pending, specs) = (&mut self.next, &mut self.pending, &mut self.specs);
            self.tracker.submit(id, spec, |s| {
                let iid = format!("inv-{}", *next);
                *next += 1;
                pending.push(iid.clone());
                specs.insert(iid.clone(), s);
                Ok(iid)
            })
        }

        /// Complete `iid` (success unless `fail`), advancing the DAG.
        fn complete(&mut self, iid: &str, fail: bool) {
            let spec = self.specs[iid].clone();
            let mut inv = Invocation::new(iid, spec, crate::util::SimTime(0));
            if fail {
                inv.status = Status::Failed("boom".into());
            } else {
                inv.status = Status::Succeeded;
                inv.result_key = Some(keys::result(iid));
            }
            self.pending.retain(|p| p != iid);
            let (next, pending, specs) = (&mut self.next, &mut self.pending, &mut self.specs);
            self.tracker.on_completion(&inv, |s| {
                let iid = format!("inv-{}", *next);
                *next += 1;
                pending.push(iid.clone());
                specs.insert(iid.clone(), s);
                Ok(iid)
            });
        }
    }

    #[test]
    fn validation_rejects_malformed_dags() {
        assert!(PipelineSpec::new("d").validate().is_err(), "empty");
        let dup = PipelineSpec::new("d")
            .stage(StageSpec::new("a", "r"))
            .stage(StageSpec::new("a", "r"));
        assert!(dup.validate().unwrap_err().to_string().contains("duplicate"));
        let ghost = PipelineSpec::new("d").stage(StageSpec::new("a", "r").after(["zzz"]));
        assert!(ghost.validate().unwrap_err().to_string().contains("unknown parent"));
        let selfloop = PipelineSpec::new("d").stage(StageSpec::new("a", "r").after(["a"]));
        assert!(selfloop.validate().is_err());
        let cycle = PipelineSpec::new("d")
            .stage(StageSpec::new("a", "r").after(["b"]))
            .stage(StageSpec::new("b", "r").after(["a"]));
        assert!(cycle.validate().unwrap_err().to_string().contains("cycle"));
        assert!(chain3().validate().is_ok());
    }

    #[test]
    fn spec_json_roundtrip_and_lenient_priority() {
        let spec = chain3().with_priority(Priority::Batch);
        let back = PipelineSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // Old-peer payload without a priority field: Interactive.
        let mut j = chain3().to_json();
        j = j.set("priority", Json::Null);
        assert_eq!(
            PipelineSpec::from_json(&j).unwrap().priority,
            Priority::Interactive
        );
    }

    #[test]
    fn linear_chain_links_datasets_through_result_keys() {
        let mut sim = Sim::new();
        sim.submit("pipe-1", chain3()).unwrap();
        // Only the root launches, on the pipeline's own dataset.
        assert_eq!(sim.pending, vec!["inv-0"]);
        assert_eq!(sim.specs["inv-0"].dataset, "datasets/in");
        assert_eq!(sim.specs["inv-0"].runtime, "dec");

        sim.complete("inv-0", false);
        assert_eq!(sim.pending, vec!["inv-1"]);
        // The CAS chain link: stage N+1's dataset is stage N's result key.
        assert_eq!(sim.specs["inv-1"].dataset, keys::result("inv-0"));
        sim.complete("inv-1", false);
        assert_eq!(sim.specs["inv-2"].dataset, keys::result("inv-1"));
        sim.complete("inv-2", false);

        let st = sim.tracker.status("pipe-1").unwrap();
        assert_eq!(st.state, PipelineState::Succeeded);
        assert!(st.stages.iter().all(|s| s.status == StageStatus::Succeeded));
        assert_eq!(st.stages[1].dataset.as_deref(), Some("results/inv-0"));
        assert!(sim.pending.is_empty());
    }

    #[test]
    fn fan_in_receives_all_parent_results_in_config_inputs() {
        // Diamond: src -> (left, right) -> join.
        let spec = PipelineSpec::new("datasets/in")
            .stage(StageSpec::new("src", "r"))
            .stage(StageSpec::new("left", "r").after(["src"]))
            .stage(StageSpec::new("right", "r").after(["src"]))
            .stage(StageSpec::new("join", "r").after(["left", "right"]));
        let mut sim = Sim::new();
        sim.submit("pipe-1", spec).unwrap();
        sim.complete("inv-0", false); // src -> left + right launch
        assert_eq!(sim.pending.len(), 2, "fan-out: both branches launch");
        let branches = sim.pending.clone();
        // Joining needs *both* parents: completing one is not enough.
        sim.complete(&branches[0], false);
        assert_eq!(sim.pending.len(), 1, "join still waiting on the other branch");
        sim.complete(&branches[1], false);
        assert_eq!(sim.pending.len(), 1, "join launched");
        let join_id = sim.pending[0].clone();
        let join_spec = &sim.specs[&join_id];
        // dataset = first-listed parent's result; inputs = all parents.
        let st = sim.tracker.status("pipe-1").unwrap();
        let inv_of = |name: &str| {
            st.stages
                .iter()
                .find(|s| s.name == name)
                .unwrap()
                .invocation_id
                .clone()
                .unwrap()
        };
        assert_eq!(join_spec.dataset, keys::result(&inv_of("left")));
        // The ordered input list carries BOTH parents' result keys, in
        // `after` order — not just the first parent.
        assert_eq!(
            join_spec.datasets,
            vec![keys::result(&inv_of("left")), keys::result(&inv_of("right"))]
        );
        let inputs = join_spec.config.get("inputs").expect("fan-in inputs");
        assert_eq!(
            inputs.str_of("left").unwrap(),
            keys::result(&inv_of("left"))
        );
        assert_eq!(
            inputs.str_of("right").unwrap(),
            keys::result(&inv_of("right"))
        );
        sim.complete(&join_id, false);
        assert_eq!(
            sim.tracker.status("pipe-1").unwrap().state,
            PipelineState::Succeeded
        );
    }

    /// Regression: a join stage's dataset list must follow the stage's
    /// `after` order (and survive the EventSpec wire roundtrip), even
    /// when that order disagrees with name sort or completion order.
    /// The old behavior delivered only one parent's key as `dataset` and
    /// buried the rest in stage config.
    #[test]
    fn fan_in_datasets_follow_after_order_not_completion_order() {
        let spec = PipelineSpec::new("datasets/in")
            .stage(StageSpec::new("src", "r"))
            .stage(StageSpec::new("a-early", "r").after(["src"]))
            .stage(StageSpec::new("z-late", "r").after(["src"]))
            // `after` deliberately lists the lexicographically-later
            // stage first.
            .stage(StageSpec::new("join", "r").after(["z-late", "a-early"]));
        let mut sim = Sim::new();
        sim.submit("pipe-1", spec).unwrap();
        sim.complete("inv-0", false);
        // Complete the branches in the OPPOSITE of `after` order.
        let st = sim.tracker.status("pipe-1").unwrap();
        let inv_of = |st: &PipelineStatus, name: &str| {
            st.stages
                .iter()
                .find(|s| s.name == name)
                .unwrap()
                .invocation_id
                .clone()
                .unwrap()
        };
        let early = inv_of(&st, "a-early");
        let late = inv_of(&st, "z-late");
        sim.complete(&early, false);
        sim.complete(&late, false);
        let st = sim.tracker.status("pipe-1").unwrap();
        let join_id = inv_of(&st, "join");
        let join_spec = &sim.specs[&join_id];
        let want = vec![keys::result(&late), keys::result(&early)];
        assert_eq!(join_spec.datasets, want, "after-order, not completion/name order");
        assert_eq!(join_spec.dataset, want[0], "legacy field mirrors the head");
        // Roots carry the pipeline input as a one-entry list.
        assert_eq!(sim.specs["inv-0"].datasets, vec!["datasets/in".to_string()]);
        // And the ordered list survives serialization (what a node-side
        // peer actually sees across the gateway wire).
        let back = EventSpec::from_json(&join_spec.to_json()).unwrap();
        assert_eq!(back.datasets, want);
    }

    #[test]
    fn failure_skips_exactly_the_descendants() {
        // src -> (bad, good); bad -> tail.  Failing `bad` must skip only
        // `tail`; `good` still runs; state = PartialFailure.
        let spec = PipelineSpec::new("datasets/in")
            .stage(StageSpec::new("src", "r"))
            .stage(StageSpec::new("bad", "r").after(["src"]))
            .stage(StageSpec::new("good", "r").after(["src"]))
            .stage(StageSpec::new("tail", "r").after(["bad"]));
        let mut sim = Sim::new();
        sim.submit("pipe-1", spec).unwrap();
        sim.complete("inv-0", false);
        let st = sim.tracker.status("pipe-1").unwrap();
        let bad_id = st.stages[1].invocation_id.clone().unwrap();
        let good_id = st.stages[2].invocation_id.clone().unwrap();
        sim.complete(&bad_id, true);
        sim.complete(&good_id, false);
        let st = sim.tracker.status("pipe-1").unwrap();
        assert_eq!(st.state, PipelineState::PartialFailure);
        assert_eq!(st.stages[0].status, StageStatus::Succeeded);
        assert_eq!(st.stages[1].status, StageStatus::Failed("boom".into()));
        assert_eq!(st.stages[2].status, StageStatus::Succeeded);
        assert_eq!(st.stages[3].status, StageStatus::Skipped);
        assert!(st.stages[3].invocation_id.is_none(), "skipped stages never launch");
        assert!(sim.pending.is_empty());
    }

    #[test]
    fn duplicate_completion_reports_are_idempotent() {
        let mut sim = Sim::new();
        sim.submit("pipe-1", chain3()).unwrap();
        sim.complete("inv-0", false);
        assert_eq!(sim.pending, vec!["inv-1"]);
        // A node retrying its report RPC delivers inv-0 again: no effect.
        sim.complete("inv-0", false);
        assert_eq!(sim.pending, vec!["inv-1"], "no double-launch of classify");
        // Foreign (non-pipeline) completions are ignored outright.
        let mut foreign =
            Invocation::new("inv-999", EventSpec::new("r", "d"), crate::util::SimTime(0));
        foreign.status = Status::Succeeded;
        sim.tracker.on_completion(&foreign, |_| unreachable!("no launches"));
    }

    #[test]
    fn status_json_roundtrip() {
        let mut sim = Sim::new();
        sim.submit("pipe-1", chain3()).unwrap();
        sim.complete("inv-0", false);
        let st = sim.tracker.status("pipe-1").unwrap();
        let back = PipelineStatus::from_json(&st.to_json()).unwrap();
        assert_eq!(back, st);
        assert!(back.describe().contains("decode"));
        // Failed stage reasons survive the wire too.
        let inv_id = st.stages[1].invocation_id.clone().unwrap();
        sim.complete(&inv_id, true);
        let st = sim.tracker.status("pipe-1").unwrap();
        let back = PipelineStatus::from_json(&st.to_json()).unwrap();
        assert_eq!(back, st);
        assert_eq!(back.state, PipelineState::PartialFailure);
    }

    /// Random DAGs: every stage runs exactly once, only after all its
    /// parents, with `dataset` = first parent's result key and a correct
    /// `inputs` map; completion order is randomized.
    #[test]
    fn property_random_dags_run_every_stage_once_after_parents() {
        crate::prop::check(
            "dag-runs-once-after-parents",
            40,
            |rng| {
                let n = rng.range(1, 10) as usize;
                // Each stage picks parents among its predecessors.
                let parents: Vec<Vec<u64>> = (0..n)
                    .map(|i| {
                        (0..i as u64)
                            .filter(|_| rng.below(3) == 0)
                            .collect()
                    })
                    .collect();
                let order_seed = rng.next_u64();
                (parents, order_seed)
            },
            |(parents, order_seed)| {
                let mut spec = PipelineSpec::new("datasets/in");
                for (i, ps) in parents.iter().enumerate() {
                    spec = spec.stage(
                        StageSpec::new(format!("s{i}"), format!("r{}", i % 3))
                            .after(ps.iter().map(|p| format!("s{p}"))),
                    );
                }
                let mut sim = Sim::new();
                sim.submit("pipe-1", spec).unwrap();
                let mut order_rng = crate::util::Rng::new(*order_seed);
                let mut completed: HashSet<String> = HashSet::new();
                let mut launched_total = sim.pending.len();
                while !sim.pending.is_empty() {
                    let pick = order_rng.below(sim.pending.len() as u64) as usize;
                    let iid = sim.pending[pick].clone();
                    // Check launch-time invariants before completing.
                    let st = sim.tracker.status("pipe-1").unwrap();
                    let stage = st
                        .stages
                        .iter()
                        .position(|s| s.invocation_id.as_deref() == Some(iid.as_str()))
                        .expect("launched invocation maps to a stage");
                    let ps = &parents[stage];
                    for p in ps {
                        let pname = format!("s{p}");
                        let pstage =
                            st.stages.iter().find(|s| s.name == pname).unwrap();
                        if pstage.status != StageStatus::Succeeded {
                            return false; // launched before a parent finished
                        }
                    }
                    let espec = &sim.specs[&iid];
                    let want_dataset = match ps.first() {
                        None => "datasets/in".to_string(),
                        Some(p) => {
                            let pinv = st.stages[*p as usize]
                                .invocation_id
                                .clone()
                                .unwrap();
                            keys::result(&pinv)
                        }
                    };
                    if espec.dataset != want_dataset {
                        return false;
                    }
                    // The ordered input list is every parent's result
                    // key in `after` order (roots: the pipeline input).
                    let want_datasets: Vec<String> = if ps.is_empty() {
                        vec!["datasets/in".to_string()]
                    } else {
                        ps.iter()
                            .map(|p| {
                                let pinv = st.stages[*p as usize]
                                    .invocation_id
                                    .clone()
                                    .unwrap();
                                keys::result(&pinv)
                            })
                            .collect()
                    };
                    if espec.datasets != want_datasets {
                        return false;
                    }
                    if !ps.is_empty() {
                        let Some(inputs) = espec.config.get("inputs") else {
                            return false;
                        };
                        for p in ps {
                            let pinv = st.stages[*p as usize]
                                .invocation_id
                                .clone()
                                .unwrap();
                            if inputs.str_of(&format!("s{p}")).ok()
                                != Some(keys::result(&pinv).as_str())
                            {
                                return false;
                            }
                        }
                    }
                    if !completed.insert(iid.clone()) {
                        return false; // ran twice
                    }
                    let before = sim.pending.len();
                    sim.complete(&iid, false);
                    launched_total += sim.pending.len() + 1 - before;
                }
                // Every stage ran exactly once and succeeded.
                let st = sim.tracker.status("pipe-1").unwrap();
                st.state == PipelineState::Succeeded
                    && launched_total == parents.len()
                    && st.stages.iter().all(|s| s.status == StageStatus::Succeeded)
            },
        );
    }

    /// Random DAGs with one failing stage: exactly its descendants are
    /// skipped, everything else succeeds, state = PartialFailure.
    #[test]
    fn property_failure_cascades_to_exactly_the_descendants() {
        crate::prop::check(
            "dag-failure-exact-descendants",
            40,
            |rng| {
                let n = rng.range(2, 10) as usize;
                let parents: Vec<Vec<u64>> = (0..n)
                    .map(|i| (0..i as u64).filter(|_| rng.below(3) == 0).collect())
                    .collect();
                let fail = rng.below(n as u64) as usize;
                let order_seed = rng.next_u64();
                (parents, fail, order_seed)
            },
            |(parents, fail, order_seed)| {
                // Expected skip set: transitive descendants of `fail`.
                let n = parents.len();
                let mut descendants: HashSet<usize> = HashSet::new();
                loop {
                    let before = descendants.len();
                    for i in 0..n {
                        if parents[i].iter().any(|&p| {
                            p as usize == *fail || descendants.contains(&(p as usize))
                        }) {
                            descendants.insert(i);
                        }
                    }
                    if descendants.len() == before {
                        break;
                    }
                }
                let mut spec = PipelineSpec::new("datasets/in");
                for (i, ps) in parents.iter().enumerate() {
                    spec = spec.stage(
                        StageSpec::new(format!("s{i}"), "r")
                            .after(ps.iter().map(|p| format!("s{p}"))),
                    );
                }
                let mut sim = Sim::new();
                sim.submit("pipe-1", spec).unwrap();
                let mut order_rng = crate::util::Rng::new(*order_seed);
                while !sim.pending.is_empty() {
                    let pick = order_rng.below(sim.pending.len() as u64) as usize;
                    let iid = sim.pending[pick].clone();
                    let st = sim.tracker.status("pipe-1").unwrap();
                    let stage = st
                        .stages
                        .iter()
                        .position(|s| s.invocation_id.as_deref() == Some(iid.as_str()))
                        .unwrap();
                    sim.complete(&iid, stage == *fail);
                }
                let st = sim.tracker.status("pipe-1").unwrap();
                if st.state != PipelineState::PartialFailure {
                    return false;
                }
                st.stages.iter().enumerate().all(|(i, s)| {
                    if i == *fail {
                        matches!(s.status, StageStatus::Failed(_))
                    } else if descendants.contains(&i) {
                        s.status == StageStatus::Skipped
                    } else {
                        s.status == StageStatus::Succeeded
                    }
                })
            },
        );
    }

    #[test]
    fn duplicate_pipeline_id_rejected() {
        let sim_tracker = DagTracker::new();
        let mut n = 0u64;
        let mut launch = |_: EventSpec| {
            n += 1;
            Ok(format!("inv-{n}"))
        };
        sim_tracker.submit("pipe-1", chain3(), &mut launch).unwrap();
        assert!(sim_tracker.submit("pipe-1", chain3(), &mut launch).is_err());
        assert_eq!(sim_tracker.len(), 1);
    }

    #[test]
    fn launch_failure_fails_stage_and_skips_descendants() {
        // The queue refuses the root launch: the stage reads Failed, its
        // chain is skipped, and the pipeline settles as PartialFailure
        // instead of hanging forever.
        let tracker = DagTracker::new();
        tracker
            .submit("pipe-1", chain3(), |_| bail!("queue unavailable"))
            .unwrap();
        let st = tracker.status("pipe-1").unwrap();
        assert_eq!(st.state, PipelineState::PartialFailure);
        assert!(matches!(st.stages[0].status, StageStatus::Failed(_)));
        assert_eq!(st.stages[1].status, StageStatus::Skipped);
        assert_eq!(st.stages[2].status, StageStatus::Skipped);
    }
}
