//! Minimal JSON substrate (serde is unavailable in this offline build).
//!
//! A complete RFC 8259 value model, recursive-descent parser, and
//! serializer.  Used for the AOT `manifest.json`, wire-protocol framing,
//! config files, metric exports, and object-store metadata.
//!
//! Numbers are stored as `f64` (JSON's interchange model); integer
//! accessors check exact representability.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order for deterministic round-trips.
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error.
#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {pos}: {msg}")]
    Parse { pos: usize, msg: String },
    #[error("json type error: expected {expected} at '{key}'")]
    Type { expected: &'static str, key: String },
    #[error("json missing key '{0}'")]
    Missing(String),
}

pub type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    // ---------------------------------------------------------------- build
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(m) = &mut self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    // --------------------------------------------------------------- access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // Typed required accessors (error carries the key for diagnostics).
    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or(JsonError::Type { expected: "string", key: key.into() })
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or(JsonError::Type { expected: "number", key: key.into() })
    }

    pub fn u64_of(&self, key: &str) -> Result<u64> {
        self.req(key)?.as_u64().ok_or(JsonError::Type { expected: "u64", key: key.into() })
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or(JsonError::Type { expected: "usize", key: key.into() })
    }

    pub fn bool_of(&self, key: &str) -> Result<bool> {
        self.req(key)?.as_bool().ok_or(JsonError::Type { expected: "bool", key: key.into() })
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?.as_arr().ok_or(JsonError::Type { expected: "array", key: key.into() })
    }

    // ---------------------------------------------------------------- parse
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ serialize
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Conversions
// ---------------------------------------------------------------------------

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(a: &[T]) -> Json {
        Json::Arr(a.iter().cloned().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape char")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Reassemble UTF-8 multibyte sequences.
                    let len = utf8_len(c).ok_or_else(|| self.err("bad utf8 lead byte"))?;
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0x00..=0x7f => Some(1),
        0xc0..=0xdf => Some(2),
        0xe0..=0xef => Some(3),
        0xf0..=0xf7 => Some(4),
        _ => None,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_of("c").unwrap(), "x");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ✓");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "[1] x",
                    "\"\\ud800\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-7,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn integer_accessors() {
        let v = Json::parse("{\"a\": 7, \"b\": 7.5, \"c\": -1}").unwrap();
        assert_eq!(v.u64_of("a").unwrap(), 7);
        assert!(v.req("b").unwrap().as_i64().is_none());
        assert!(v.u64_of("c").is_err());
        assert_eq!(v.req("c").unwrap().as_i64().unwrap(), -1);
    }

    #[test]
    fn builder() {
        let v = Json::obj()
            .set("name", "x")
            .set("n", 3u64)
            .set("ok", true)
            .set("tags", Json::Arr(vec!["a".into(), "b".into()]));
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.str_of("name").unwrap(), "x");
        assert_eq!(parsed.u64_of("n").unwrap(), 3);
        assert_eq!(parsed.arr_of("tags").unwrap().len(), 2);
    }

    #[test]
    fn missing_and_type_errors_carry_key() {
        let v = Json::parse("{\"a\": 1}").unwrap();
        assert!(matches!(v.str_of("a"), Err(JsonError::Type { .. })));
        assert!(matches!(v.str_of("zz"), Err(JsonError::Missing(_))));
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn big_manifest_like_doc() {
        // shape of the real artifacts/manifest.json
        let doc = r#"{
          "model": "tiny-yolo-v2-repro",
          "weights": [{"name": "[conv][0][b]", "shape": [16], "offset": 0, "len": 64}],
          "artifacts": [{"name": "tinyyolo-gpu", "input_shape": [1,64,64,3],
                         "tags": ["gpu", "cuda-onnx"]}]
        }"#;
        let v = Json::parse(doc).unwrap();
        let w = &v.arr_of("weights").unwrap()[0];
        assert_eq!(w.u64_of("len").unwrap(), 64);
        let a = &v.arr_of("artifacts").unwrap()[0];
        assert_eq!(a.arr_of("tags").unwrap()[0].as_str().unwrap(), "gpu");
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
