//! Accelerator profiles: kind, capacity, service-time model, cold-start
//! cost, and the runtime→variant mapping.

use crate::json::{Json, JsonError};
use crate::util::Rng;
use std::collections::BTreeMap;

/// Accelerator class.  The paper's thesis is that the platform should
/// absorb *arbitrary* kinds — hence the open `Custom` arm.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AcceleratorKind {
    Gpu,
    Vpu,
    Tpu,
    Fpga,
    Cpu,
    Custom(String),
}

impl AcceleratorKind {
    pub fn as_str(&self) -> &str {
        match self {
            AcceleratorKind::Gpu => "gpu",
            AcceleratorKind::Vpu => "vpu",
            AcceleratorKind::Tpu => "tpu",
            AcceleratorKind::Fpga => "fpga",
            AcceleratorKind::Cpu => "cpu",
            AcceleratorKind::Custom(s) => s,
        }
    }

    pub fn parse(s: &str) -> AcceleratorKind {
        match s {
            "gpu" => AcceleratorKind::Gpu,
            "vpu" => AcceleratorKind::Vpu,
            "tpu" => AcceleratorKind::Tpu,
            "fpga" => AcceleratorKind::Fpga,
            "cpu" => AcceleratorKind::Cpu,
            other => AcceleratorKind::Custom(other.to_string()),
        }
    }
}

/// Lognormal service-time model: `median_ms` with multiplicative jitter
/// `sigma`.  Lognormal matches the right-skewed ELat distributions of
/// inference serving (and never goes negative).
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceTimeModel {
    pub median_ms: f64,
    pub sigma: f64,
}

impl ServiceTimeModel {
    pub fn new(median_ms: f64, sigma: f64) -> ServiceTimeModel {
        ServiceTimeModel { median_ms, sigma }
    }

    /// Sample one service time (ms, sim time).
    pub fn sample_ms(&self, rng: &mut Rng) -> f64 {
        rng.lognormal(self.median_ms, self.sigma)
    }
}

/// Static description of one accelerator device.
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorProfile {
    /// Marketing name (diagnostics only), e.g. `quadro-k600`.
    pub name: String,
    pub kind: AcceleratorKind,
    /// Parallel runtime instances the device sustains (paper: 2 per GPU,
    /// 1 on the compute stick).
    pub slots: usize,
    /// Per-invocation execution-time pacing (calibrated to §V-B medians).
    pub service: ServiceTimeModel,
    /// Cold-start cost of spinning up a runtime instance on this device
    /// (driver/session init + model load), in sim-ms.
    pub cold_start_ms: f64,
    /// Logical runtime → artifact variant implemented for this device
    /// kind, e.g. `tinyyolo → tinyyolo-gpu`.  This is the paper's
    /// "different runtime instances of a runtime ... for different types
    /// of hardware accelerators" (§IV-D).
    pub runtimes: BTreeMap<String, String>,
}

impl AcceleratorProfile {
    /// NVIDIA Quadro K600 profile, calibrated to the paper: median ELat
    /// 1675 ms, 2 parallel runtime instances.  Cold start ≈ 2.5 s (CUDA
    /// context + ONNX session creation on 2012-era hardware).
    pub fn quadro_k600() -> AcceleratorProfile {
        AcceleratorProfile {
            name: "quadro-k600".into(),
            kind: AcceleratorKind::Gpu,
            slots: 2,
            service: ServiceTimeModel::new(1675.0, 0.05),
            cold_start_ms: 2500.0,
            runtimes: BTreeMap::from([("tinyyolo".to_string(), "tinyyolo-gpu".to_string())]),
        }
    }

    /// Intel Movidius Neural Compute Stick profile: median ELat 1577 ms,
    /// single instance, slower cold start (USB firmware + graph upload).
    pub fn movidius_ncs() -> AcceleratorProfile {
        AcceleratorProfile {
            name: "movidius-ncs".into(),
            kind: AcceleratorKind::Vpu,
            slots: 1,
            service: ServiceTimeModel::new(1577.0, 0.05),
            cold_start_ms: 4000.0,
            runtimes: BTreeMap::from([("tinyyolo".to_string(), "tinyyolo-vpu".to_string())]),
        }
    }

    /// K600 profile serving BOTH runtime stacks (detector + classifier) —
    /// the paper's prototype ships two runtimes (ONNX and PyTorch) and a
    /// node "needs to be configured correctly to support all available
    /// runtimes for this accelerator" (§IV-D).
    pub fn quadro_k600_multi() -> AcceleratorProfile {
        let mut p = Self::quadro_k600();
        p.runtimes
            .insert("tinycls".to_string(), "tinycls-gpu".to_string());
        p
    }

    /// NCS profile serving both runtime stacks.
    pub fn movidius_ncs_multi() -> AcceleratorProfile {
        let mut p = Self::movidius_ncs();
        p.runtimes
            .insert("tinycls".to_string(), "tinycls-vpu".to_string());
        p
    }

    /// Variant artifact implementing `runtime` on this device, if any.
    pub fn variant_for(&self, runtime: &str) -> Option<&str> {
        self.runtimes.get(runtime).map(|s| s.as_str())
    }

    pub fn supports(&self, runtime: &str) -> bool {
        self.runtimes.contains_key(runtime)
    }

    pub fn to_json(&self) -> Json {
        let mut runtimes = Json::obj();
        for (k, v) in &self.runtimes {
            runtimes = runtimes.set(k, v.as_str());
        }
        Json::obj()
            .set("name", self.name.as_str())
            .set("kind", self.kind.as_str())
            .set("slots", self.slots)
            .set("service_median_ms", self.service.median_ms)
            .set("service_sigma", self.service.sigma)
            .set("cold_start_ms", self.cold_start_ms)
            .set("runtimes", runtimes)
    }

    pub fn from_json(j: &Json) -> Result<AcceleratorProfile, JsonError> {
        let mut runtimes = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("runtimes") {
            for (k, v) in m {
                if let Some(s) = v.as_str() {
                    runtimes.insert(k.clone(), s.to_string());
                }
            }
        }
        Ok(AcceleratorProfile {
            name: j.str_of("name")?.to_string(),
            kind: AcceleratorKind::parse(j.str_of("kind")?),
            slots: j.usize_of("slots")?,
            service: ServiceTimeModel::new(
                j.f64_of("service_median_ms")?,
                j.f64_of("service_sigma")?,
            ),
            cold_start_ms: j.f64_of("cold_start_ms")?,
            runtimes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in ["gpu", "vpu", "tpu", "fpga", "cpu", "npu-x9"] {
            assert_eq!(AcceleratorKind::parse(k).as_str(), k);
        }
    }

    #[test]
    fn paper_profiles_match_calibration() {
        let gpu = AcceleratorProfile::quadro_k600();
        assert_eq!(gpu.slots, 2);
        assert_eq!(gpu.service.median_ms, 1675.0);
        assert_eq!(gpu.variant_for("tinyyolo"), Some("tinyyolo-gpu"));
        let vpu = AcceleratorProfile::movidius_ncs();
        assert_eq!(vpu.slots, 1);
        assert_eq!(vpu.service.median_ms, 1577.0);
        assert_eq!(vpu.variant_for("tinyyolo"), Some("tinyyolo-vpu"));
        assert!(!vpu.supports("resnet"));
    }

    #[test]
    fn service_model_sample_distribution() {
        let m = ServiceTimeModel::new(1000.0, 0.05);
        let mut rng = Rng::new(42);
        let mut xs: Vec<f64> = (0..4001).map(|_| m.sample_ms(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[2000];
        assert!((median - 1000.0).abs() < 20.0, "median {median}");
        assert!(xs.iter().all(|&x| x > 0.0));
        // jitter is small but present
        assert!(xs[4000] > xs[0]);
    }

    #[test]
    fn profile_json_roundtrip() {
        let p = AcceleratorProfile::movidius_ncs();
        let back = AcceleratorProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn custom_kind_roundtrips_through_json() {
        let mut p = AcceleratorProfile::quadro_k600();
        p.kind = AcceleratorKind::Custom("inferentia".into());
        let back = AcceleratorProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back.kind, AcceleratorKind::Custom("inferentia".into()));
    }
}
