//! Accelerator abstraction — virtual devices standing in for the paper's
//! physical testbed (DESIGN.md substitution S1).
//!
//! Paper §IV-D: *"Every node manager has a list of all accelerators
//! available to it in which it stores the type of the accelerator, a
//! locally unique ID for it, and information necessary to schedule and
//! balance the available resources."*  That list is [`DeviceRegistry`];
//! the per-device scheduling information is [`AcceleratorProfile`] (slot
//! count, service-time model, cold-start cost) plus live slot occupancy.
//!
//! A **virtual** device still runs the real AOT-compiled HLO through PJRT
//! (numerics are real); the profile *paces* completion to the calibrated
//! service time so the coordination plane observes the same rates the
//! paper's hardware produced: Quadro K600 ≈ 1675 ms median ELat with 2
//! runtime slots per card, Movidius NCS ≈ 1577 ms with 1 slot (§V-B).

pub mod device;
pub mod profile;

pub use device::{Device, DeviceRegistry, SlotGuard};
pub use profile::{AcceleratorKind, AcceleratorProfile, ServiceTimeModel};

use std::sync::Arc;

/// The paper's dual-GPU setup: 2× Quadro K600, two runtime slots each
/// (§V-A: "the test environment can run two parallel instances per GPU").
pub fn paper_dualgpu() -> DeviceRegistry {
    DeviceRegistry::new(vec![
        Device::new("gpu0", AcceleratorProfile::quadro_k600()),
        Device::new("gpu1", AcceleratorProfile::quadro_k600()),
    ])
}

/// The paper's full setup: both GPUs plus the Movidius Neural Compute
/// Stick ("plus one on the Compute Stick").
pub fn paper_all_accel() -> DeviceRegistry {
    DeviceRegistry::new(vec![
        Device::new("gpu0", AcceleratorProfile::quadro_k600()),
        Device::new("gpu1", AcceleratorProfile::quadro_k600()),
        Device::new("vpu0", AcceleratorProfile::movidius_ncs()),
    ])
}

/// The full setup with every device serving BOTH runtime stacks
/// (detector + classifier) — the paper's multi-runtime generality.
pub fn paper_all_multi() -> DeviceRegistry {
    DeviceRegistry::new(vec![
        Device::new("gpu0", AcceleratorProfile::quadro_k600_multi()),
        Device::new("gpu1", AcceleratorProfile::quadro_k600_multi()),
        Device::new("vpu0", AcceleratorProfile::movidius_ncs_multi()),
    ])
}

/// Registry from a config-described device list.
pub fn from_profiles(profiles: Vec<(String, AcceleratorProfile)>) -> DeviceRegistry {
    DeviceRegistry::new(
        profiles
            .into_iter()
            .map(|(id, p)| Device::new(id, p))
            .collect::<Vec<Arc<Device>>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_setups_have_expected_capacity() {
        assert_eq!(paper_dualgpu().total_slots(), 4);
        assert_eq!(paper_all_accel().total_slots(), 5);
    }

    #[test]
    fn paper_setups_support_tinyyolo() {
        for reg in [paper_dualgpu(), paper_all_accel()] {
            assert!(reg.supported_runtimes().contains(&"tinyyolo".to_string()));
        }
    }
}
