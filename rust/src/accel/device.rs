//! Live accelerator devices: slot occupancy and the node-local registry.

use super::profile::AcceleratorProfile;
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex};

/// One physical (here: virtual) accelerator with live slot tracking.
pub struct Device {
    /// Locally unique id, e.g. `gpu0` (paper §IV-D).
    pub id: String,
    pub profile: AcceleratorProfile,
    busy: Mutex<usize>,
}

impl Device {
    pub fn new(id: impl Into<String>, profile: AcceleratorProfile) -> Arc<Device> {
        Arc::new(Device { id: id.into(), profile, busy: Mutex::new(0) })
    }

    /// Try to occupy one runtime slot; `None` when saturated.  The guard
    /// frees the slot on drop, so a panicking worker thread cannot leak
    /// device capacity.
    pub fn try_acquire(self: &Arc<Device>) -> Option<SlotGuard> {
        let mut busy = self.busy.lock().expect("device poisoned");
        if *busy < self.profile.slots {
            *busy += 1;
            Some(SlotGuard { device: self.clone() })
        } else {
            None
        }
    }

    pub fn busy_slots(&self) -> usize {
        *self.busy.lock().expect("device poisoned")
    }

    pub fn free_slots(&self) -> usize {
        self.profile.slots - self.busy_slots()
    }

    pub fn supports(&self, runtime: &str) -> bool {
        self.profile.supports(runtime)
    }
}

/// RAII slot occupancy.
pub struct SlotGuard {
    device: Arc<Device>,
}

impl SlotGuard {
    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }
}

impl Drop for SlotGuard {
    fn drop(&mut self) {
        let mut busy = self.device.busy.lock().expect("device poisoned");
        *busy = busy.saturating_sub(1);
    }
}

/// The node manager's device list (paper §IV-D).
#[derive(Clone)]
pub struct DeviceRegistry {
    devices: Vec<Arc<Device>>,
}

impl DeviceRegistry {
    pub fn new(devices: Vec<Arc<Device>>) -> DeviceRegistry {
        let mut ids = BTreeSet::new();
        for d in &devices {
            assert!(ids.insert(d.id.clone()), "duplicate device id {}", d.id);
        }
        DeviceRegistry { devices }
    }

    pub fn devices(&self) -> &[Arc<Device>] {
        &self.devices
    }

    pub fn get(&self, id: &str) -> Option<&Arc<Device>> {
        self.devices.iter().find(|d| d.id == id)
    }

    pub fn total_slots(&self) -> usize {
        self.devices.iter().map(|d| d.profile.slots).sum()
    }

    pub fn free_slots(&self) -> usize {
        self.devices.iter().map(|d| d.free_slots()).sum()
    }

    /// Union of logical runtimes any local accelerator implements —
    /// exactly the `runtimes` field of the node's [`TakeFilter`].
    pub fn supported_runtimes(&self) -> Vec<String> {
        let mut set = BTreeSet::new();
        for d in &self.devices {
            for r in d.profile.runtimes.keys() {
                set.insert(r.clone());
            }
        }
        set.into_iter().collect()
    }

    /// Devices that implement `runtime` and currently have a free slot,
    /// most-free-first (simple load balancing across equal accelerators).
    /// "If a runtime is supported by multiple available accelerators, then
    /// the node is free to choose which accelerator to use" (§IV-C) — our
    /// choice is the least-loaded supporting device.
    pub fn candidates(&self, runtime: &str) -> Vec<Arc<Device>> {
        let mut out: Vec<Arc<Device>> = self
            .devices
            .iter()
            .filter(|d| d.supports(runtime) && d.free_slots() > 0)
            .cloned()
            .collect();
        out.sort_by_key(|d| std::cmp::Reverse(d.free_slots()));
        out
    }

    /// Acquire a slot on the best candidate for `runtime`.
    pub fn acquire_for(&self, runtime: &str) -> Option<SlotGuard> {
        for d in self.candidates(runtime) {
            if let Some(guard) = d.try_acquire() {
                return Some(guard);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::profile::AcceleratorProfile;

    fn registry() -> DeviceRegistry {
        DeviceRegistry::new(vec![
            Device::new("gpu0", AcceleratorProfile::quadro_k600()),
            Device::new("gpu1", AcceleratorProfile::quadro_k600()),
            Device::new("vpu0", AcceleratorProfile::movidius_ncs()),
        ])
    }

    #[test]
    fn slot_acquire_release() {
        let d = Device::new("gpu0", AcceleratorProfile::quadro_k600());
        assert_eq!(d.free_slots(), 2);
        let g1 = d.try_acquire().unwrap();
        let g2 = d.try_acquire().unwrap();
        assert!(d.try_acquire().is_none(), "saturated at profile.slots");
        drop(g1);
        assert_eq!(d.free_slots(), 1);
        drop(g2);
        assert_eq!(d.free_slots(), 2);
    }

    #[test]
    fn guard_releases_on_panic() {
        let d = Device::new("gpu0", AcceleratorProfile::quadro_k600());
        let d2 = d.clone();
        let _ = std::thread::spawn(move || {
            let _g = d2.try_acquire().unwrap();
            panic!("worker died");
        })
        .join();
        assert_eq!(d.free_slots(), 2, "slot recovered after worker panic");
    }

    #[test]
    fn registry_capacity_and_support() {
        let r = registry();
        assert_eq!(r.total_slots(), 5);
        assert_eq!(r.free_slots(), 5);
        assert_eq!(r.supported_runtimes(), vec!["tinyyolo".to_string()]);
        assert!(r.get("vpu0").is_some());
        assert!(r.get("nope").is_none());
    }

    #[test]
    fn candidates_prefer_least_loaded() {
        let r = registry();
        let _g = r.get("gpu0").unwrap().try_acquire().unwrap();
        let cands = r.candidates("tinyyolo");
        // gpu1 (2 free) should sort before gpu0 (1 free); vpu0 has 1 free
        assert_eq!(cands[0].id, "gpu1");
    }

    #[test]
    fn acquire_for_saturates_then_fails() {
        let r = registry();
        let mut guards = Vec::new();
        for _ in 0..5 {
            guards.push(r.acquire_for("tinyyolo").expect("capacity left"));
        }
        assert!(r.acquire_for("tinyyolo").is_none(), "all 5 slots busy");
        guards.pop();
        assert!(r.acquire_for("tinyyolo").is_some());
    }

    #[test]
    fn unknown_runtime_has_no_candidates() {
        let r = registry();
        assert!(r.candidates("resnet").is_empty());
        assert!(r.acquire_for("resnet").is_none());
    }

    #[test]
    #[should_panic(expected = "duplicate device id")]
    fn duplicate_ids_rejected() {
        DeviceRegistry::new(vec![
            Device::new("x", AcceleratorProfile::quadro_k600()),
            Device::new("x", AcceleratorProfile::movidius_ncs()),
        ]);
    }

    #[test]
    fn property_slot_accounting_under_concurrency() {
        use crate::prop;
        prop::check(
            "slots-never-oversubscribed",
            20,
            |rng| rng.range(1, 6) as usize,
            |&threads| {
                let d = Device::new("g", AcceleratorProfile::quadro_k600());
                let mut handles = Vec::new();
                for _ in 0..threads {
                    let d = d.clone();
                    handles.push(std::thread::spawn(move || {
                        let mut ok = true;
                        for _ in 0..50 {
                            if let Some(g) = d.try_acquire() {
                                ok &= g.device().busy_slots() <= 2;
                                drop(g);
                            }
                        }
                        ok
                    }));
                }
                let all_ok = handles.into_iter().all(|h| h.join().unwrap());
                all_ok && d.busy_slots() == 0
            },
        );
    }
}
