//! The node manager — paper §IV-D.
//!
//! *"The node manager is responsible for managing all aspects of a single
//! worker node ... It starts, stops, and distributes invocations to
//! runtime instances and assigns accelerators to them. To perform these
//! operations, the node manager interfaces with the invocation queue to
//! get invocations and object storage to fetch data."*
//!
//! One manager thread polls the shared queue with the policy-built
//! [`TakeFilter`]; work is taken in **variant-grouped micro-batch
//! chunks** (`take_batch_grouped`) sized to keep every accelerator slot
//! busy, each chunk handed to one worker thread.  Workers drive a (warm
//! or cold-started) [`RuntimeInstance`], execute the whole chunk in one
//! device dispatch (`exec_batch`), pace to the device's calibrated
//! service time, persist the decoded results, `ack_batch` the queue,
//! signal completions — and then issue the paper's *same-configuration
//! re-take* (batched, with an adaptive linger window) so a warm instance
//! drains matching work without returning to the scheduler.

pub mod batch;
pub mod reserve;
pub mod worker;

pub use batch::{BatchAggregator, BatchConfig, VariantBatchStats};
pub use reserve::InstanceReserve;

use crate::accel::DeviceRegistry;
use crate::events::Invocation;
use crate::queue::{InvocationQueue, Lease, TakeFilter};
use crate::runtime::InstancePool;
use crate::scheduler::{Admission, BatchAware, Policy};
use crate::store::{CacheStats, CachedStore, DecodedCache, ObjectStore};
use crate::util::Clock;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Data-locality scoreboard (DESIGN.md §15): at fetch time, was the
/// invocation's dataset already resident in the node-local cache?  A hit
/// means the work ran where its data lives; a miss on an
/// affinity-steered take means the hint went stale and the fetch fell
/// back to the backing store — never an error.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct AffinityStats {
    pub hits: u64,
    pub misses: u64,
}

impl AffinityStats {
    pub fn absorb(&mut self, other: &AffinityStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }
}

/// Shared atomics behind [`AffinityStats`]: workers bump at dataset-fetch
/// time, the handle (and cluster aggregation) reads.
#[derive(Default)]
pub struct AffinityCounters {
    hits: AtomicU64,
    misses: AtomicU64,
}

impl AffinityCounters {
    pub fn record(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> AffinityStats {
        AffinityStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// Where a node reports terminal invocations (paper §IV-C: nodes signal
/// completion back to the event generator).  Single-process deployments
/// use an mpsc channel straight into the coordinator's collector;
/// distributed nodes report to the gateway over TCP
/// ([`crate::api::RemoteReporter`]).  The node manager is agnostic.
pub trait CompletionSink: Send + Sync {
    /// Deliver one terminal invocation.  Errors are the sink's problem to
    /// describe; the node logs and keeps serving either way.
    fn report(&self, inv: Invocation) -> Result<()>;
}

/// The in-process sink: a channel into the coordinator (or a test rig).
impl CompletionSink for mpsc::Sender<Invocation> {
    fn report(&self, inv: Invocation) -> Result<()> {
        self.send(inv)
            .map_err(|_| anyhow::anyhow!("completion receiver dropped"))
    }
}

/// Fan a completion out to several sinks (e.g. gateway RPC + local log).
/// Every sink sees every invocation; the first error is returned after
/// all sinks have been tried.
pub struct TeeSink(pub Vec<Arc<dyn CompletionSink>>);

impl CompletionSink for TeeSink {
    fn report(&self, inv: Invocation) -> Result<()> {
        let mut first_err = None;
        for sink in &self.0 {
            if let Err(e) = sink.report(inv.clone()) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

/// Node configuration.
#[derive(Clone)]
pub struct NodeConfig {
    pub id: String,
    /// Sim-time pause between empty queue polls.
    pub poll_interval: Duration,
    /// Max live runtime instances on this node (warm pool capacity).
    pub pool_capacity: usize,
    /// Bytes budget for the node-local store cache (raw objects) and the
    /// decoded-input cache (each gets this budget).  0 disables both and
    /// every `get` goes to the backing store.
    pub cache_bytes: usize,
    /// Micro-batching knobs (device batch cap + adaptive linger ceiling).
    /// `max_batch: 1` restores serial per-invocation execution.
    pub batch: BatchConfig,
}

impl NodeConfig {
    pub fn new(id: impl Into<String>) -> NodeConfig {
        NodeConfig {
            id: id.into(),
            poll_interval: Duration::from_millis(50),
            pool_capacity: 8,
            cache_bytes: 256 * 1024 * 1024,
            batch: BatchConfig::default(),
        }
    }
}

/// Everything a node needs to operate (shared services).
pub struct NodeDeps {
    pub queue: Arc<dyn InvocationQueue>,
    pub store: Arc<dyn ObjectStore>,
    pub clock: Arc<dyn Clock>,
    pub policy: Arc<dyn Policy>,
    pub reserve: Arc<InstanceReserve>,
    /// Completion signal back to the event generator (paper §IV-C).
    pub completions: Arc<dyn CompletionSink>,
}

/// Handle to a running node manager.
pub struct NodeHandle {
    pub id: String,
    stop: Arc<AtomicBool>,
    /// Decommission flag: set, the manager (and its workers' warm
    /// re-take path) stops taking new leases while in-flight work drains.
    draining: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    pool: Arc<InstancePool>,
    registry: DeviceRegistry,
    /// The node-local store cache (None when `cache_bytes` was 0).
    cache: Option<Arc<CachedStore>>,
    decoded: Arc<DecodedCache>,
    batcher: Arc<BatchAggregator>,
    affinity: Arc<AffinityCounters>,
}

impl NodeHandle {
    /// Signal the manager loop to stop and join it (drains in-flight
    /// workers).  Nodes can leave at any time — queued work stays in the
    /// shared queue untouched (dynamic membership, §IV-C).
    pub fn stop(mut self) {
        self.stop_inner();
    }

    /// Begin graceful scale-in: the node stops taking new leases (both
    /// the manager poll and the workers' same-config re-take) but keeps
    /// serving whatever it already leased.  Call [`stop`](Self::stop) —
    /// or [`retire`](Self::retire) — afterwards to drain and join.
    pub fn decommission(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Graceful scale-in, end to end: decommission, drain, stop, and
    /// hand back the node's terminal cache/pool/batch counters so the
    /// cluster can fold them into its totals (counters must survive
    /// scale-in — `cluster_stats` never goes backwards).  The returned
    /// pool gauges (`live`/`busy`) are zeroed: those instances die with
    /// the node.
    pub fn retire(
        mut self,
    ) -> (
        CacheStats,
        crate::runtime::pool::PoolStats,
        Vec<VariantBatchStats>,
        AffinityStats,
    ) {
        self.decommission();
        self.stop_inner();
        let cache = self.cache_stats();
        let mut pool = self.pool.stats();
        pool.live = 0;
        pool.busy = 0;
        let batch = self.batch_stats();
        let affinity = self.affinity_stats();
        (cache, pool, batch, affinity)
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    pub fn pool_stats(&self) -> crate::runtime::pool::PoolStats {
        self.pool.stats()
    }

    /// Counters of the node-local store cache (zeros when disabled).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Counters of the node's decoded-input (bytes→f32) cache.
    pub fn decoded_stats(&self) -> CacheStats {
        self.decoded.stats()
    }

    /// Data-locality counters: dataset fetches already resident in the
    /// node-local cache (hits) vs served by the backing store (misses).
    pub fn affinity_stats(&self) -> AffinityStats {
        self.affinity.snapshot()
    }

    /// Per-variant micro-batch counters (dispatches, mean size, linger
    /// hits, size distribution) — the `cluster_stats.batch` section.
    pub fn batch_stats(&self) -> Vec<VariantBatchStats> {
        self.batcher.stats()
    }

    pub fn free_slots(&self) -> usize {
        self.registry.free_slots()
    }

    /// Logical runtimes this node can serve (union over its devices).
    pub fn supported_runtimes(&self) -> Vec<String> {
        self.registry.supported_runtimes()
    }
}

impl Drop for NodeHandle {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Start a node manager over `registry`.  When `cfg.cache_bytes` > 0 the
/// node's store view is wrapped in a node-local [`CachedStore`]
/// (read-through LRU + single-flight), and workers share a
/// [`DecodedCache`] so each dataset is decoded to f32 once per node.
/// When `cfg.batch.max_batch` > 1 the policy is wrapped in
/// [`BatchAware`] (deep-lane grouped takes) and workers execute
/// micro-batches through a shared [`BatchAggregator`].
pub fn spawn_node(cfg: NodeConfig, registry: DeviceRegistry, mut deps: NodeDeps) -> Result<NodeHandle> {
    let stop = Arc::new(AtomicBool::new(false));
    let draining = Arc::new(AtomicBool::new(false));
    let pool = InstancePool::new(cfg.pool_capacity);
    let cache = if cfg.cache_bytes > 0 {
        let c = Arc::new(CachedStore::new(deps.store.clone(), cfg.cache_bytes));
        deps.store = c.clone() as Arc<dyn ObjectStore>;
        Some(c)
    } else {
        None
    };
    // Bind cache-aware policies to *this node's* cache: the cluster
    // shares one policy Arc across every node it spawns, but an affinity
    // policy must advertise the taking node's own hot-set.
    if let Some(c) = &cache {
        if let Some(bound) = deps.policy.bind_cache(c) {
            deps.policy = bound;
        }
    }
    let decoded = Arc::new(DecodedCache::new(cfg.cache_bytes));
    let batcher = BatchAggregator::new(cfg.batch.clone());
    if cfg.batch.max_batch > 1 {
        deps.policy = Arc::new(BatchAware { inner: deps.policy });
    }
    let affinity = Arc::new(AffinityCounters::default());
    let gossiped = Arc::new(AtomicU64::new(0));
    let handle_pool = pool.clone();
    let handle_registry = registry.clone();
    let handle_cache = cache.clone();
    let handle_decoded = decoded.clone();
    let handle_batcher = batcher.clone();
    let handle_affinity = affinity.clone();
    let stop2 = stop.clone();
    let draining2 = draining.clone();
    let id = cfg.id.clone();
    let thread = std::thread::Builder::new()
        .name(format!("node-mgr-{}", cfg.id))
        .spawn(move || {
            manager_loop(
                cfg, registry, pool, deps, cache, decoded, batcher, affinity, gossiped,
                stop2, draining2,
            )
        })?;
    Ok(NodeHandle {
        id,
        stop,
        draining,
        thread: Some(thread),
        pool: handle_pool,
        registry: handle_registry,
        cache: handle_cache,
        decoded: handle_decoded,
        batcher: handle_batcher,
        affinity: handle_affinity,
    })
}

/// Chunk size for this dispatch round: deep backlogs fill batches up to
/// the cap, shallow ones spread across the given parallelism so devices
/// (local and on peer nodes sharing the queue) stay busy rather than a
/// few lopsided batches hoarding the backlog.  `parallelism` is the
/// caller's slot budget for this round (the manager passes twice its
/// free slots to leave headroom for peers).
fn chunk_cap(matching_depth: usize, parallelism: usize, max_batch: usize) -> usize {
    matching_depth
        .div_ceil(parallelism.max(1))
        .clamp(1, max_batch.max(1))
}

/// Re-send the node's hot-set summary on an idle poll tick when the
/// cache generation advanced past the last gossiped one.  The report is
/// a *gossip-only* invocation — empty id, hot fields populated — riding
/// the existing [`CompletionSink`]; the coordinator folds the summary
/// into its affinity table and then drops the report (no metrics, no
/// tracking).  `gossiped` only advances on successful delivery, so a
/// failed send retries on the next idle tick.
fn idle_gossip(
    node_id: &str,
    cache: Option<&CachedStore>,
    gossiped: &AtomicU64,
    now: crate::util::SimTime,
    completions: &dyn CompletionSink,
) {
    let Some(cache) = cache else { return };
    if cache.generation() <= gossiped.load(Ordering::Relaxed) {
        return;
    }
    let (keys, generation) = cache.hot_keys(crate::scheduler::DEFAULT_HOT_SET);
    if generation == 0 {
        return;
    }
    let mut inv = Invocation::new("", crate::events::EventSpec::new("", ""), now);
    inv.node = Some(node_id.to_string());
    inv.hot_keys = keys;
    inv.hot_generation = generation;
    if completions.report(inv).is_ok() {
        gossiped.fetch_max(generation, Ordering::Relaxed);
    }
}

#[allow(clippy::too_many_arguments)]
fn manager_loop(
    cfg: NodeConfig,
    registry: DeviceRegistry,
    pool: Arc<InstancePool>,
    deps: NodeDeps,
    cache: Option<Arc<CachedStore>>,
    decoded: Arc<DecodedCache>,
    batcher: Arc<BatchAggregator>,
    affinity: Arc<AffinityCounters>,
    gossiped: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
) {
    let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    // Chunk ceiling: `max_batch`, clamped by the *most permissive*
    // device's lease-safe dispatch cap — one slow accelerator must not
    // serialise unrelated fast lanes node-wide.  A chunk that lands on a
    // slower device is trimmed by the worker (its own device-cap check
    // releases the excess), and that churn is bounded: dispatch rounds
    // are service-time paced, not spinning.
    let max_batch = registry
        .devices()
        .iter()
        .map(|d| batcher.dispatch_cap(d.profile.service.median_ms))
        .max()
        .unwrap_or(1);
    // Chunk-deepening gate: the per-round depth probe (a stats RPC on
    // remote queues) is only paid after a round that filled every free
    // slot — shallow traffic keeps PR 2's one-round-trip dispatch cost,
    // and a burst pays one slots-wide serial round before batching kicks
    // in (the workers' batched warm re-take absorbs most of it anyway).
    let mut last_round_saturated = false;
    while !stop.load(Ordering::SeqCst) {
        workers.retain(|w| !w.is_finished());

        // Decommissioned: in-flight workers run to completion, but no
        // new lease is taken (graceful scale-in, the autoscaler's
        // remove path).
        if draining.load(Ordering::SeqCst) {
            deps.clock.sleep(cfg.poll_interval);
            continue;
        }

        // Backpressure: never take work we have no slot for.
        if registry.free_slots() == 0 {
            deps.clock.sleep(cfg.poll_interval);
            continue;
        }

        let filter = deps.policy.filter(&registry, &pool);
        // Blocking take: the wall-clock wait equals the sim poll interval
        // under the experiment's time scale; work arriving mid-wait wakes
        // the manager immediately — condvar in-process, server-side
        // long-poll over TCP.
        let wall_wait = Duration::from_secs_f64(
            cfg.poll_interval.as_secs_f64() / deps.clock.scale(),
        );
        let first = match deps.queue.take_timeout(&filter, wall_wait) {
            Ok(Some(l)) => l,
            Ok(None) => {
                // Idle tick: no completion will carry the hot-set
                // summary, so if the cache changed since the last
                // piggyback (evictions, prefetches), re-gossip it
                // through the completion path — the coordinator's
                // affinity table must not steer by a stale set just
                // because a node went quiet (DESIGN.md §15).
                idle_gossip(
                    &cfg.id,
                    cache.as_deref(),
                    &gossiped,
                    deps.clock.now(),
                    deps.completions.as_ref(),
                );
                continue;
            }
            Err(e) => {
                log::warn!("node {}: queue take failed: {e:#}", cfg.id);
                deps.clock.sleep(cfg.poll_interval);
                continue;
            }
        };

        // Size this round's chunks from the still-queued matching depth
        // (one O(|classes|) stats probe) so batches deepen exactly when
        // backlog exceeds slot parallelism.  The divisor doubles this
        // node's free slots: the queue is shared, so peer nodes must be
        // able to take their share of a deep backlog — under-batching
        // costs us one immediate extra manager round (or a warm
        // re-take), over-batching starves peers for a whole service
        // time.
        let free = registry.free_slots();
        let cap = if max_batch > 1 && last_round_saturated {
            let depth: usize = match deps.queue.stats() {
                Ok(s) => s
                    .classes
                    .iter()
                    .filter(|c| {
                        filter.accepts_cold(&c.runtime) || filter.accepts_warm(&c.runtime)
                    })
                    .map(|c| c.queued)
                    .sum(),
                Err(_) => 0,
            };
            chunk_cap(depth + 1, free * 2, max_batch)
        } else {
            1
        };

        // Gather same-runtime chunks.  With batching off (or a chunk cap
        // of 1) keep PR 2's path: fill every remaining free slot from a
        // single `take_batch` round trip, one lease per chunk.  With
        // batching on, deepen the first lease's class, then one
        // variant-grouped take per remaining free slot (each a single
        // RPC on remote queues).  Every chunk is one device dispatch
        // downstream.
        let mut chunks: Vec<Vec<Lease>>;
        if cap <= 1 {
            chunks = vec![vec![first]];
            let extra = free.saturating_sub(1);
            if extra > 0 {
                match deps.queue.take_batch(&filter, extra) {
                    Ok(more) => chunks.extend(more.into_iter().map(|l| vec![l])),
                    Err(e) => log::warn!("node {}: take_batch failed: {e:#}", cfg.id),
                }
            }
        } else {
            let rt0 = first.invocation.spec.runtime.clone();
            // Runtime-aware refinement: chunk0's class is known, so size
            // it under its slowest candidate device's lease-safe cap —
            // no worker-side trim churn on the known-runtime path.
            let rt0_cap = registry
                .candidates(&rt0)
                .iter()
                .map(|d| batcher.dispatch_cap(d.profile.service.median_ms))
                .min()
                .unwrap_or(1);
            let cap0 = cap.min(rt0_cap);
            let mut chunk0 = vec![first];
            if cap0 > 1 {
                let class = TakeFilter::same_class(&rt0, filter.accepts_warm(&rt0));
                match deps.queue.take_batch(&class, cap0 - 1) {
                    Ok(more) => chunk0.extend(more),
                    Err(e) => log::warn!("node {}: take_batch failed: {e:#}", cfg.id),
                }
            }
            chunks = vec![chunk0];
            while chunks.len() < free {
                match deps.queue.take_batch_grouped(&filter, cap) {
                    Ok(group) if !group.is_empty() => chunks.push(group),
                    Ok(_) => break,
                    Err(e) => {
                        log::warn!("node {}: take_batch_grouped failed: {e:#}", cfg.id);
                        break;
                    }
                }
            }
        }

        let taken: usize = chunks.iter().map(|c| c.len()).sum();
        last_round_saturated = taken >= free.max(1);

        // Leases that could not be placed, in lease order.  Once one
        // chunk fails to place, the rest are handed back too (the
        // optimistic free-slot count was stale) — released newest-first
        // below, so the front-requeue's descending seqs leave the oldest
        // lease frontmost and FIFO order survives the round trip.
        let mut unplaced: Vec<String> = Vec::new();
        for chunk in chunks {
            if !unplaced.is_empty() {
                unplaced.extend(chunk.into_iter().map(|l| l.invocation.id));
                continue;
            }
            let runtime = chunk[0].invocation.spec.runtime.clone();
            let warm_hint = chunk.iter().any(|l| l.warm_hit);

            // Admission (deadline policies reject without executing).
            // Rejections ack in one batched round trip.
            let mut batch: Vec<Invocation> = Vec::with_capacity(chunk.len());
            let mut rejected: Vec<Invocation> = Vec::new();
            for lease in chunk {
                let mut inv = lease.invocation;
                inv.node = Some(cfg.id.clone());
                inv.stamps.n_start = Some(deps.clock.now());
                if let Admission::Reject(reason) =
                    deps.policy.admit(&inv, deps.clock.now())
                {
                    inv.status = crate::events::Status::Failed(reason);
                    rejected.push(inv);
                    continue;
                }
                batch.push(inv);
            }
            worker::ack_and_report_rejected(
                deps.queue.as_ref(),
                deps.completions.as_ref(),
                &cfg.id,
                cache.as_deref(),
                &gossiped,
                rejected,
            );
            if batch.is_empty() {
                continue;
            }

            // Assign an accelerator (§IV-C: node chooses among supporting
            // devices; ours picks the least-loaded, preferring warm-capable).
            let Some(slot) = worker::pick_slot(&registry, &pool, &runtime, warm_hint)
            else {
                // Raced out of capacity: hand the events back untouched.
                unplaced.extend(batch.into_iter().map(|inv| inv.id));
                continue;
            };

            let ctx = worker::WorkerCtx {
                node_id: cfg.id.clone(),
                pool: pool.clone(),
                queue: deps.queue.clone(),
                store: deps.store.clone(),
                cache: cache.clone(),
                decoded: decoded.clone(),
                clock: deps.clock.clone(),
                policy: deps.policy.clone(),
                reserve: deps.reserve.clone(),
                completions: deps.completions.clone(),
                batcher: batcher.clone(),
                affinity: affinity.clone(),
                draining: draining.clone(),
                gossiped: gossiped.clone(),
            };
            let name = format!("worker-{}", batch[0].id);
            let worker = std::thread::Builder::new()
                .name(name)
                .spawn(move || worker::run_invocations(ctx, batch, slot))
                .expect("spawn worker");
            workers.push(worker);
        }
        if !unplaced.is_empty() {
            for id in unplaced.iter().rev() {
                let _ = deps.queue.release(id);
            }
            deps.clock.sleep(cfg.poll_interval);
        }
    }
    for w in workers {
        let _ = w.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{paper_all_accel, paper_dualgpu};
    use crate::events::{EventSpec, Status};
    use crate::queue::MemQueue;
    use crate::runtime::instance::MockExecutor;
    use crate::runtime::RuntimeInstance;
    use crate::scheduler::WarmFirst;
    use crate::store::{MemStore, ObjectStore};
    use crate::util::clock::ScaledClock;
    use crate::util::SimTime;

    /// Full in-process node test rig with mock executors (no PJRT).
    struct Rig {
        queue: Arc<MemQueue>,
        store: Arc<MemStore>,
        clock: Arc<ScaledClock>,
        completions: mpsc::Receiver<Invocation>,
        node: NodeHandle,
    }

    fn rig(registry: DeviceRegistry) -> Rig {
        rig_with_batch(registry, BatchConfig::default())
    }

    fn rig_with_batch(registry: DeviceRegistry, batch: BatchConfig) -> Rig {
        rig_full(registry, batch, Arc::new(WarmFirst))
    }

    fn rig_full(
        registry: DeviceRegistry,
        batch: BatchConfig,
        policy: Arc<dyn Policy>,
    ) -> Rig {
        rig_exec(registry, batch, policy, None)
    }

    /// `ladder: None` seeds legacy batch-1 mock executors; `Some(l)`
    /// seeds batched-HLO mocks whose compiled ladder is `l` (visible to
    /// the aggregator, one dispatch delay per planned device program).
    fn rig_exec(
        registry: DeviceRegistry,
        batch: BatchConfig,
        policy: Arc<dyn Policy>,
        ladder: Option<Vec<usize>>,
    ) -> Rig {
        // 100x compression: mock delays of sim-ms become wall-µs.
        let clock: Arc<ScaledClock> = ScaledClock::new(100.0);
        let queue = MemQueue::new(clock.clone());
        let store = Arc::new(MemStore::new());
        let reserve = InstanceReserve::new();
        // Mock instances for every (variant, device, slot).
        for d in registry.devices() {
            for variant in d.profile.runtimes.values() {
                for _ in 0..d.profile.slots {
                    let factory = match &ladder {
                        Some(l) => MockExecutor::factory_batched(
                            2.0,
                            Duration::from_millis(1),
                            l.clone(),
                        ),
                        None => MockExecutor::factory(2.0, Duration::from_millis(1)),
                    };
                    reserve.add(
                        RuntimeInstance::start(variant.clone(), d.id.clone(), factory)
                            .unwrap(),
                    );
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        let deps = NodeDeps {
            queue: queue.clone(),
            store: store.clone(),
            clock: clock.clone(),
            policy,
            reserve,
            completions: Arc::new(tx),
        };
        let mut cfg = NodeConfig::new("node-1");
        cfg.poll_interval = Duration::from_millis(20);
        cfg.batch = batch;
        let node = spawn_node(cfg, registry, deps).unwrap();
        Rig { queue, store, clock, completions: rx, node }
    }

    impl Rig {
        /// Next *completion* off the sink, skipping gossip-only reports
        /// (empty id): the coordinator drops those before tracking, so
        /// tests reading the raw channel must too.
        fn recv(&self, secs: u64) -> Invocation {
            recv_completion(&self.completions, Duration::from_secs(secs))
        }
    }

    fn recv_completion(
        rx: &mpsc::Receiver<Invocation>,
        timeout: Duration,
    ) -> Invocation {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            let inv = rx.recv_timeout(left).expect("completion");
            if !inv.id.is_empty() {
                return inv;
            }
        }
    }

    fn dataset(store: &MemStore, name: &str, values: &[f32]) -> String {
        let key = format!("datasets/{name}");
        let bytes: Vec<u8> = values.iter().flat_map(|f| f.to_le_bytes()).collect();
        store.put(&key, &bytes).unwrap();
        key
    }

    fn submit(rig: &Rig, id: &str, dataset_key: &str) {
        let inv = Invocation::new(
            id,
            EventSpec::new("tinyyolo", dataset_key),
            rig.clock.now(),
        );
        rig.queue.publish(inv).unwrap();
    }

    #[test]
    fn executes_one_invocation_end_to_end() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0, 2.0, 3.0]);
        submit(&r, "inv-a", &key);
        let done = r.recv(10);
        assert_eq!(done.id, "inv-a");
        assert_eq!(done.status, Status::Succeeded);
        assert_eq!(done.node.as_deref(), Some("node-1"));
        let accel = done.accelerator.clone().unwrap();
        assert!(accel.starts_with("gpu"), "{accel}");
        assert_eq!(done.variant.as_deref(), Some("tinyyolo-gpu"));
        // stamps are monotone
        let s = &done.stamps;
        assert!(s.r_start <= s.n_start && s.n_start <= s.e_start);
        assert!(s.e_start < s.e_end && s.e_end <= s.n_end);
        // result persisted (mock output = input * 2)
        let result_key = done.result_key.clone().unwrap();
        let body = r.store.get(&result_key).unwrap();
        let floats: Vec<f32> = body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(floats, vec![2.0, 4.0, 6.0]);
        // queue fully drained + acked
        let qs = r.queue.stats().unwrap();
        assert_eq!((qs.queued, qs.in_flight, qs.acked), (0, 0, 1));
        r.node.stop();
    }

    #[test]
    fn missing_dataset_fails_event() {
        let r = rig(paper_dualgpu());
        submit(&r, "inv-miss", "datasets/does-not-exist");
        let done = r.recv(10);
        match &done.status {
            Status::Failed(reason) => assert!(reason.contains("not found"), "{reason}"),
            s => panic!("expected failure, got {s:?}"),
        }
        assert_eq!(r.queue.stats().unwrap().acked, 1, "failed events still ack");
        r.node.stop();
    }

    #[test]
    fn elat_is_paced_to_profile() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[0.5; 16]);
        submit(&r, "inv-pace", &key);
        let done = r.recv(15);
        let elat = done.stamps.elat_ms().unwrap();
        // K600 profile: lognormal(median 1675 ms, σ=0.05) -> overwhelmingly
        // within [1400, 2000] sim-ms.
        assert!((1300.0..2200.0).contains(&elat), "ELat {elat} ms");
        r.node.stop();
    }

    #[test]
    fn saturates_all_slots_and_drains_backlog() {
        let r = rig(paper_all_accel());
        let key = dataset(&r.store, "img", &[1.0; 8]);
        for i in 0..20 {
            submit(&r, &format!("inv-{i}"), &key);
        }
        let mut done = Vec::new();
        for _ in 0..20 {
            done.push(r.recv(30));
        }
        assert!(done.iter().all(|d| d.status == Status::Succeeded));
        // both accelerator kinds participated (the paper's heterogeneity
        // claim: VPU absorbs work without user intervention)
        let kinds: std::collections::BTreeSet<String> = done
            .iter()
            .map(|d| d.accelerator.clone().unwrap())
            .map(|a| a.trim_end_matches(|c: char| c.is_ascii_digit()).to_string())
            .collect();
        assert!(kinds.contains("gpu"), "{kinds:?}");
        assert!(kinds.contains("vpu"), "{kinds:?}");
        // VPU events ran the vpu variant
        for d in &done {
            if d.accelerator.as_deref() == Some("vpu0") {
                assert_eq!(d.variant.as_deref(), Some("tinyyolo-vpu"));
            }
        }
        r.node.stop();
    }

    #[test]
    fn warm_reuse_after_first_completion() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0; 4]);
        for i in 0..6 {
            submit(&r, &format!("inv-{i}"), &key);
        }
        let mut warm_count = 0;
        for _ in 0..6 {
            let d = r.recv(30);
            if d.warm {
                warm_count += 1;
            }
        }
        assert!(
            warm_count >= 2,
            "with 4 slots and 6 events, at least 2 must reuse warm instances (got {warm_count})"
        );
        r.node.stop();
    }

    #[test]
    fn dataset_fetched_and_decoded_once_across_invocations() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0; 16]);
        // Warm the node with one invocation first: the decoded cache has
        // no single-flight (cold concurrent decodes race benignly), so
        // exact-count asserts need a populated cache before the burst.
        submit(&r, "inv-warmup", &key);
        let first = r.recv(30);
        assert_eq!(first.status, Status::Succeeded);
        let n: u64 = 12;
        for i in 1..n {
            submit(&r, &format!("inv-{i}"), &key);
        }
        for _ in 1..n {
            let d = r.recv(30);
            assert_eq!(d.status, Status::Succeeded);
        }
        // The node-local cache collapses n dataset fetches into one
        // backing read (the burst is all LRU hits)...
        let cs = r.node.cache_stats();
        assert_eq!(cs.misses, 1, "one backing fetch for {n} invocations ({cs:?})");
        assert_eq!(
            cs.hits + cs.coalesced,
            n - 1,
            "every other invocation was served node-locally ({cs:?})"
        );
        // ...and the bytes→f32 pass ran once per node, not per invocation.
        let ds = r.node.decoded_stats();
        assert_eq!(ds.misses, 1, "one decode ({ds:?})");
        assert_eq!(ds.hits, n - 1, "{ds:?}");
        r.node.stop();
    }

    #[test]
    fn stale_affinity_hint_degrades_to_backing_fetch() {
        use crate::scheduler::CacheAffinity;
        let r = rig_full(
            paper_dualgpu(),
            BatchConfig::default(),
            Arc::new(CacheAffinity::over(Arc::new(WarmFirst))),
        );
        let key = dataset(&r.store, "img", &[1.0; 4]);
        submit(&r, "inv-1", &key);
        let d = r.recv(10);
        assert_eq!(d.status, Status::Succeeded);
        assert_eq!(r.node.affinity_stats(), AffinityStats { hits: 0, misses: 1 });
        // Resident now: the repeat invocation is an affinity hit.
        submit(&r, "inv-2", &key);
        let d = r.recv(10);
        assert_eq!(d.status, Status::Succeeded);
        assert_eq!(r.node.affinity_stats(), AffinityStats { hits: 1, misses: 1 });
        // Evict behind the queue's back: the cluster may still steer by
        // the stale hint, but the invocation must complete via a plain
        // backing fetch — never an error, never skipped.
        r.node.cache.as_ref().unwrap().invalidate(&key);
        submit(&r, "inv-3", &key);
        let d = r.recv(10);
        assert_eq!(d.status, Status::Succeeded);
        assert_eq!(r.node.affinity_stats(), AffinityStats { hits: 1, misses: 2 });
        r.node.stop();
    }

    #[test]
    fn completion_reports_carry_the_hot_set_summary() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0; 4]);
        submit(&r, "inv-hot", &key);
        let done = r.recv(10);
        assert_eq!(done.status, Status::Succeeded);
        assert!(
            done.hot_keys.contains(&key),
            "summary lists the dataset just served: {:?}",
            done.hot_keys
        );
        assert!(done.hot_generation >= 1, "key-set changes bump the generation");
        r.node.stop();
    }

    #[test]
    fn cache_disabled_when_budget_zero() {
        // A zero budget must degrade to pass-through, not break execution.
        let clock: Arc<ScaledClock> = ScaledClock::new(100.0);
        let queue = MemQueue::new(clock.clone());
        let store = Arc::new(MemStore::new());
        let reserve = InstanceReserve::new();
        let registry = paper_dualgpu();
        for d in registry.devices() {
            for variant in d.profile.runtimes.values() {
                for _ in 0..d.profile.slots {
                    reserve.add(
                        RuntimeInstance::start(
                            variant.clone(),
                            d.id.clone(),
                            MockExecutor::factory(2.0, Duration::from_millis(1)),
                        )
                        .unwrap(),
                    );
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        let deps = NodeDeps {
            queue: queue.clone(),
            store: store.clone(),
            clock: clock.clone(),
            policy: Arc::new(WarmFirst),
            reserve,
            completions: Arc::new(tx),
        };
        let mut cfg = NodeConfig::new("node-nocache");
        cfg.cache_bytes = 0;
        let node = spawn_node(cfg, registry, deps).unwrap();
        let bytes: Vec<u8> = [1.0f32; 4].iter().flat_map(|f| f.to_le_bytes()).collect();
        store.put("datasets/img", &bytes).unwrap();
        let inv = Invocation::new(
            "inv-nc",
            EventSpec::new("tinyyolo", "datasets/img"),
            clock.now(),
        );
        queue.publish(inv).unwrap();
        let done = recv_completion(&rx, Duration::from_secs(10));
        assert_eq!(done.status, Status::Succeeded);
        assert_eq!(node.cache_stats(), crate::store::CacheStats::default());
        assert!(done.hot_keys.is_empty(), "no cache, no hot-set gossip");
        assert_eq!(done.hot_generation, 0);
        assert_eq!(node.affinity_stats(), AffinityStats::default());
        node.stop();
    }

    #[test]
    fn node_stop_is_clean_and_releases_work() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0; 4]);
        submit(&r, "inv-1", &key);
        let _ = r.recv(10);
        r.node.stop();
        // after stop, new publishes stay queued (no one polls)
        let inv = Invocation::new("inv-2", EventSpec::new("tinyyolo", &key), SimTime(0));
        r.queue.publish(inv).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(r.queue.stats().unwrap().queued, 1);
    }

    #[test]
    fn decommission_stops_new_leases_but_serves_inflight() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0; 4]);
        submit(&r, "inv-before", &key);
        let done = r.recv(10);
        assert_eq!(done.status, Status::Succeeded);
        // Decommission: the node stays alive but must take nothing new —
        // neither via the manager poll nor the workers' warm re-take.
        r.node.decommission();
        assert!(r.node.is_draining());
        // Let the manager cycle past the flag (a take entered just
        // before the flag flipped could otherwise race the publish).
        std::thread::sleep(Duration::from_millis(50));
        submit(&r, "inv-after", &key);
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(
            r.queue.stats().unwrap().queued,
            1,
            "decommissioned node must not take new leases"
        );
        assert!(
            r.completions.try_recv().is_err(),
            "nothing served after decommission"
        );
        // retire() drains + joins and hands back terminal counters.
        let (cache, pool, _batch, _affinity) = r.node.retire();
        assert!(cache.misses >= 1, "served one dataset fetch: {cache:?}");
        assert_eq!((pool.live, pool.busy), (0, 0), "gauges zeroed on retire");
        assert!(pool.cold_starts >= 1, "{pool:?}");
        assert_eq!(r.queue.stats().unwrap().queued, 1, "queued work untouched");
    }

    #[test]
    fn deep_backlog_forms_batches_and_counts_stats() {
        // 12 invocations over 4 slots (dual-GPU): the first round is
        // slots-wide serial (the depth probe is gated on a saturated
        // previous round), and the remaining 8 drain through batched
        // warm re-takes — strictly fewer device dispatches than
        // invocations.
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0; 8]);
        let invs: Vec<Invocation> = (0..12)
            .map(|i| {
                Invocation::new(
                    format!("inv-{i}"),
                    EventSpec::new("tinyyolo", &key),
                    r.clock.now(),
                )
            })
            .collect();
        r.queue.publish_batch(invs).unwrap();
        for _ in 0..12 {
            let d = r.recv(30);
            assert_eq!(d.status, Status::Succeeded);
        }
        let stats = r.node.batch_stats();
        assert_eq!(stats.len(), 1, "{stats:?}");
        let s = &stats[0];
        assert_eq!(s.variant, "tinyyolo-gpu");
        assert_eq!(s.invocations, 12);
        assert!(
            s.batches <= 8,
            "12 invocations must coalesce into fewer dispatches: {s:?}"
        );
        assert!(s.mean_size() >= 1.5, "{s:?}");
        let qs = r.queue.stats().unwrap();
        assert_eq!((qs.queued, qs.in_flight, qs.acked), (0, 0, 12));
        r.node.stop();
    }

    #[test]
    fn malformed_input_fails_alone_not_its_batch() {
        // One poisoned input fails the whole device dispatch
        // (all-or-nothing executor contract); the worker must isolate it
        // by re-running members individually — its well-formed
        // neighbours keep the outcome serial execution would have given
        // them.
        struct PoisonExec;
        impl crate::runtime::Executor for PoisonExec {
            fn infer(&mut self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
                if input.first() == Some(&-1.0) {
                    anyhow::bail!("malformed input");
                }
                Ok(input.iter().map(|x| x * 2.0).collect())
            }
        }
        let clock: Arc<ScaledClock> = ScaledClock::new(100.0);
        let queue = MemQueue::new(clock.clone());
        let store = Arc::new(MemStore::new());
        let reserve = InstanceReserve::new();
        let registry = paper_dualgpu();
        for d in registry.devices() {
            for variant in d.profile.runtimes.values() {
                for _ in 0..d.profile.slots {
                    reserve.add(
                        RuntimeInstance::start(variant.clone(), d.id.clone(), {
                            Box::new(|| {
                                Ok(Box::new(PoisonExec)
                                    as Box<dyn crate::runtime::Executor>)
                            })
                        })
                        .unwrap(),
                    );
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        let deps = NodeDeps {
            queue: queue.clone(),
            store: store.clone(),
            clock,
            policy: Arc::new(WarmFirst),
            reserve,
            completions: Arc::new(tx),
        };
        let node = spawn_node(NodeConfig::new("node-poison"), registry, deps).unwrap();
        let good = dataset(&store, "good", &[1.0; 4]);
        let bad = dataset(&store, "bad", &[-1.0; 4]);
        let invs: Vec<Invocation> = (0..16)
            .map(|i| {
                let key = if i == 5 { &bad } else { &good };
                Invocation::new(
                    format!("inv-{i}"),
                    EventSpec::new("tinyyolo", key),
                    SimTime(0),
                )
            })
            .collect();
        queue.publish_batch(invs).unwrap();
        let mut failed = Vec::new();
        let mut ok = 0;
        for _ in 0..16 {
            let d = recv_completion(&rx, Duration::from_secs(30));
            match d.status {
                Status::Succeeded => ok += 1,
                Status::Failed(_) => failed.push(d.id),
                ref s => panic!("non-terminal completion {s:?}"),
            }
        }
        assert_eq!(failed, vec!["inv-5".to_string()], "only the poisoned input fails");
        assert_eq!(ok, 15);
        node.stop();
    }

    #[test]
    fn max_batch_one_restores_serial_execution() {
        let r = rig_with_batch(
            paper_dualgpu(),
            BatchConfig { max_batch: 1, max_linger: Duration::from_millis(5), ..BatchConfig::default() },
        );
        let key = dataset(&r.store, "img", &[1.0; 4]);
        let invs: Vec<Invocation> = (0..6)
            .map(|i| {
                Invocation::new(
                    format!("inv-{i}"),
                    EventSpec::new("tinyyolo", &key),
                    r.clock.now(),
                )
            })
            .collect();
        r.queue.publish_batch(invs).unwrap();
        for _ in 0..6 {
            let d = r.recv(30);
            assert_eq!(d.status, Status::Succeeded);
        }
        let stats = r.node.batch_stats();
        assert_eq!(stats.len(), 1, "{stats:?}");
        assert_eq!(stats[0].batches, stats[0].invocations, "every dispatch is size 1");
        assert_eq!(stats[0].size_hist[0], stats[0].batches);
        assert_eq!(stats[0].lingered, 0, "serial mode never lingers");
        r.node.stop();
    }

    #[test]
    fn property_batched_execution_is_semantically_invisible() {
        use crate::prop;
        // The acceptance property: identical invocation streams through
        // serial, batched, and batched-HLO nodes produce byte-identical
        // per-invocation results, identical statuses, and identical
        // ack/completion counts — batching (and padded / sub-batched
        // device programs) may only change how many device dispatches
        // happen, never what the client observes.
        prop::check(
            "batched-vs-serial-equivalence",
            5,
            |rng| {
                let n = rng.range(1, 13) as usize;
                let datasets: Vec<Vec<f32>> = (0..3)
                    .map(|_| {
                        (0..rng.range(1, 9))
                            .map(|_| (rng.below(1000) as f32) / 100.0)
                            .collect()
                    })
                    .collect();
                // Each invocation: dataset 0..2, or 3 = missing dataset
                // (per-invocation failures must stay per-invocation).
                let picks: Vec<u64> = (0..n).map(|_| rng.below(4)).collect();
                (datasets, picks)
            },
            |(datasets, picks)| {
                let run = |batch: BatchConfig, ladder: Option<Vec<usize>>| {
                    let r = rig_exec(
                        paper_dualgpu(),
                        batch,
                        Arc::new(WarmFirst),
                        ladder,
                    );
                    let keys: Vec<String> = datasets
                        .iter()
                        .enumerate()
                        .map(|(i, vals)| dataset(&r.store, &format!("d{i}"), vals))
                        .collect();
                    let invs: Vec<Invocation> = picks
                        .iter()
                        .enumerate()
                        .map(|(i, &p)| {
                            let key = keys
                                .get(p as usize)
                                .cloned()
                                .unwrap_or_else(|| "datasets/missing".into());
                            Invocation::new(
                                format!("inv-{i}"),
                                EventSpec::new("tinyyolo", key),
                                r.clock.now(),
                            )
                        })
                        .collect();
                    r.queue.publish_batch(invs).unwrap();
                    let mut done: Vec<Invocation> = (0..picks.len())
                        .map(|_| {
                            r.recv(30)
                        })
                        .collect();
                    done.sort_by(|a, b| a.id.cmp(&b.id));
                    let observed: Vec<(String, Status, Option<Vec<u8>>)> = done
                        .into_iter()
                        .map(|d| {
                            let body = d
                                .result_key
                                .as_deref()
                                .map(|k| r.store.get(k).unwrap().to_vec());
                            (d.id, d.status, body)
                        })
                        .collect();
                    let acked = r.queue.stats().unwrap().acked;
                    r.node.stop();
                    (observed, acked)
                };
                let deep = BatchConfig {
                    max_batch: 8,
                    max_linger: Duration::from_millis(5),
                    ..BatchConfig::default()
                };
                let serial = run(
                    BatchConfig { max_batch: 1, ..deep.clone() },
                    None,
                );
                let batched = run(deep.clone(), None);
                // Batched HLO with a sparse ladder: batches of 3/5/6/7
                // members pad to the 4- or 8-wide program (or split),
                // and the padded rows must never surface.
                let batched_hlo = run(deep, Some(vec![1, 4, 8]));
                serial == batched && serial == batched_hlo
            },
        );
    }

    #[test]
    fn idle_tick_regossips_hot_set_after_silent_cache_change() {
        let r = rig(paper_dualgpu());
        let key = dataset(&r.store, "img", &[1.0; 4]);
        submit(&r, "inv-1", &key);
        let done = r.recv(10);
        assert_eq!(done.status, Status::Succeeded);
        let g0 = done.hot_generation;
        assert!(g0 >= 1);
        // Evict behind the node's back: the key-set changes with no
        // completion left to carry the news — only the manager's idle
        // poll tick can refresh the coordinator's affinity table now.
        r.node.cache.as_ref().unwrap().invalidate(&key);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let gossip = loop {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            let inv = r.completions.recv_timeout(left).expect("idle gossip report");
            if inv.id.is_empty() && inv.hot_generation > g0 {
                break inv;
            }
        };
        assert_eq!(gossip.node.as_deref(), Some("node-1"));
        assert!(
            !gossip.hot_keys.contains(&key),
            "evicted key must have left the gossiped hot set: {:?}",
            gossip.hot_keys
        );
        // The refresh is generation-gated, not periodic: with no further
        // cache change the idle loop stays silent.
        std::thread::sleep(Duration::from_millis(150));
        let mut extra = 0;
        while let Ok(inv) = r.completions.try_recv() {
            if inv.id.is_empty() && inv.hot_generation > gossip.hot_generation {
                extra += 1;
            }
        }
        assert_eq!(extra, 0, "no re-gossip without a new generation");
        r.node.stop();
    }

    #[test]
    fn batched_hlo_node_counts_device_programs_and_pad_slots() {
        // Mock engines advertising a compiled {1,2,4,8} ladder: the
        // aggregator snaps chunk caps onto the ladder, and every
        // dispatch's device-program / pad-slot counts flow into the
        // per-variant stats.
        let r = rig_exec(
            paper_dualgpu(),
            BatchConfig::default(),
            Arc::new(WarmFirst),
            Some(vec![1, 2, 4, 8]),
        );
        let key = dataset(&r.store, "img", &[1.0; 8]);
        let invs: Vec<Invocation> = (0..12)
            .map(|i| {
                Invocation::new(
                    format!("inv-{i}"),
                    EventSpec::new("tinyyolo", &key),
                    r.clock.now(),
                )
            })
            .collect();
        r.queue.publish_batch(invs).unwrap();
        for _ in 0..12 {
            let d = r.recv(30);
            assert_eq!(d.status, Status::Succeeded);
        }
        let stats = r.node.batch_stats();
        assert_eq!(stats.len(), 1, "{stats:?}");
        let s = &stats[0];
        assert_eq!(s.invocations, 12);
        assert!(
            s.device_programs >= s.batches,
            "every dispatch runs at least one program: {s:?}"
        );
        assert!(
            s.device_programs <= s.invocations,
            "batched HLO never exceeds one program per input: {s:?}"
        );
        r.node.stop();
    }

    #[test]
    fn unsupported_runtime_left_in_queue() {
        let r = rig(paper_dualgpu());
        let inv = Invocation::new(
            "inv-alien",
            EventSpec::new("bert-large", "datasets/x"),
            r.clock.now(),
        );
        r.queue.publish(inv).unwrap();
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(
            r.queue.stats().unwrap().queued,
            1,
            "node must not take runtimes it cannot serve"
        );
        r.node.stop();
    }
}
