//! Adaptive micro-batch aggregation — the node's answer to per-dispatch
//! accelerator overhead.
//!
//! Production accelerator serving wins an order of magnitude of
//! throughput by coalescing concurrent requests into one device
//! execution; both the in-storage DSA serverless work and the Berkeley
//! serverless view (PAPERS.md) identify per-invocation dispatch overhead
//! as the dominant tax on accelerated FaaS.  PR 2 batched the wire
//! (`take_batch`), PR 3 shared the inputs (`Blob`/`DecodedCache`) — this
//! module carries the batch the last hop: N same-variant invocations
//! become **one instance-thread hop and one device dispatch**
//! (`RuntimeInstance::exec_batch`).
//!
//! ## Aggregator state machine (DESIGN.md §11)
//!
//! Per `(variant, device)` lane the aggregator is a two-state machine:
//!
//! * **Forming** — a batch has ≥ 1 invocation but is not full.  The
//!   worker may *linger* (park on the queue) for more same-variant work,
//!   up to an adaptive budget.
//! * **Dispatch** — the batch is full (`max_batch`), the linger budget is
//!   exhausted, or lingering is off.  One `exec_batch` runs the batch.
//!
//! ## Linger adaptation
//!
//! The linger ceiling is `max_linger` (sim time), but the *effective*
//! budget scales with how full this lane's recent batches ran relative
//! to the lane's effective dispatch cap (`max_batch`, lease-clamped per
//! device by [`BatchAggregator::dispatch_cap`]):
//!
//! ```text
//! effective_linger = max_linger × clamp(ewma_fill / cap, 0, 1)
//! ```
//!
//! where `ewma_fill` is an exponentially weighted average of observed
//! batch sizes (α = 0.25, seeded at 1).  A shallow queue keeps
//! `ewma_fill ≈ 1`, so a lone invocation waits at most
//! `max_linger / cap` (sub-millisecond at the defaults) and p50
//! latency does not regress at low load; a sustained backlog drives the
//! average toward the cap and the lane earns its full linger window —
//! including on lanes whose cap is hold-clamped below `max_batch`.
//! The rule is pure arithmetic over explicit `waited` durations, so it is
//! pinned exactly under `SimClock` with zero wall sleeps.

use crate::json::Json;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Micro-batching knobs (sim time, like every node duration).
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Device batch-size cap.  1 disables batching entirely (serial
    /// execution, the pre-batching behaviour).
    pub max_batch: usize,
    /// Linger ceiling: how long a *forming* batch may wait for more
    /// same-variant work before dispatching.  The effective budget
    /// adapts downward at low load (module docs).  Zero disables linger
    /// (batches still form from backlog, but never wait).
    pub max_linger: Duration,
    /// Lease-safety ceiling on one dispatch's device occupancy (sim
    /// time): a dispatch holds its members' leases for the **summed**
    /// service pacing, which must finish inside the queue's visibility
    /// window (30 s default) or mid-execution redelivery duplicates
    /// work.  The worker caps members per dispatch at
    /// `max_hold / service_median` for its device
    /// ([`BatchAggregator::dispatch_cap`]); the manager sizes chunks
    /// under the worst device's cap, and a worker handed more releases
    /// the excess back to the queue rather than holding leases across
    /// sequential dispatches.
    pub max_hold: Duration,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig {
            max_batch: 8,
            // ~0.3% of the paper's ~1.6 s service times at full depth,
            // and 8× less than that for a lone invocation.
            max_linger: Duration::from_millis(5),
            // Half the default queue visibility: paper-calibrated
            // devices (~1.6 s median) cap out near 9 members even when
            // `max_batch` asks for 32.
            max_hold: Duration::from_secs(15),
        }
    }
}

/// Batch-size histogram buckets: ≤1, ≤2, ≤4, ≤8, ≤16, ≤32, >32.
pub const SIZE_BUCKETS: usize = 7;

fn size_bucket(size: usize) -> usize {
    match size {
        0..=1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        17..=32 => 5,
        _ => 6,
    }
}

/// Per-variant batching counters (surfaced through `cluster_stats` and
/// `hardless status`, lenient JSON like the cache counters).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct VariantBatchStats {
    pub variant: String,
    /// Device dispatches (one `exec_batch` each).
    pub batches: u64,
    /// Invocations served across those dispatches.
    pub invocations: u64,
    /// Dispatches that went out full (`size == max_batch`).
    pub full: u64,
    /// Dispatches that waited a linger window before going out.
    pub lingered: u64,
    /// Batch-size distribution (≤1, ≤2, ≤4, ≤8, ≤16, ≤32, >32).
    pub size_hist: [u64; SIZE_BUCKETS],
    /// Sum over invocations of the queue→device wait (`EStart − NStart`)
    /// in µs — the latency split batching is allowed to spend.  Kept in
    /// µs because the interesting waits (the adaptive linger window) are
    /// sub-millisecond and would truncate to zero.
    pub queue_to_device_us: u64,
    /// Device programs actually dispatched (DESIGN.md §16): with batched
    /// HLO one dispatch can serve a whole batch with one program, so this
    /// runs *below* `invocations`; a per-input loop pins it equal.
    pub device_programs: u64,
    /// Padded rows executed and discarded by pad-to-next-size dispatches.
    pub pad_slots: u64,
}

impl VariantBatchStats {
    /// Mean invocations per device dispatch.
    pub fn mean_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.invocations as f64 / self.batches as f64
        }
    }

    /// Mean queue→device wait per invocation, ms.
    pub fn mean_queue_to_device_ms(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.queue_to_device_us as f64 / 1e3 / self.invocations as f64
        }
    }

    /// Fold another lane/node's counters for the same variant in.
    pub fn add(&mut self, other: &VariantBatchStats) {
        self.batches += other.batches;
        self.invocations += other.invocations;
        self.full += other.full;
        self.lingered += other.lingered;
        for (a, b) in self.size_hist.iter_mut().zip(other.size_hist.iter()) {
            *a += b;
        }
        self.queue_to_device_us += other.queue_to_device_us;
        self.device_programs += other.device_programs;
        self.pad_slots += other.pad_slots;
    }

    pub fn to_json(&self) -> Json {
        let hist: Vec<Json> =
            self.size_hist.iter().map(|&n| Json::from(n as usize)).collect();
        Json::obj()
            .set("variant", self.variant.as_str())
            .set("batches", self.batches as usize)
            .set("invocations", self.invocations as usize)
            .set("full", self.full as usize)
            .set("lingered", self.lingered as usize)
            .set("mean_size", self.mean_size())
            .set("size_hist", Json::Arr(hist))
            .set("queue_to_device_us", self.queue_to_device_us as usize)
            .set("device_programs", self.device_programs as usize)
            .set("pad_slots", self.pad_slots as usize)
    }

    /// Lenient parse: every counter defaults to zero (the section
    /// postdates the stats wire format).
    pub fn from_json(j: &Json) -> Result<VariantBatchStats> {
        let n = |key: &str| j.usize_of(key).unwrap_or(0) as u64;
        let mut size_hist = [0u64; SIZE_BUCKETS];
        if let Some(arr) = j.get("size_hist").and_then(|v| v.as_arr()) {
            for (slot, v) in size_hist.iter_mut().zip(arr.iter()) {
                *slot = v.as_usize().unwrap_or(0) as u64;
            }
        }
        Ok(VariantBatchStats {
            variant: j.str_of("variant")?.to_string(),
            batches: n("batches"),
            invocations: n("invocations"),
            full: n("full"),
            lingered: n("lingered"),
            size_hist,
            queue_to_device_us: n("queue_to_device_us"),
            device_programs: n("device_programs"),
            pad_slots: n("pad_slots"),
        })
    }
}

/// Merge per-lane/per-node stats into a per-variant list sorted by
/// variant name (deterministic for wire encoding and tests).
pub fn merge_variant_stats(
    into: &mut Vec<VariantBatchStats>,
    more: &[VariantBatchStats],
) {
    for s in more {
        match into.iter_mut().find(|t| t.variant == s.variant) {
            Some(t) => t.add(s),
            None => into.push(s.clone()),
        }
    }
    into.sort_by(|a, b| a.variant.cmp(&b.variant));
}

struct LaneState {
    /// EWMA of observed batch sizes (α = 0.25), seeded at 1.0 so a cold
    /// lane behaves like a shallow one.
    ewma_fill: f64,
    stats: VariantBatchStats,
}

/// Get-or-seed the lane entry (shared by every observe path so the
/// seeding stays in one place).
fn lane_mut<'a>(
    lanes: &'a mut HashMap<(String, String), LaneState>,
    variant: &str,
    device_id: &str,
) -> &'a mut LaneState {
    lanes
        .entry((variant.to_string(), device_id.to_string()))
        .or_insert_with(|| LaneState {
            ewma_fill: 1.0,
            stats: VariantBatchStats {
                variant: variant.to_string(),
                ..VariantBatchStats::default()
            },
        })
}

/// Per-`(variant, device)` batch former shared by a node's workers.
pub struct BatchAggregator {
    cfg: BatchConfig,
    lanes: Mutex<HashMap<(String, String), LaneState>>,
    /// Compiled batch ladders per variant, noted by workers at pool
    /// checkout from the instance's cold-start capture
    /// (`RuntimeInstance::compiled_batch_sizes`).  Feeds
    /// [`snap_cap`](Self::snap_cap).
    compiled: Mutex<HashMap<String, Vec<usize>>>,
}

impl BatchAggregator {
    pub fn new(cfg: BatchConfig) -> Arc<BatchAggregator> {
        Arc::new(BatchAggregator {
            cfg,
            lanes: Mutex::new(HashMap::new()),
            compiled: Mutex::new(HashMap::new()),
        })
    }

    /// Record `variant`'s compiled batch ladder (sorted ascending).
    pub fn note_compiled(&self, variant: &str, sizes: &[usize]) {
        if sizes.is_empty() {
            return;
        }
        let mut compiled = self.compiled.lock().expect("batcher poisoned");
        compiled
            .entry(variant.to_string())
            .or_insert_with(|| sizes.to_vec());
    }

    /// Snap a dispatch/chunk cap down to the largest compiled batch size
    /// <= `cap` (DESIGN.md §16), so full batches land exactly on a device
    /// program instead of padding or splitting.  Left unchanged when the
    /// variant's ladder is unknown, when no rung above 1 fits (a batch-1
    /// ladder means the loop fallback, which never pads), or when the
    /// whole ladder sits above `cap`.
    pub fn snap_cap(&self, variant: &str, cap: usize) -> usize {
        let compiled = self.compiled.lock().expect("batcher poisoned");
        match compiled.get(variant) {
            Some(ladder) => match ladder.iter().rev().find(|&&n| n > 1 && n <= cap) {
                Some(&n) => n,
                None => cap,
            },
            None => cap,
        }
    }

    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch.max(1)
    }

    /// Device-aware per-dispatch member cap: `max_batch`, further capped
    /// so the dispatch's summed service pacing
    /// (`members × service_median`) stays within `max_hold` — leases
    /// must never outlive the queue's visibility window mid-execution.
    pub fn dispatch_cap(&self, service_median_ms: f64) -> usize {
        if service_median_ms <= 0.0 {
            return self.max_batch();
        }
        let by_hold =
            (self.cfg.max_hold.as_secs_f64() * 1e3 / service_median_ms) as usize;
        by_hold.clamp(1, self.max_batch())
    }

    /// Snapshot a lane's fill EWMA (one lock + lookup).  Workers take it
    /// once per gather round and feed it to
    /// [`linger_budget_at`](Self::linger_budget_at), keeping the
    /// per-lease budget probe allocation- and lock-free.  Sibling
    /// workers on a multi-slot device may `observe` the lane mid-gather;
    /// a one-gather-stale snapshot is fine — the budget rule is monotone
    /// in fill and always bounded by `max_linger`.
    pub fn lane_fill(&self, variant: &str, device_id: &str) -> f64 {
        let lanes = self.lanes.lock().expect("batcher poisoned");
        lanes
            .get(&(variant.to_string(), device_id.to_string()))
            .map(|l| l.ewma_fill)
            .unwrap_or(1.0)
    }

    /// The pure linger rule over a snapshot `fill`: remaining budget for
    /// a forming batch of `have` invocations that has already waited
    /// `waited` (sim time), on a lane whose effective dispatch cap is
    /// `cap` (`max_batch`, lease-clamped per device — fill is judged
    /// against what this lane can actually coalesce).  `None` = dispatch
    /// now — the batch is full, lingering is disabled, or the adaptive
    /// budget is spent.
    pub fn linger_budget_at(
        &self,
        fill: f64,
        cap: usize,
        have: usize,
        waited: Duration,
    ) -> Option<Duration> {
        let cap = cap.clamp(1, self.max_batch());
        if have >= cap || cap <= 1 || self.cfg.max_linger.is_zero() {
            return None;
        }
        let ratio = (fill / cap as f64).clamp(0.0, 1.0);
        let effective = self.cfg.max_linger.mul_f64(ratio);
        let remaining = effective.saturating_sub(waited);
        if remaining.is_zero() {
            None
        } else {
            Some(remaining)
        }
    }

    /// Snapshot + rule in one call at the unclamped cap (tests and
    /// one-shot probes).
    pub fn linger_budget(
        &self,
        variant: &str,
        device_id: &str,
        have: usize,
        waited: Duration,
    ) -> Option<Duration> {
        self.linger_budget_at(
            self.lane_fill(variant, device_id),
            self.max_batch(),
            have,
            waited,
        )
    }

    /// Record one dispatched batch: feeds the linger adaptation (EWMA of
    /// fill) and the per-variant counters.  `cap` is the lane's
    /// effective dispatch cap — a dispatch that leaves at its
    /// lease-clamped cap counts as full.
    pub fn observe(
        &self,
        variant: &str,
        device_id: &str,
        size: usize,
        cap: usize,
        lingered: bool,
        queue_to_device_us: u64,
        programs: usize,
        pad_slots: usize,
    ) {
        let mut lanes = self.lanes.lock().expect("batcher poisoned");
        let lane = lane_mut(&mut lanes, variant, device_id);
        lane.ewma_fill = 0.75 * lane.ewma_fill + 0.25 * size as f64;
        lane.stats.batches += 1;
        lane.stats.invocations += size as u64;
        lane.stats.device_programs += programs as u64;
        lane.stats.pad_slots += pad_slots as u64;
        if size >= cap.clamp(1, self.max_batch()) {
            lane.stats.full += 1;
        }
        if lingered {
            lane.stats.lingered += 1;
        }
        lane.stats.size_hist[size_bucket(size)] += 1;
        lane.stats.queue_to_device_us += queue_to_device_us;
    }

    /// Record an isolation-fallback round: the coalesced dispatch failed
    /// and `n` members re-ran as serial dispatches of one.  Feeding the
    /// EWMA and histogram what actually happened keeps the adaptive
    /// linger window from lengthening on a lane that is executing
    /// serially.
    pub fn observe_serial(
        &self,
        variant: &str,
        device_id: &str,
        n: usize,
        lingered: bool,
        queue_to_device_us: u64,
    ) {
        let mut lanes = self.lanes.lock().expect("batcher poisoned");
        let lane = lane_mut(&mut lanes, variant, device_id);
        for _ in 0..n {
            lane.ewma_fill = 0.75 * lane.ewma_fill + 0.25;
        }
        lane.stats.batches += n as u64;
        lane.stats.invocations += n as u64;
        // Serial fallback runs one device program per member, never pads.
        lane.stats.device_programs += n as u64;
        if lingered {
            // The gather did wait a linger window; the fallback does not
            // erase that from the linger hit rate.
            lane.stats.lingered += 1;
        }
        lane.stats.size_hist[size_bucket(1)] += n as u64;
        lane.stats.queue_to_device_us += queue_to_device_us;
    }

    /// Per-variant counters, lanes merged, sorted by variant.
    pub fn stats(&self) -> Vec<VariantBatchStats> {
        let lanes = self.lanes.lock().expect("batcher poisoned");
        let mut out: Vec<VariantBatchStats> = Vec::new();
        for lane in lanes.values() {
            merge_variant_stats(&mut out, std::slice::from_ref(&lane.stats));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agg(max_batch: usize, linger_ms: u64) -> Arc<BatchAggregator> {
        BatchAggregator::new(BatchConfig {
            max_batch,
            max_linger: Duration::from_millis(linger_ms),
            ..BatchConfig::default()
        })
    }

    #[test]
    fn linger_adaptation_pins_shallow_vs_deep() {
        // The acceptance-pinned rule: effective = max_linger · ewma/max.
        let a = agg(8, 8);
        // Cold lane (ewma = 1): a lone invocation may wait at most
        // max_linger / max_batch = 1 ms — p50 at shallow depth is safe.
        let cold = a
            .linger_budget("v", "gpu0", 1, Duration::ZERO)
            .expect("forming batch gets some budget");
        assert_eq!(cold, Duration::from_millis(1), "shallow budget = ceiling / max_batch");
        // Budget is a deadline, not a reset: waiting it out exhausts it.
        assert_eq!(
            a.linger_budget("v", "gpu0", 1, Duration::from_millis(1)),
            None,
            "spent budget dispatches"
        );
        // Sustained full batches drive ewma -> max_batch and the lane
        // earns (asymptotically) the full ceiling.
        for _ in 0..32 {
            a.observe("v", "gpu0", 8, 8, false, 0, 1, 0);
        }
        let deep = a.linger_budget("v", "gpu0", 1, Duration::ZERO).unwrap();
        assert!(
            deep > Duration::from_millis(7),
            "deep lane approaches the 8 ms ceiling: {deep:?}"
        );
        // ...and the budget decreases monotonically with time waited.
        let later = a
            .linger_budget("v", "gpu0", 1, Duration::from_millis(5))
            .unwrap();
        assert!(later < deep);
        // Load drops again -> singles pull the ewma (and the budget) back
        // down; a quiet period can never leave the linger stuck high.
        for _ in 0..32 {
            a.observe("v", "gpu0", 1, 8, false, 0, 1, 0);
        }
        let shallow_again = a.linger_budget("v", "gpu0", 1, Duration::ZERO).unwrap();
        assert!(shallow_again <= Duration::from_millis(2), "{shallow_again:?}");
    }

    #[test]
    fn dispatch_cap_bounds_lease_hold() {
        // max_hold 15 s over the K600's 1675 ms median: 8 members max,
        // no matter how large max_batch is configured.
        let a = BatchAggregator::new(BatchConfig {
            max_batch: 32,
            max_linger: Duration::from_millis(5),
            max_hold: Duration::from_secs(15),
        });
        assert_eq!(a.dispatch_cap(1675.0), 8);
        assert_eq!(a.dispatch_cap(1577.0), 9, "VPU median caps at 9");
        // Cheap device: max_batch is the binding limit.
        assert_eq!(a.dispatch_cap(10.0), 32);
        // A service time longer than max_hold still allows one member.
        assert_eq!(a.dispatch_cap(60_000.0), 1);
        // Degenerate median: fall back to max_batch.
        assert_eq!(a.dispatch_cap(0.0), 32);
    }

    #[test]
    fn hold_capped_lane_earns_full_window_and_counts_full() {
        // max_batch 32 but the device's lease-safe cap is 8: batches of
        // 8 ARE full for this lane — the EWMA saturates at 8 and the
        // lane earns the whole linger ceiling, and `full` counts.
        let a = agg(32, 8);
        for _ in 0..32 {
            a.observe("v", "gpu0", 8, 8, false, 0, 1, 0);
        }
        let fill = a.lane_fill("v", "gpu0");
        let budget = a.linger_budget_at(fill, 8, 1, Duration::ZERO).unwrap();
        assert!(
            budget > Duration::from_millis(7),
            "cap-relative adaptation reaches the ceiling: {budget:?}"
        );
        let stats = a.stats();
        assert_eq!(stats[0].full, 32, "cap-sized dispatches count as full");
        // have >= cap dispatches immediately even though < max_batch.
        assert_eq!(a.linger_budget_at(fill, 8, 8, Duration::ZERO), None);
    }

    #[test]
    fn linger_disabled_cases() {
        // Full batch never lingers.
        let a = agg(4, 10);
        assert_eq!(a.linger_budget("v", "d", 4, Duration::ZERO), None);
        // max_batch = 1 = batching off.
        let serial = agg(1, 10);
        assert_eq!(serial.linger_budget("v", "d", 1, Duration::ZERO), None);
        // Zero ceiling = linger off even while forming.
        let nolinger = agg(8, 0);
        assert_eq!(nolinger.linger_budget("v", "d", 1, Duration::ZERO), None);
    }

    #[test]
    fn lanes_adapt_independently() {
        let a = agg(8, 8);
        for _ in 0..32 {
            a.observe("v", "gpu0", 8, 8, false, 0, 1, 0);
        }
        let hot = a.linger_budget("v", "gpu0", 1, Duration::ZERO).unwrap();
        let cold = a.linger_budget("v", "gpu1", 1, Duration::ZERO).unwrap();
        assert!(hot > cold, "per-(variant,device) adaptation: {hot:?} vs {cold:?}");
    }

    #[test]
    fn stats_merge_lanes_per_variant_and_roundtrip_json() {
        let a = agg(8, 5);
        a.observe("tinyyolo-gpu", "gpu0", 8, 8, true, 40, 1, 0);
        a.observe("tinyyolo-gpu", "gpu1", 4, 8, false, 12, 2, 3);
        a.observe("tinyyolo-vpu", "vpu0", 1, 8, false, 3, 1, 0);
        let stats = a.stats();
        assert_eq!(stats.len(), 2, "{stats:?}");
        assert_eq!(stats[0].variant, "tinyyolo-gpu", "sorted by variant");
        assert_eq!(stats[0].batches, 2);
        assert_eq!(stats[0].invocations, 12);
        assert_eq!(stats[0].full, 1);
        assert_eq!(stats[0].lingered, 1);
        assert_eq!(stats[0].mean_size(), 6.0);
        assert_eq!(stats[0].queue_to_device_us, 52);
        assert_eq!(stats[0].device_programs, 3, "1 + 2 across lanes");
        assert_eq!(stats[0].pad_slots, 3);
        assert_eq!(stats[0].size_hist[3], 1, "size 8 bucket");
        assert_eq!(stats[0].size_hist[2], 1, "size 4 bucket");
        assert_eq!(stats[1].variant, "tinyyolo-vpu");
        assert_eq!(stats[1].size_hist[0], 1);
        // JSON roundtrip + lenient parse of a bare payload
        for s in &stats {
            assert_eq!(VariantBatchStats::from_json(&s.to_json()).unwrap(), *s);
        }
        let bare = Json::obj().set("variant", "x");
        let parsed = VariantBatchStats::from_json(&bare).unwrap();
        assert_eq!(parsed.batches, 0);
        assert_eq!(parsed.size_hist, [0; SIZE_BUCKETS]);
    }

    #[test]
    fn serial_fallback_counts_one_program_per_member() {
        let a = agg(8, 5);
        a.observe_serial("v", "gpu0", 4, true, 20);
        let stats = a.stats();
        assert_eq!(stats[0].device_programs, 4);
        assert_eq!(stats[0].pad_slots, 0);
    }

    #[test]
    fn snap_cap_lands_on_largest_compiled_rung() {
        let a = agg(32, 5);
        // Unknown variant: cap passes through untouched.
        assert_eq!(a.snap_cap("v", 9), 9);
        a.note_compiled("v", &[1, 2, 4, 8, 16, 32]);
        // 9 snaps down to the 8-rung program; exact rungs stay put.
        assert_eq!(a.snap_cap("v", 9), 8);
        assert_eq!(a.snap_cap("v", 16), 16);
        assert_eq!(a.snap_cap("v", 31), 16);
        // A cap below every rung > 1 is left alone (never snap *up*).
        assert_eq!(a.snap_cap("v", 1), 1);
        // Batch-1-only ladder = loop fallback: snapping to 1 would
        // serialize batches for nothing, so the cap is untouched.
        a.note_compiled("legacy", &[1]);
        assert_eq!(a.snap_cap("legacy", 9), 9);
        // First-noted ladder wins; later notes are ignored.
        a.note_compiled("v", &[1]);
        assert_eq!(a.snap_cap("v", 9), 8);
    }

    #[test]
    fn size_buckets_cover_range() {
        assert_eq!(size_bucket(1), 0);
        assert_eq!(size_bucket(2), 1);
        assert_eq!(size_bucket(4), 2);
        assert_eq!(size_bucket(8), 3);
        assert_eq!(size_bucket(16), 4);
        assert_eq!(size_bucket(32), 5);
        assert_eq!(size_bucket(33), 6);
    }
}
