//! Per-batch worker: the node's execution path.
//!
//! A worker owns one accelerator slot for its lifetime.  It checks out a
//! runtime instance (warm from the pool, or cold-started from the
//! reserve with the profile's cold-start pacing), then loops over
//! **micro-batches** of same-runtime invocations:
//!
//!   fetch datasets → one `exec_batch` device dispatch → pace once to the
//!   device's service time → postprocess + persist each result →
//!   `ack_batch` → signal completions → same-config re-take (§IV-D warm
//!   reuse, up to `max_batch` at a time with an adaptive linger window) →
//!   repeat until the queue has no matching work.
//!
//! Batching is semantically invisible: per-invocation outputs, acks, and
//! completion reports are identical to serial execution (pinned by the
//! equivalence property test in `crate::node`); only the dispatch count
//! changes — N same-variant invocations cost one instance-thread hop and
//! one device execution.

use crate::accel::{Device, DeviceRegistry, SlotGuard};
use crate::events::{Invocation, Status};
use crate::node::batch::BatchAggregator;
use crate::node::CompletionSink;
use crate::postprocess;
use crate::queue::{InvocationQueue, Lease, TakeFilter};
use crate::runtime::{InstancePool, RuntimeInstance};
use crate::scheduler::{warm_runtimes, Admission, Policy};
use crate::store::{keys, CachedStore, DecodedCache, ObjectStore};
use crate::util::{Clock, Rng};
use anyhow::{anyhow, Context, Result};
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

/// Shared services a worker needs.
pub struct WorkerCtx {
    pub node_id: String,
    pub pool: Arc<InstancePool>,
    pub queue: Arc<dyn InvocationQueue>,
    /// The node's store view — a node-local [`crate::store::CachedStore`]
    /// when the cache is enabled (see [`crate::node::spawn_node`]).
    pub store: Arc<dyn ObjectStore>,
    /// The same cache, typed (None when caching is disabled): residency
    /// probes for affinity accounting and the hot-set summary
    /// piggybacked on completion reports (DESIGN.md §15).
    pub cache: Option<Arc<CachedStore>>,
    /// Node-wide bytes→f32 cache: the decode pass runs once per dataset
    /// buffer per node, not once per invocation.
    pub decoded: Arc<DecodedCache>,
    pub clock: Arc<dyn Clock>,
    pub policy: Arc<dyn Policy>,
    pub reserve: Arc<crate::node::InstanceReserve>,
    pub completions: Arc<dyn CompletionSink>,
    /// Per-(variant, device) micro-batch former: linger budgets and the
    /// per-variant batch-size distribution (`cluster_stats.batch`).
    pub batcher: Arc<BatchAggregator>,
    /// Data-locality scoreboard: bumped once per dataset fetch.
    pub affinity: Arc<crate::node::AffinityCounters>,
    /// Node decommission flag: set, workers finish their current
    /// batch but skip the §IV-D warm re-take (graceful scale-in
    /// must stop *all* lease-taking paths, not just the manager poll).
    pub draining: Arc<std::sync::atomic::AtomicBool>,
    /// Highest cache generation already gossiped off this node (shared
    /// with the manager's idle tick): completions advance it as they
    /// piggyback the hot set, so the idle path only re-sends when the
    /// cache changed with no completion to carry the news (DESIGN.md §15).
    pub gossiped: Arc<std::sync::atomic::AtomicU64>,
}

/// Pick a device + slot for `runtime`.  When the lease was a warm hit,
/// prefer a device that actually holds an idle warm instance; otherwise
/// least-loaded wins (§IV-C: the node is free to choose).
pub fn pick_slot(
    registry: &DeviceRegistry,
    pool: &InstancePool,
    runtime: &str,
    warm_hit: bool,
) -> Option<SlotGuard> {
    if warm_hit {
        for d in registry.candidates(runtime) {
            let has_warm = d
                .profile
                .variant_for(runtime)
                .map(|v| pool.has_idle(v, &d.id))
                .unwrap_or(false);
            if has_warm {
                if let Some(guard) = d.try_acquire() {
                    return Some(guard);
                }
            }
        }
    }
    registry.acquire_for(runtime)
}

/// Deterministic per-invocation RNG (service-time jitter reproducibility).
fn rng_for(invocation_id: &str) -> Rng {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in invocation_id.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    Rng::new(h)
}

/// Entry point for a worker thread: run the leased batch (same logical
/// runtime throughout), then drain same-config work while the instance
/// is hot.  `first` is non-empty; the invocations' `warm` flags are
/// assigned here (lead = the pool checkout's warm/cold outcome, riders
/// = warm) — callers need not set them.  Warm *placement* is the
/// manager's job via [`pick_slot`]'s `warm_hit` argument.
pub fn run_invocations(ctx: WorkerCtx, first: Vec<Invocation>, slot: SlotGuard) {
    let device = slot.device().clone();
    let Some(lead) = first.first() else {
        return;
    };
    let runtime = lead.spec.runtime.clone();

    // Resolve the accelerator-specific implementation variant.
    let Some(variant) = device.profile.variant_for(&runtime).map(String::from) else {
        let reason = format!("device {} does not implement {runtime}", device.id);
        fail_batch(&ctx, first, &reason);
        return;
    };

    // Check out an instance: warm from the pool, or cold via the reserve
    // with the profile's cold-start pacing applied in sim time.  The
    // reserve can be transiently empty while another worker on this
    // device is between "finished executing" and "returned the instance
    // to the pool" — retry briefly (a warm instance or reserve slot shows
    // up as soon as that worker unwinds) before declaring failure.
    let mut pooled = None;
    let mut last_err = None;
    for _attempt in 0..50 {
        let attempt = {
            let reserve = ctx.reserve.clone();
            let clock = ctx.clock.clone();
            let profile = device.profile.clone();
            let v = variant.clone();
            let d = device.id.clone();
            ctx.pool.acquire_or_start(&variant, &device.id, move || {
                // Pop first (cheap, fallible), pace the cold start after.
                let instance = reserve.pop(&v, &d).ok_or_else(|| {
                    anyhow!("instance reserve exhausted for {v} on {d}")
                })?;
                clock.sleep(Duration::from_secs_f64(profile.cold_start_ms / 1e3));
                Ok(instance)
            })
        };
        match attempt {
            Ok(p) => {
                pooled = Some(p);
                break;
            }
            Err(e) => {
                last_err = Some(e);
                ctx.clock.sleep(Duration::from_millis(50));
            }
        }
    }
    let pooled = match pooled {
        Some(p) => p,
        None => {
            let reason = format!(
                "cold start failed after retries: {:#}",
                last_err.unwrap_or_else(|| anyhow!("unknown"))
            );
            fail_batch(&ctx, first, &reason);
            return;
        }
    };

    // Device-aware per-dispatch cap (lease safety): one dispatch paces
    // to the *sum* of its members' service times, which must finish
    // inside the queue's visibility window — at most
    // `max_hold / service_median` members for this device.  The
    // manager's chunk ceiling is sized for the node's most permissive
    // device, so a chunk placed on a slower one can exceed this cap;
    // the excess is handed straight back rather than held across
    // sequential dispatches — a worker never holds more leases than one
    // dispatch serves.
    // The instance thread captured the bundle's compiled batch ladder at
    // cold start; publish it so chunk caps snap to a compiled size
    // (DESIGN.md §16) and full batches land on one device program.
    ctx.batcher
        .note_compiled(&variant, pooled.instance.compiled_batch_sizes());
    let cap = ctx
        .batcher
        .dispatch_cap(device.profile.service.median_ms)
        .max(1);
    let cap = ctx.batcher.snap_cap(&variant, cap);
    let mut batch = first;
    if batch.len() > cap {
        let overflow = batch.split_off(cap);
        // Released newest-first so the front-requeue's descending seqs
        // keep the oldest frontmost (FIFO survives the round trip).
        for inv in overflow.iter().rev() {
            let _ = ctx.queue.release(&inv.id);
        }
    }
    let mut warm = pooled.warm;
    let mut lingered = false;
    // Built once: the §IV-D same-configuration reuse query runs after
    // every dispatch, so keep it out of the drain loop.
    let reuse_filter = TakeFilter::warm_reuse(&runtime);
    loop {
        for (i, inv) in batch.iter_mut().enumerate() {
            inv.accelerator = Some(device.id.clone());
            inv.variant = Some(variant.clone());
            // Within a batch only the lead invocation can be a cold
            // start; the rest ride the (now hot) instance.
            inv.warm = warm || i > 0;
        }
        let (dispatched, fallback, programs, pad_slots) =
            execute_batch(&ctx, &device, &pooled.instance, &mut batch);
        let n_end = ctx.clock.now();
        // Accumulate in µs: the waits this metric exists to expose (the
        // sub-ms adaptive linger window) would truncate to 0 in ms.
        let mut q2d_us = 0u64;
        for inv in batch.iter_mut() {
            inv.stamps.n_end = Some(n_end);
            if let (Some(n_start), Some(e_start)) =
                (inv.stamps.n_start, inv.stamps.e_start)
            {
                q2d_us += e_start.since(n_start).as_micros() as u64;
            }
        }
        // One ack round trip for the whole batch, then per-invocation
        // completion reports (the coordinator's contract is per-event).
        // Fetch-failed members were already acked + reported inside
        // execute_batch (fast-fail), so `batch` may have shrunk.
        if !batch.is_empty() {
            let ids: Vec<String> = batch.iter().map(|i| i.id.clone()).collect();
            if let Err(e) = ctx.queue.ack_batch(&ids) {
                log::warn!("node {}: ack_batch failed: {e:#}", ctx.node_id);
            }
        }
        // Only real device dispatches feed the stats and the linger
        // EWMA — a batch whose every member failed its dataset fetch
        // executed nothing, and an isolation fallback ran serially.
        if dispatched > 0 {
            if fallback {
                ctx.batcher
                    .observe_serial(&variant, &device.id, dispatched, lingered, q2d_us);
            } else {
                ctx.batcher.observe(
                    &variant, &device.id, dispatched, cap, lingered, q2d_us, programs,
                    pad_slots,
                );
            }
        }
        for mut inv in batch.drain(..) {
            stamp_hot_set(ctx.cache.as_deref(), &ctx.gossiped, &mut inv);
            if let Err(e) = ctx.completions.report(inv) {
                log::warn!("node {}: completion report failed: {e:#}", ctx.node_id);
            }
        }

        // Decommissioned mid-drain: the batch just served is done; no
        // further work may be taken on this node.
        if ctx.draining.load(std::sync::atomic::Ordering::SeqCst) {
            break;
        }

        warm = true; // instance is hot after the first dispatch

        // §IV-D: "When an already running invocation is finished, they
        // query whether the queue has invocations that have the same
        // configuration so that the worker node can reuse an existing
        // runtime instance." — batched: take up to `cap` matching
        // invocations, lingering (adaptively) for stragglers.
        let (leases, did_linger) =
            gather_reuse(&ctx, &reuse_filter, &variant, &device.id, cap);
        if leases.is_empty() {
            break;
        }
        lingered = did_linger;
        batch.clear();
        let mut rejected: Vec<Invocation> = Vec::new();
        for lease in leases {
            let mut next = lease.invocation;
            next.node = Some(ctx.node_id.clone());
            // `NStart` was stamped at lease-take time inside
            // gather_reuse, so the linger wait lands in the
            // queue→device split instead of vanishing.
            if next.stamps.n_start.is_none() {
                next.stamps.n_start = Some(ctx.clock.now());
            }
            if let Admission::Reject(reason) = ctx.policy.admit(&next, ctx.clock.now()) {
                next.status = Status::Failed(reason);
                rejected.push(next);
                continue;
            }
            batch.push(next);
        }
        ack_and_report_rejected(
            ctx.queue.as_ref(),
            ctx.completions.as_ref(),
            &ctx.node_id,
            ctx.cache.as_deref(),
            &ctx.gossiped,
            rejected,
        );
        if batch.is_empty() {
            break;
        }
    }
    drop(pooled);
    drop(slot);
}

/// The warm-reuse re-take, batched: grab whatever same-runtime work is
/// queued (up to the batch cap), then linger — park on the queue's
/// condvar/long-poll — for more while the aggregator's adaptive budget
/// lasts.  Returns the leases and whether any linger wait happened.
fn gather_reuse(
    ctx: &WorkerCtx,
    reuse: &TakeFilter,
    variant: &str,
    device_id: &str,
    max: usize,
) -> (Vec<Lease>, bool) {
    // Each lease gets `NStart` at its take time: invocations gathered
    // before a linger wait carry that wait in their queue→device split.
    let stamp = |ls: &mut [Lease], now: crate::util::SimTime| {
        for l in ls {
            l.invocation.stamps.n_start = Some(now);
        }
    };
    let mut leases = match ctx.queue.take_batch(reuse, max) {
        Ok(l) => l,
        Err(e) => {
            log::warn!("node {}: reuse take_batch failed: {e:#}", ctx.node_id);
            return (Vec::new(), false);
        }
    };
    stamp(&mut leases, ctx.clock.now());
    if leases.is_empty() {
        return (leases, false);
    }
    // One lane snapshot per gather keeps the per-lease budget probe
    // allocation- and lock-free; a sibling worker on a multi-slot device
    // may move the EWMA mid-gather, which is fine (see `lane_fill`).
    let fill = ctx.batcher.lane_fill(variant, device_id);
    let mut lingered = false;
    let mut waited = Duration::ZERO;
    while leases.len() < max {
        let Some(budget) =
            ctx.batcher.linger_budget_at(fill, max, leases.len(), waited)
        else {
            break;
        };
        lingered = true;
        // Budget is sim time; the queue parks in wall time.
        let wall = Duration::from_secs_f64(budget.as_secs_f64() / ctx.clock.scale());
        let t0 = std::time::Instant::now();
        let got = ctx.queue.take_timeout(reuse, wall);
        waited += Duration::from_secs_f64(t0.elapsed().as_secs_f64() * ctx.clock.scale());
        match got {
            Ok(Some(lease)) => {
                let from = leases.len();
                leases.push(lease);
                if leases.len() < max {
                    if let Ok(more) = ctx.queue.take_batch(reuse, max - leases.len()) {
                        leases.extend(more);
                    }
                }
                stamp(&mut leases[from..], ctx.clock.now());
            }
            // Timed out (budget spent) or errored: dispatch what we have.
            _ => break,
        }
    }
    (leases, lingered)
}

/// One device dispatch for the whole batch: fetch each dataset, run
/// `exec_batch` once, pace to the summed per-invocation service times,
/// persist each result.  Per-invocation fetch failures (missing
/// dataset) are removed from the batch and **fast-failed immediately**
/// (one `ack_batch` + reports) — the serial path never made them wait
/// for neighbours' pacing; an executor error fails the dispatch (the
/// all-or-nothing contract of
/// [`crate::runtime::Executor::infer_batch`]) and the members are then
/// re-run individually so one malformed input cannot poison its
/// neighbours.  Returns how many invocations actually reached the
/// device (0 = no dispatch ran), whether the serial isolation
/// fallback ran (stats must then record serial dispatches), and the
/// dispatch's device-program / pad-slot counts (DESIGN.md §16).
fn execute_batch(
    ctx: &WorkerCtx,
    device: &Arc<Device>,
    instance: &Arc<RuntimeInstance>,
    batch: &mut Vec<Invocation>,
) -> (usize, bool, usize, usize) {
    // Fetch the datasets (stateless workloads fetch their inputs, §IV-A).
    // Through the node's CachedStore this is an Arc clone on the warm
    // path, and the decoded-input cache skips the bytes→f32 pass when the
    // same buffer was already decoded on this node — a batch over one
    // dataset sends the same allocation N times, never copies.
    let mut inputs = Vec::with_capacity(batch.len());
    let mut kept: Vec<Invocation> = Vec::with_capacity(batch.len());
    let mut fetch_failed: Vec<Invocation> = Vec::new();
    for mut inv in batch.drain(..) {
        // Affinity accounting *before* the fetch fills the cache: was the
        // dataset already here?  A stale hot hint lands as a miss — the
        // read-through fetch below serves it from backing regardless.
        if let Some(cache) = &ctx.cache {
            ctx.affinity.record(cache.contains_cached(&inv.spec.dataset));
        }
        let fetched = ctx
            .store
            .get(&inv.spec.dataset)
            .with_context(|| format!("dataset {}", inv.spec.dataset));
        match fetched {
            Ok(data) => {
                inputs.push(ctx.decoded.get_or_decode(&inv.spec.dataset, &data));
                kept.push(inv);
            }
            Err(e) => {
                inv.status = Status::Failed(format!("{e:#}"));
                inv.stamps.n_end = Some(ctx.clock.now());
                fetch_failed.push(inv);
            }
        }
    }
    *batch = kept;
    ack_and_report_rejected(
        ctx.queue.as_ref(),
        ctx.completions.as_ref(),
        &ctx.node_id,
        ctx.cache.as_deref(),
        &ctx.gossiped,
        fetch_failed,
    );
    if batch.is_empty() {
        return (0, false, 0, 0);
    }
    // Every remaining batch entry is a device-batch member, index-aligned
    // with `inputs`.

    // Execute on the accelerator: one instance-thread hop, one dispatch.
    // Inputs are kept (Arc clones) for the failure-isolation fallback.
    let e_start = ctx.clock.now();
    for inv in batch.iter_mut() {
        inv.stamps.e_start = Some(e_start);
    }
    let outcome = instance.exec_batch(inputs.clone());

    // Pace to the device's calibrated service times: batching amortizes
    // *dispatch overhead*, never modeled device compute — each
    // invocation keeps its own lognormal sample (seeded from its own
    // id, exactly as the serial path sampled it) and the dispatch
    // occupies the device for the **sum** (DESIGN.md S1/§11).  The real
    // compute already consumed `compute_wall * scale` sim-ms; sleep the
    // remainder once.  `EEnd` stamps stagger cumulatively (the device
    // serves the batch members serially within the dispatch), stretched
    // proportionally when real compute overran the sampled total so the
    // stamps never claim the window ended before it did.
    let targets_ms: Vec<f64> = batch
        .iter()
        .map(|inv| {
            let mut rng = rng_for(&inv.id);
            device.profile.service.sample_ms(&mut rng)
        })
        .collect();
    let total_ms: f64 = targets_ms.iter().sum();
    let mut fallback = false;
    let mut programs = 0usize;
    let mut pad_slots = 0usize;
    match outcome {
        Ok(out) => {
            programs = out.programs;
            pad_slots = out.pad_slots;
            let spent_ms = out.compute_wall.as_secs_f64() * 1e3 * ctx.clock.scale();
            if total_ms > spent_ms {
                ctx.clock
                    .sleep(Duration::from_secs_f64((total_ms - spent_ms) / 1e3));
            }
            let stretch = if spent_ms > total_ms && total_ms > 0.0 {
                spent_ms / total_ms
            } else {
                1.0
            };
            let mut elapsed_ms = 0.0;
            for (i, inv) in batch.iter_mut().enumerate() {
                elapsed_ms += targets_ms[i];
                let e_end = crate::util::SimTime(
                    e_start.as_micros() + (elapsed_ms * stretch * 1e3) as u64,
                );
                // Turbofish pins the otherwise-unconstrained error type
                // of the generic result parameter.
                complete_member(ctx, inv, Ok::<_, anyhow::Error>(&out.outputs[i]), e_end);
            }
        }
        Err(e) if batch.len() == 1 => {
            // The device was handed one program even though it errored.
            programs = 1;
            let now = ctx.clock.now();
            complete_member(ctx, &mut batch[0], Err(e), now);
        }
        Err(_) => {
            // The dispatch is all-or-nothing, so one malformed input
            // failed the whole batch — isolate the culprit(s) by
            // re-running every member individually (exactly the
            // `max_batch = 1` serial path, pacing included), so
            // well-formed neighbours keep the outcome they would have
            // had without batching.
            fallback = true;
            for (i, inv) in batch.iter_mut().enumerate() {
                // Re-stamp EStart per re-run: the wait for preceding
                // members belongs to the queue→device split, not this
                // member's execution window.
                inv.stamps.e_start = Some(ctx.clock.now());
                let single = instance.exec(inputs[i].clone());
                if let Ok(one) = &single {
                    let spent_ms =
                        one.compute_wall.as_secs_f64() * 1e3 * ctx.clock.scale();
                    if targets_ms[i] > spent_ms {
                        ctx.clock.sleep(Duration::from_secs_f64(
                            (targets_ms[i] - spent_ms) / 1e3,
                        ));
                    }
                }
                let now = ctx.clock.now();
                complete_member(
                    ctx,
                    inv,
                    single.as_ref().map(|one| one.output.as_slice()),
                    now,
                );
            }
        }
    }
    (batch.len(), fallback, programs, pad_slots)
}

/// Terminal bookkeeping for one member — `EEnd` stamp, result
/// persistence, status — shared by the batched success path, the
/// single-member error path, and the isolation fallback, so the
/// serial-identical contract is structural rather than copy-kept.
fn complete_member(
    ctx: &WorkerCtx,
    inv: &mut Invocation,
    result: std::result::Result<&[f32], impl std::fmt::Display>,
    e_end: crate::util::SimTime,
) {
    match result {
        Ok(output) => {
            inv.stamps.e_end = Some(e_end);
            match persist_result(ctx, inv, output) {
                Ok(()) => inv.status = Status::Succeeded,
                Err(e) => inv.status = Status::Failed(format!("{e:#}")),
            }
        }
        // No `EEnd` on an executor failure — the device produced
        // nothing, and a stamp here would feed ~0 ms ELat samples into
        // the latency histograms (the serial path never stamped it).
        // `{:#}` keeps anyhow's cause chain, matching the serial path.
        Err(e) => inv.status = Status::Failed(format!("{e:#}")),
    }
}

/// Stamp the node's current hot-set summary onto an outgoing completion
/// report — the affinity gossip rides the existing completion path
/// (DESIGN.md §15), no new RPC.  No cache, no summary: the fields stay
/// empty/zero and are omitted on the wire.
fn stamp_hot_set(
    cache: Option<&CachedStore>,
    gossiped: &std::sync::atomic::AtomicU64,
    inv: &mut Invocation,
) {
    if let Some(cache) = cache {
        let (keys, generation) = cache.hot_keys(crate::scheduler::DEFAULT_HOT_SET);
        inv.hot_keys = keys;
        inv.hot_generation = generation;
        // This completion carries generation G: the manager's idle tick
        // need not re-gossip anything at or below it.
        gossiped.fetch_max(generation, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Batched admission-rejection epilogue shared by the manager's dispatch
/// loop and the worker's warm re-take: one `ack_batch` round trip, then
/// per-invocation completion reports.
pub(crate) fn ack_and_report_rejected(
    queue: &dyn InvocationQueue,
    completions: &dyn CompletionSink,
    node_id: &str,
    hot_from: Option<&CachedStore>,
    gossiped: &std::sync::atomic::AtomicU64,
    rejected: Vec<Invocation>,
) {
    if rejected.is_empty() {
        return;
    }
    let ids: Vec<String> = rejected.iter().map(|i| i.id.clone()).collect();
    if let Err(e) = queue.ack_batch(&ids) {
        log::warn!("node {node_id}: reject ack_batch failed: {e:#}");
    }
    for mut inv in rejected {
        stamp_hot_set(hot_from, gossiped, &mut inv);
        if let Err(e) = completions.report(inv) {
            log::warn!("node {node_id}: completion report failed: {e:#}");
        }
    }
}

/// Persist one invocation's output before terminating (§IV-A).
/// Detection-shaped outputs (. * 125 grid channels) are decoded + NMS'd;
/// anything else is stored raw (mock executors, foreign runtimes).
fn persist_result(ctx: &WorkerCtx, inv: &mut Invocation, output: &[f32]) -> Result<()> {
    let result_key = keys::result(&inv.id);
    let cfg = postprocess::DecodeConfig::default();
    let per_cell = cfg.anchors.len() * cfg.stride();
    let body: Vec<u8> = if output.len() >= per_cell
        && output.len() % per_cell == 0
        && is_square(output.len() / per_cell)
    {
        let cells = output.len() / per_cell;
        let g = (cells as f64).sqrt() as usize;
        let dets = postprocess::postprocess(output, g, g, &cfg);
        postprocess::detections_to_json(&dets)
            .to_string()
            .into_bytes()
    } else {
        output.iter().flat_map(|f| f.to_le_bytes()).collect()
    };
    ctx.store.put(&result_key, &body)?;
    inv.result_key = Some(result_key);
    Ok(())
}

fn is_square(n: usize) -> bool {
    let r = (n as f64).sqrt() as usize;
    r * r == n
}

/// Fail a whole leased batch before execution (variant miss, cold-start
/// exhaustion): one `ack_batch` round trip, per-invocation reports.
fn fail_batch(ctx: &WorkerCtx, invs: Vec<Invocation>, reason: &str) {
    let now = ctx.clock.now();
    let failed: Vec<Invocation> = invs
        .into_iter()
        .map(|mut inv| {
            inv.status = Status::Failed(reason.to_string());
            inv.stamps.n_end = Some(now);
            inv
        })
        .collect();
    ack_and_report_rejected(
        ctx.queue.as_ref(),
        ctx.completions.as_ref(),
        &ctx.node_id,
        ctx.cache.as_deref(),
        &ctx.gossiped,
        failed,
    );
}

/// Exposed for scheduler integration tests.  A borrowed-through
/// [`HashSet`] end to end: no `Vec` rebuild between the pool probe and
/// the [`TakeFilter`] it feeds.
pub fn warm_set(registry: &DeviceRegistry, pool: &InstancePool) -> HashSet<String> {
    warm_runtimes(registry, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::paper_all_accel;
    use crate::runtime::instance::MockExecutor;

    #[test]
    fn rng_for_is_deterministic_per_id() {
        let a = rng_for("inv-1").next_u64();
        let b = rng_for("inv-1").next_u64();
        let c = rng_for("inv-2").next_u64();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn is_square_checks() {
        assert!(is_square(1));
        assert!(is_square(4));
        assert!(!is_square(2));
        assert!(!is_square(8));
    }

    #[test]
    fn warm_set_is_a_set() {
        let reg = paper_all_accel();
        let pool = InstancePool::new(8);
        assert!(warm_set(&reg, &pool).is_empty());
        drop(
            pool.acquire_or_start("tinyyolo-gpu", "gpu1", || {
                RuntimeInstance::start(
                    "tinyyolo-gpu",
                    "gpu1",
                    MockExecutor::factory(1.0, Duration::ZERO),
                )
            })
            .unwrap(),
        );
        assert_eq!(
            warm_set(&reg, &pool),
            HashSet::from(["tinyyolo".to_string()])
        );
    }

    #[test]
    fn pick_slot_prefers_warm_device_on_warm_hit() {
        let reg = paper_all_accel();
        let pool = InstancePool::new(8);
        // make gpu1 warm for the gpu variant
        drop(
            pool.acquire_or_start("tinyyolo-gpu", "gpu1", || {
                RuntimeInstance::start(
                    "tinyyolo-gpu",
                    "gpu1",
                    MockExecutor::factory(1.0, Duration::ZERO),
                )
            })
            .unwrap(),
        );
        let slot = pick_slot(&reg, &pool, "tinyyolo", true).unwrap();
        assert_eq!(slot.device().id, "gpu1", "warm-hit placement follows the warm instance");
        // non-warm pick just wants capacity
        let slot2 = pick_slot(&reg, &pool, "tinyyolo", false).unwrap();
        assert!(["gpu0", "gpu1", "vpu0"].contains(&slot2.device().id.as_str()));
    }

    #[test]
    fn pick_slot_none_when_saturated() {
        let reg = paper_all_accel();
        let pool = InstancePool::new(8);
        let mut guards = Vec::new();
        while let Some(g) = reg.acquire_for("tinyyolo") {
            guards.push(g);
        }
        assert!(pick_slot(&reg, &pool, "tinyyolo", false).is_none());
    }
}
