//! Per-invocation worker: the node's execution path.
//!
//! A worker owns one accelerator slot for its lifetime.  It checks out a
//! runtime instance (warm from the pool, or cold-started from the
//! reserve with the profile's cold-start pacing), then loops:
//!
//!   fetch dataset → execute via PJRT → pace to the device's service
//!   time → postprocess + persist result → ack → signal completion →
//!   same-config re-take (§IV-D warm reuse) → repeat until the queue has
//!   no matching work.

use crate::accel::{Device, DeviceRegistry, SlotGuard};
use crate::events::{Invocation, Status};
use crate::node::CompletionSink;
use crate::postprocess;
use crate::queue::{InvocationQueue, TakeFilter};
use crate::runtime::{InstancePool, RuntimeInstance};
use crate::scheduler::{warm_runtimes, Admission, Policy};
use crate::store::{keys, DecodedCache, ObjectStore};
use crate::util::{Clock, Rng};
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;
use std::time::Duration;

/// Shared services a worker needs.
pub struct WorkerCtx {
    pub node_id: String,
    pub pool: Arc<InstancePool>,
    pub queue: Arc<dyn InvocationQueue>,
    /// The node's store view — a node-local [`crate::store::CachedStore`]
    /// when the cache is enabled (see [`crate::node::spawn_node`]).
    pub store: Arc<dyn ObjectStore>,
    /// Node-wide bytes→f32 cache: the decode pass runs once per dataset
    /// buffer per node, not once per invocation.
    pub decoded: Arc<DecodedCache>,
    pub clock: Arc<dyn Clock>,
    pub policy: Arc<dyn Policy>,
    pub reserve: Arc<crate::node::InstanceReserve>,
    pub completions: Arc<dyn CompletionSink>,
    /// Node decommission flag: set, workers finish their current
    /// invocation but skip the §IV-D warm re-take (graceful scale-in
    /// must stop *all* lease-taking paths, not just the manager poll).
    pub draining: Arc<std::sync::atomic::AtomicBool>,
}

/// Pick a device + slot for `runtime`.  When the lease was a warm hit,
/// prefer a device that actually holds an idle warm instance; otherwise
/// least-loaded wins (§IV-C: the node is free to choose).
pub fn pick_slot(
    registry: &DeviceRegistry,
    pool: &InstancePool,
    runtime: &str,
    warm_hit: bool,
) -> Option<SlotGuard> {
    if warm_hit {
        for d in registry.candidates(runtime) {
            let has_warm = d
                .profile
                .variant_for(runtime)
                .map(|v| pool.has_idle(v, &d.id))
                .unwrap_or(false);
            if has_warm {
                if let Some(guard) = d.try_acquire() {
                    return Some(guard);
                }
            }
        }
    }
    registry.acquire_for(runtime)
}

/// Deterministic per-invocation RNG (service-time jitter reproducibility).
fn rng_for(invocation_id: &str) -> Rng {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in invocation_id.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    Rng::new(h)
}

/// Entry point for a worker thread: run the leased invocation, then drain
/// same-config work while the instance is hot.
pub fn run_invocations(ctx: WorkerCtx, first: Invocation, slot: SlotGuard) {
    let device = slot.device().clone();
    let runtime = first.spec.runtime.clone();

    // Resolve the accelerator-specific implementation variant.
    let Some(variant) = device.profile.variant_for(&runtime).map(String::from) else {
        fail(&ctx, first, format!("device {} does not implement {runtime}", device.id));
        return;
    };

    // Check out an instance: warm from the pool, or cold via the reserve
    // with the profile's cold-start pacing applied in sim time.  The
    // reserve can be transiently empty while another worker on this
    // device is between "finished executing" and "returned the instance
    // to the pool" — retry briefly (a warm instance or reserve slot shows
    // up as soon as that worker unwinds) before declaring failure.
    let mut pooled = None;
    let mut last_err = None;
    for _attempt in 0..50 {
        let attempt = {
            let reserve = ctx.reserve.clone();
            let clock = ctx.clock.clone();
            let profile = device.profile.clone();
            let v = variant.clone();
            let d = device.id.clone();
            ctx.pool.acquire_or_start(&variant, &device.id, move || {
                // Pop first (cheap, fallible), pace the cold start after.
                let instance = reserve.pop(&v, &d).ok_or_else(|| {
                    anyhow!("instance reserve exhausted for {v} on {d}")
                })?;
                clock.sleep(Duration::from_secs_f64(profile.cold_start_ms / 1e3));
                Ok(instance)
            })
        };
        match attempt {
            Ok(p) => {
                pooled = Some(p);
                break;
            }
            Err(e) => {
                last_err = Some(e);
                ctx.clock.sleep(Duration::from_millis(50));
            }
        }
    }
    let pooled = match pooled {
        Some(p) => p,
        None => {
            fail(
                &ctx,
                first,
                format!(
                    "cold start failed after retries: {:#}",
                    last_err.unwrap_or_else(|| anyhow!("unknown"))
                ),
            );
            return;
        }
    };

    let mut inv = first;
    let mut warm = pooled.warm;
    // Built once: the §IV-D same-configuration reuse query is issued after
    // every completion, so keep it out of the drain loop.
    let reuse_filter = TakeFilter::warm_reuse(&runtime);
    loop {
        inv.accelerator = Some(device.id.clone());
        inv.variant = Some(variant.clone());
        inv.warm = warm;
        match execute_one(&ctx, &device, &pooled.instance, &mut inv) {
            Ok(()) => {
                inv.status = Status::Succeeded;
            }
            Err(e) => {
                inv.status = Status::Failed(format!("{e:#}"));
            }
        }
        inv.stamps.n_end = Some(ctx.clock.now());
        let _ = ctx.queue.ack(&inv.id);
        if let Err(e) = ctx.completions.report(inv) {
            log::warn!("node {}: completion report failed: {e:#}", ctx.node_id);
        }

        // Decommissioned mid-drain: the lease just served is done; no
        // further work may be taken on this node.
        if ctx.draining.load(std::sync::atomic::Ordering::SeqCst) {
            break;
        }

        // §IV-D: "When an already running invocation is finished, they
        // query whether the queue has invocations that have the same
        // configuration so that the worker node can reuse an existing
        // runtime instance."
        match ctx.queue.take(&reuse_filter) {
            Ok(Some(lease)) => {
                let mut next = lease.invocation;
                next.node = Some(ctx.node_id.clone());
                next.stamps.n_start = Some(ctx.clock.now());
                if let Admission::Reject(reason) = ctx.policy.admit(&next, ctx.clock.now()) {
                    next.status = Status::Failed(reason);
                    let _ = ctx.queue.ack(&next.id);
                    let _ = ctx.completions.report(next);
                    break;
                }
                inv = next;
                warm = true; // instance is hot by construction
            }
            _ => break,
        }
    }
    drop(pooled);
    drop(slot);
}

/// One execution: fetch → infer → pace → persist.
fn execute_one(
    ctx: &WorkerCtx,
    device: &Arc<Device>,
    instance: &Arc<RuntimeInstance>,
    inv: &mut Invocation,
) -> Result<()> {
    // Fetch the dataset (stateless workloads fetch their inputs, §IV-A).
    // Through the node's CachedStore this is an Arc clone on the warm
    // path, and the decoded-input cache skips the bytes→f32 pass when the
    // same buffer was already decoded on this node.
    let data = ctx
        .store
        .get(&inv.spec.dataset)
        .with_context(|| format!("dataset {}", inv.spec.dataset))?;
    let input = ctx.decoded.get_or_decode(&inv.spec.dataset, &data);

    // Execute on the accelerator (shared buffer — no per-invocation copy).
    inv.stamps.e_start = Some(ctx.clock.now());
    let outcome = instance.exec(input)?;

    // Pace to the device's calibrated service time: the real PJRT compute
    // already consumed `compute_wall * scale` sim-ms; sleep the remainder
    // of the sampled lognormal service time (DESIGN.md S1).
    let mut rng = rng_for(&inv.id);
    let target_ms = device.profile.service.sample_ms(&mut rng);
    let spent_ms = outcome.compute_wall.as_secs_f64() * 1e3 * ctx.clock.scale();
    if target_ms > spent_ms {
        ctx.clock
            .sleep(Duration::from_secs_f64((target_ms - spent_ms) / 1e3));
    }
    inv.stamps.e_end = Some(ctx.clock.now());

    // Persist the result before terminating (§IV-A).  Detection-shaped
    // outputs (. * 125 grid channels) are decoded + NMS'd; anything else
    // is stored raw (mock executors, foreign runtimes).
    let result_key = keys::result(&inv.id);
    let cfg = postprocess::DecodeConfig::default();
    let per_cell = cfg.anchors.len() * cfg.stride();
    let body: Vec<u8> = if outcome.output.len() >= per_cell
        && outcome.output.len() % per_cell == 0
        && is_square(outcome.output.len() / per_cell)
    {
        let cells = outcome.output.len() / per_cell;
        let g = (cells as f64).sqrt() as usize;
        let dets = postprocess::postprocess(&outcome.output, g, g, &cfg);
        postprocess::detections_to_json(&dets)
            .to_string()
            .into_bytes()
    } else {
        outcome
            .output
            .iter()
            .flat_map(|f| f.to_le_bytes())
            .collect()
    };
    ctx.store.put(&result_key, &body)?;
    inv.result_key = Some(result_key);
    Ok(())
}

fn is_square(n: usize) -> bool {
    let r = (n as f64).sqrt() as usize;
    r * r == n
}

fn fail(ctx: &WorkerCtx, mut inv: Invocation, reason: String) {
    inv.status = Status::Failed(reason);
    inv.stamps.n_end = Some(ctx.clock.now());
    let _ = ctx.queue.ack(&inv.id);
    let _ = ctx.completions.report(inv);
}

/// Exposed for scheduler integration tests.
pub fn warm_set(registry: &DeviceRegistry, pool: &InstancePool) -> Vec<String> {
    warm_runtimes(registry, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::paper_all_accel;
    use crate::runtime::instance::MockExecutor;

    #[test]
    fn rng_for_is_deterministic_per_id() {
        let a = rng_for("inv-1").next_u64();
        let b = rng_for("inv-1").next_u64();
        let c = rng_for("inv-2").next_u64();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn is_square_checks() {
        assert!(is_square(1));
        assert!(is_square(4));
        assert!(!is_square(2));
        assert!(!is_square(8));
    }

    #[test]
    fn pick_slot_prefers_warm_device_on_warm_hit() {
        let reg = paper_all_accel();
        let pool = InstancePool::new(8);
        // make gpu1 warm for the gpu variant
        drop(
            pool.acquire_or_start("tinyyolo-gpu", "gpu1", || {
                RuntimeInstance::start(
                    "tinyyolo-gpu",
                    "gpu1",
                    MockExecutor::factory(1.0, Duration::ZERO),
                )
            })
            .unwrap(),
        );
        let slot = pick_slot(&reg, &pool, "tinyyolo", true).unwrap();
        assert_eq!(slot.device().id, "gpu1", "warm-hit placement follows the warm instance");
        // non-warm pick just wants capacity
        let slot2 = pick_slot(&reg, &pool, "tinyyolo", false).unwrap();
        assert!(["gpu0", "gpu1", "vpu0"].contains(&slot2.device().id.as_str()));
    }

    #[test]
    fn pick_slot_none_when_saturated() {
        let reg = paper_all_accel();
        let pool = InstancePool::new(8);
        let mut guards = Vec::new();
        while let Some(g) = reg.acquire_for("tinyyolo") {
            guards.push(g);
        }
        assert!(pick_slot(&reg, &pool, "tinyyolo", false).is_none());
    }
}
