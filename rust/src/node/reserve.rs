//! Pre-built runtime-instance reserve.
//!
//! Real XLA compilation of an AOT artifact costs ~1 s of wall clock.
//! Under the time-scaled experiment clock (DESIGN.md S6) that second
//! would masquerade as minutes of *simulated* time and corrupt the
//! protocol, so the testbed separates the two costs:
//!
//! * **artifact compilation** (an engineering cost the paper never
//!   measures — its ONNX models are equally pre-deployed) happens once at
//!   node startup, off the experiment clock, via [`InstanceReserve::prewarm_pjrt`];
//! * **cold start** (what the paper *does* model: process spawn + model
//!   load on the accelerator) is paced per [`crate::accel::AcceleratorProfile::cold_start_ms`]
//!   in sim time when a worker pops an instance from the reserve.
//!
//! The reserve is just a typed bag of stopped-warm instances keyed by
//! (variant, device).

use crate::accel::DeviceRegistry;
use crate::runtime::{RuntimeBundle, RuntimeInstance};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Pre-built instances keyed by (variant, device id).
#[derive(Default)]
pub struct InstanceReserve {
    inner: Mutex<HashMap<(String, String), Vec<RuntimeInstance>>>,
}

impl InstanceReserve {
    pub fn new() -> Arc<InstanceReserve> {
        Arc::new(InstanceReserve::default())
    }

    pub fn add(&self, instance: RuntimeInstance) {
        let key = (instance.variant.clone(), instance.device_id.clone());
        self.inner
            .lock()
            .expect("reserve poisoned")
            .entry(key)
            .or_default()
            .push(instance);
    }

    /// Pop a pre-built instance for (variant, device), if any.
    pub fn pop(&self, variant: &str, device_id: &str) -> Option<RuntimeInstance> {
        self.inner
            .lock()
            .expect("reserve poisoned")
            .get_mut(&(variant.to_string(), device_id.to_string()))
            .and_then(|v| v.pop())
    }

    pub fn count(&self, variant: &str, device_id: &str) -> usize {
        self.inner
            .lock()
            .expect("reserve poisoned")
            .get(&(variant.to_string(), device_id.to_string()))
            .map(|v| v.len())
            .unwrap_or(0)
    }

    pub fn total(&self) -> usize {
        self.inner
            .lock()
            .expect("reserve poisoned")
            .values()
            .map(|v| v.len())
            .sum()
    }

    /// Build PJRT instances for every (device, variant, slot) of the
    /// registry from `bundle` — the node-startup compile pass.  Returns
    /// the number of instances built.
    ///
    /// Requires the `pjrt` cargo feature (the `xla` bindings); without it
    /// this fails at call time with a pointer at the mock engine.
    #[cfg(feature = "pjrt")]
    pub fn prewarm_pjrt(&self, registry: &DeviceRegistry, bundle: &RuntimeBundle) -> Result<usize> {
        use crate::runtime::PjrtExecutor;
        let mut built = 0;
        for device in registry.devices() {
            for (_runtime, variant) in &device.profile.runtimes {
                if bundle.artifact(variant).is_err() {
                    continue; // bundle doesn't implement this variant
                }
                for _slot in 0..device.profile.slots {
                    let b = bundle.clone();
                    let v = variant.clone();
                    let factory: crate::runtime::ExecutorFactory = Box::new(move || {
                        Ok(Box::new(PjrtExecutor::compile(&b, &v)?)
                            as Box<dyn crate::runtime::Executor>)
                    });
                    self.add(RuntimeInstance::start(
                        variant.clone(),
                        device.id.clone(),
                        factory,
                    )?);
                    built += 1;
                }
            }
        }
        Ok(built)
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn prewarm_pjrt(&self, registry: &DeviceRegistry, bundle: &RuntimeBundle) -> Result<usize> {
        let _ = (registry, bundle);
        anyhow::bail!(
            "hardless was built without the `pjrt` feature; \
             rebuild with `--features pjrt` or use the mock engine"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::instance::MockExecutor;
    use std::time::Duration;

    fn mock(variant: &str, device: &str) -> RuntimeInstance {
        RuntimeInstance::start(variant, device, MockExecutor::factory(1.0, Duration::ZERO))
            .unwrap()
    }

    #[test]
    fn add_pop_count() {
        let r = InstanceReserve::new();
        r.add(mock("v1", "gpu0"));
        r.add(mock("v1", "gpu0"));
        r.add(mock("v2", "vpu0"));
        assert_eq!(r.count("v1", "gpu0"), 2);
        assert_eq!(r.total(), 3);
        assert!(r.pop("v1", "gpu0").is_some());
        assert_eq!(r.count("v1", "gpu0"), 1);
        assert!(r.pop("v1", "vpu0").is_none(), "keyed by device too");
        assert!(r.pop("v2", "vpu0").is_some());
        assert!(r.pop("v2", "vpu0").is_none(), "exhausted");
    }

    #[test]
    #[cfg(feature = "pjrt")]
    fn prewarm_builds_slots_per_device_variant() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let bundle =
            RuntimeBundle::load_dir("tinyyolo", crate::runtime::artifacts_dir()).unwrap();
        let registry = crate::accel::paper_all_accel();
        let reserve = InstanceReserve::new();
        let built = reserve.prewarm_pjrt(&registry, &bundle).unwrap();
        // 2 GPUs x 2 slots x 1 variant + 1 VPU x 1 slot x 1 variant = 5
        assert_eq!(built, 5);
        assert_eq!(reserve.count("tinyyolo-gpu", "gpu0"), 2);
        assert_eq!(reserve.count("tinyyolo-vpu", "vpu0"), 1);
        // popped instances actually serve inference
        let inst = reserve.pop("tinyyolo-gpu", "gpu1").unwrap();
        let out = inst.exec(vec![0.1f32; 64 * 64 * 3]).unwrap();
        assert_eq!(out.output.len(), 2 * 2 * 125);
    }
}
