//! Local (in-process) implementation of [`HardlessClient`].
//!
//! [`Cluster`] *is* a client: submissions go through its coordinator,
//! results come from its object store — the same calls
//! [`super::RemoteClient`] makes over TCP, without the wire.

use super::{ClusterStats, HardlessClient, SubmissionStatus};
use crate::coordinator::Cluster;
use crate::events::{EventSpec, Invocation};
use crate::store::{Blob, ObjectStore};
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

impl HardlessClient for Cluster {
    fn submit(&self, spec: EventSpec) -> Result<String> {
        self.coordinator.submit(spec)
    }

    fn submit_batch(&self, specs: Vec<EventSpec>) -> Result<Vec<String>> {
        // One tracking-lock hold + one queue publish_batch, mirroring the
        // gateway's single-RPC path.
        self.coordinator.submit_batch(specs)
    }

    fn status(&self, id: &str) -> Result<SubmissionStatus> {
        Ok(SubmissionStatus::resolve(&self.coordinator, id))
    }

    fn wait(&self, id: &str, timeout: Duration) -> Result<Option<Invocation>> {
        Ok(self.coordinator.wait_for(id, timeout))
    }

    fn fetch_result(&self, id: &str) -> Result<Option<Blob>> {
        match self.coordinator.lookup(id).1.and_then(|i| i.result_key) {
            Some(key) => Ok(Some(self.store.get(&key)?)),
            None => Ok(None),
        }
    }

    fn cluster_stats(&self) -> Result<ClusterStats> {
        let mut stats = ClusterStats::gather(&self.coordinator)?;
        // In-process deployments see their nodes, so the node-local
        // store-cache and micro-batch counters aggregate here (a remote
        // gateway cannot), and the autoscale section comes straight from
        // the controller.
        stats.cache = self.node_cache_stats();
        stats.affinity = self.affinity_totals();
        stats.autoscale = self.autoscale_stats();
        stats.batch = self.batch_totals();
        Ok(stats)
    }

    fn list_runtimes(&self) -> Result<Vec<String>> {
        Ok(self.supported_runtimes())
    }

    fn submit_pipeline(&self, spec: crate::pipeline::PipelineSpec) -> Result<String> {
        self.coordinator.submit_pipeline(spec)
    }

    fn pipeline_status(&self, id: &str) -> Result<Option<crate::pipeline::PipelineStatus>> {
        Ok(self.coordinator.pipeline_status(id))
    }
}

/// An owning handle implementing [`HardlessClient`] over a shared
/// [`Cluster`] — for call sites that need a `'static` trait object (e.g.
/// handing one client to several submitter threads).
#[derive(Clone)]
pub struct LocalClient {
    cluster: Arc<Cluster>,
}

impl LocalClient {
    pub fn new(cluster: Arc<Cluster>) -> LocalClient {
        LocalClient { cluster }
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }
}

impl HardlessClient for LocalClient {
    fn submit(&self, spec: EventSpec) -> Result<String> {
        HardlessClient::submit(&*self.cluster, spec)
    }

    fn submit_batch(&self, specs: Vec<EventSpec>) -> Result<Vec<String>> {
        HardlessClient::submit_batch(&*self.cluster, specs)
    }

    fn status(&self, id: &str) -> Result<SubmissionStatus> {
        HardlessClient::status(&*self.cluster, id)
    }

    fn wait(&self, id: &str, timeout: Duration) -> Result<Option<Invocation>> {
        HardlessClient::wait(&*self.cluster, id, timeout)
    }

    fn fetch_result(&self, id: &str) -> Result<Option<Blob>> {
        HardlessClient::fetch_result(&*self.cluster, id)
    }

    fn cluster_stats(&self) -> Result<ClusterStats> {
        HardlessClient::cluster_stats(&*self.cluster)
    }

    fn list_runtimes(&self) -> Result<Vec<String>> {
        HardlessClient::list_runtimes(&*self.cluster)
    }

    fn submit_pipeline(&self, spec: crate::pipeline::PipelineSpec) -> Result<String> {
        HardlessClient::submit_pipeline(&*self.cluster, spec)
    }

    fn pipeline_status(&self, id: &str) -> Result<Option<crate::pipeline::PipelineStatus>> {
        HardlessClient::pipeline_status(&*self.cluster, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::paper_all_accel;
    use crate::coordinator::cluster::ExecutorKind;
    use crate::events::Status;

    fn mock_cluster() -> Arc<Cluster> {
        Arc::new(
            Cluster::builder()
                .time_scale(200.0)
                .executors(ExecutorKind::Mock {
                    scale: 2.0,
                    delay: Duration::from_millis(1),
                })
                .node("node-1", paper_all_accel())
                .build()
                .unwrap(),
        )
    }

    #[test]
    fn local_client_full_lifecycle() {
        let cluster = mock_cluster();
        let client = LocalClient::new(cluster.clone());
        assert_eq!(client.status("inv-nope").unwrap(), SubmissionStatus::Unknown);
        assert_eq!(client.list_runtimes().unwrap(), vec!["tinyyolo".to_string()]);

        let key = cluster.upload_dataset("img", &[1.0, 2.0, 3.0]).unwrap();
        let id = client.submit(EventSpec::new("tinyyolo", &key)).unwrap();
        let inv = client
            .wait(&id, Duration::from_secs(15))
            .unwrap()
            .expect("completes");
        assert_eq!(inv.status, Status::Succeeded);
        assert!(matches!(
            client.status(&id).unwrap(),
            SubmissionStatus::Done(_)
        ));

        // mock executor: output = input * 2
        let body = client.fetch_result(&id).unwrap().expect("result persisted");
        let floats: Vec<f32> = body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(floats, vec![2.0, 4.0, 6.0]);

        let stats = client.cluster_stats().unwrap();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.succeeded, 1);
        assert_eq!(stats.inflight, 0);
        assert_eq!(stats.queue.acked, 1);
        cluster.shutdown();
    }

    #[test]
    fn batch_submission_via_trait_object() {
        let cluster = mock_cluster();
        let client: Arc<dyn HardlessClient> = Arc::new(LocalClient::new(cluster.clone()));
        let key = cluster.upload_dataset("img", &[1.0; 4]).unwrap();
        let ids = client
            .submit_batch((0..5).map(|_| EventSpec::new("tinyyolo", &key)).collect())
            .unwrap();
        assert_eq!(ids.len(), 5);
        for id in &ids {
            let inv = client
                .wait(id, Duration::from_secs(20))
                .unwrap()
                .expect("completes");
            assert_eq!(inv.status, Status::Succeeded);
        }
        assert_eq!(client.cluster_stats().unwrap().succeeded, 5);
        cluster.shutdown();
    }

    #[test]
    fn pipeline_chains_results_through_the_store() {
        use crate::pipeline::{PipelineSpec, PipelineState, StageSpec};
        // Two chained stages on the mock executor (output = input × 2):
        // stage 2 consumes stage 1's *result object* as its dataset, so
        // the final result is input × 4 — proof the intermediate flowed
        // node→store→node, never through this client.
        let cluster = mock_cluster();
        let client = LocalClient::new(cluster.clone());
        assert!(client.pipeline_status("pipe-nope").unwrap().is_none());
        let key = cluster.upload_dataset("img", &[1.0, 2.0, 3.0]).unwrap();
        let pid = client
            .submit_pipeline(
                PipelineSpec::new(&key)
                    .stage(StageSpec::new("double", "tinyyolo"))
                    .stage(StageSpec::new("quad", "tinyyolo").after(["double"])),
            )
            .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        let st = loop {
            let st = client.pipeline_status(&pid).unwrap().expect("tracked");
            if st.state != PipelineState::Running {
                break st;
            }
            assert!(std::time::Instant::now() < deadline, "stuck: {st:?}");
            std::thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(st.state, PipelineState::Succeeded);
        let first = st.stages[0].invocation_id.clone().unwrap();
        assert_eq!(
            st.stages[1].dataset.as_deref(),
            Some(crate::store::keys::result(&first).as_str()),
            "stage 2 ran on stage 1's result key"
        );
        let last = st.stages[1].invocation_id.clone().unwrap();
        let body = client.fetch_result(&last).unwrap().expect("final result");
        let floats: Vec<f32> = body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(floats, vec![4.0, 8.0, 12.0], "×2 twice");
        cluster.shutdown();
    }

    #[test]
    fn fetch_result_none_while_pending_or_unknown() {
        let cluster = mock_cluster();
        let client = LocalClient::new(cluster.clone());
        assert!(client.fetch_result("inv-unknown").unwrap().is_none());
        cluster.shutdown();
    }
}
