//! The gateway: the coordinator hosted as a TCP service, plus the remote
//! client that speaks to it.
//!
//! Deployment shape (paper Fig. 2, distributed): `hardless serve` runs
//! the shared queue, the object store, and this gateway; node managers
//! take work from the queue and report completions to the gateway over
//! RPC; benchmark clients submit and wait through [`RemoteClient`].  The
//! gateway stamps `REnd` when a completion report arrives — the paper's
//! "result received by the benchmark client" moment — and feeds its
//! [`MetricsHub`], so distributed runs produce the same §V-A series as
//! in-process ones.

use super::{ClusterStats, HardlessClient, SubmissionStatus};
use crate::autoscale::{AdvisoryExecutor, AutoscaleConfig, Autoscaler, Signals};
use crate::coordinator::Coordinator;
use crate::events::{EventSpec, Invocation};
use crate::json::Json;
use crate::metrics::MetricsHub;
use crate::node::CompletionSink;
use crate::pipeline::{PipelineSpec, PipelineStatus};
use crate::queue::InvocationQueue;
use crate::store::{Blob, ObjectStore};
use crate::util::Clock;
use crate::wire::{
    poll_chunked, ClientConfig, DeferHandler, Outcome, Park, RpcClient, RpcConfig, RpcCounters,
    RpcServer, LONG_POLL_CHUNK,
};
use anyhow::{anyhow, Result};
use std::net::ToSocketAddrs;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server-side cap on one blocking `wait` chunk.  Clients loop over
/// chunks until their own deadline ([`poll_chunked`]), so this only
/// bounds how long a single RPC may hold its connection thread.
pub const WAIT_CHUNK: Duration = LONG_POLL_CHUNK;

/// Gateway tunables.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Runtimes advertised by `list_runtimes` in addition to bundles
    /// published in the object store (mock/demo deployments have no
    /// published bundle to discover).
    pub announce_runtimes: Vec<String>,
    /// Housekeeping period (sim time): lease reaping + `#queued` gauge
    /// sampling (paper §V-A).
    pub housekeeping_interval: Duration,
    /// Run the elasticity controller in **advisory** mode: the gateway
    /// cannot provision remote nodes, so decisions move a virtual node
    /// count (an [`AdvisoryExecutor`]), are logged, and surface in the
    /// `stats` RPC's `autoscale` section — an operator or external
    /// orchestrator watching `hardless status` acts on them.  The
    /// controller ticks on the housekeeping interval.
    pub autoscale: Option<AutoscaleConfig>,
    /// RPC transport tuning (backend selection, worker pool size) for
    /// the gateway's own server.
    pub rpc: RpcConfig,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig {
            announce_runtimes: Vec::new(),
            housekeeping_interval: Duration::from_secs(1),
            autoscale: None,
            rpc: RpcConfig::default(),
        }
    }
}

/// The coordinator as a network service.
pub struct GatewayServer {
    rpc: RpcServer,
    coordinator: Arc<Coordinator>,
    metrics: Arc<MetricsHub>,
    stop: Arc<AtomicBool>,
    housekeeper: Option<std::thread::JoinHandle<()>>,
}

impl GatewayServer {
    /// Bind the gateway on `addr` (port 0 for ephemeral) over a queue and
    /// store that the node fleet shares.
    pub fn serve(
        addr: &str,
        queue: Arc<dyn InvocationQueue>,
        store: Arc<dyn ObjectStore>,
        clock: Arc<dyn Clock>,
        config: GatewayConfig,
    ) -> Result<GatewayServer> {
        let metrics = Arc::new(MetricsHub::new());
        let coordinator = Coordinator::new(
            queue.clone(),
            clock.clone(),
            metrics.clone(),
            Some(store.clone()),
        );
        let completions = coordinator.completion_sender();
        let mut announce = config.announce_runtimes.clone();
        announce.sort();
        announce.dedup();

        // Advisory elasticity controller (no node provisioning from the
        // gateway; see GatewayConfig::autoscale).
        let autoscale: Option<(Arc<Autoscaler>, Arc<AdvisoryExecutor>)> =
            match config.autoscale.as_ref() {
                Some(cfg) => {
                    cfg.validate()?;
                    Some((
                        Arc::new(Autoscaler::new(cfg.clone())),
                        Arc::new(AdvisoryExecutor::new(cfg.min_nodes, cfg.min_nodes)),
                    ))
                }
                None => None,
            };

        // One shared counter block: the transport updates it, and the
        // gateway's own `stats` handler snapshots it into
        // `ClusterStats.rpc` — the server reporting on the server it
        // runs inside.
        let rpc_counters = config.rpc.counters.clone().unwrap_or_default();

        let handler: DeferHandler = {
            let coordinator = coordinator.clone();
            let store = store.clone();
            let autoscale = autoscale.clone();
            let rpc_counters = rpc_counters.clone();
            Arc::new(move |method, params, _blob| match method {
                "submit" => {
                    let spec = EventSpec::from_json(params.req("spec")?)?;
                    let id = coordinator.submit(spec)?;
                    Ok(Outcome::Ready(Json::obj().set("id", id), None))
                }
                "submit_batch" => {
                    // One RPC, one tracking-lock hold, one queue
                    // publish_batch — the whole batch is amortized.
                    let mut specs = Vec::new();
                    for spec in params.arr_of("specs")? {
                        specs.push(EventSpec::from_json(spec)?);
                    }
                    let ids = coordinator.submit_batch(specs)?;
                    let ids = ids.into_iter().map(Json::Str).collect();
                    Ok(Outcome::Ready(Json::obj().set("ids", Json::Arr(ids)), None))
                }
                "status" => {
                    let status =
                        SubmissionStatus::resolve(&coordinator, params.str_of("id")?);
                    Ok(Outcome::Ready(status.to_json(), None))
                }
                "submit_pipeline" => {
                    // One RPC for the whole DAG: the coordinator chains
                    // every successor stage server-side off completion
                    // reports — no further client round trips.
                    let spec = PipelineSpec::from_json(params.req("pipeline")?)?;
                    let id = coordinator.submit_pipeline(spec)?;
                    Ok(Outcome::Ready(Json::obj().set("id", id), None))
                }
                "pipeline_status" => {
                    match coordinator.pipeline_status(params.str_of("id")?) {
                        Some(status) => Ok(Outcome::Ready(status.to_json(), None)),
                        None => Ok(Outcome::Ready(Json::Null, None)),
                    }
                }
                "wait" => {
                    // Server-side blocking wait, reactor edition: probe
                    // the coordinator now, and if the result isn't in
                    // yet park the request as a reactor registration —
                    // a waiting benchmark client costs a waiter entry,
                    // not a connection thread.
                    let id = params.str_of("id")?.to_string();
                    let ms = params
                        .u64_of("timeout_ms")
                        .unwrap_or(0)
                        .min(WAIT_CHUNK.as_millis() as u64);
                    if let Some(inv) = coordinator.wait_for(&id, Duration::ZERO) {
                        return Ok(Outcome::Ready(inv.to_json(), None));
                    }
                    if ms == 0 {
                        return Ok(Outcome::Ready(Json::Null, None));
                    }
                    let deadline = Instant::now() + Duration::from_millis(ms);
                    let coordinator = coordinator.clone();
                    Ok(Outcome::Park(Park::new(deadline, move || {
                        Ok(coordinator
                            .wait_for(&id, Duration::ZERO)
                            .map(|inv| (inv.to_json(), None)))
                    })))
                }
                "fetch_result" => {
                    let id = params.str_of("id")?;
                    match coordinator.lookup(id).1.and_then(|i| i.result_key) {
                        Some(key) => {
                            let data = store.get(&key)?;
                            Ok(Outcome::Ready(Json::obj().set("len", data.len()), Some(data)))
                        }
                        None => Ok(Outcome::Ready(Json::Null, None)),
                    }
                }
                "stats" => {
                    let mut stats = ClusterStats::gather(&coordinator)?;
                    if let Some((scaler, exec)) = &autoscale {
                        stats.autoscale = scaler.stats();
                        stats.autoscale.nodes = exec.nodes();
                    }
                    stats.rpc = rpc_counters.snapshot();
                    Ok(Outcome::Ready(stats.to_json(), None))
                }
                "runtimes" => {
                    let mut names = announce.clone();
                    for key in store.list("runtimes/").unwrap_or_default() {
                        if let Some(rest) = key.strip_prefix("runtimes/") {
                            match rest.split('/').next() {
                                Some(name) if !name.is_empty() => {
                                    names.push(name.to_string())
                                }
                                _ => {}
                            }
                        }
                    }
                    names.sort();
                    names.dedup();
                    let arr = names.into_iter().map(Json::Str).collect();
                    Ok(Outcome::Ready(Json::obj().set("runtimes", Json::Arr(arr)), None))
                }
                "report" => {
                    // Node → gateway completion path.  The collector
                    // thread behind this sender stamps REnd and records
                    // the metrics — identical to the in-process channel.
                    let inv = Invocation::from_json(params.req("invocation")?)?;
                    completions
                        .send(inv)
                        .map_err(|_| anyhow!("gateway coordinator is shut down"))?;
                    Ok(Outcome::Ready(Json::obj(), None))
                }
                other => Err(anyhow!("unknown gateway method {other}")),
            })
        };
        let rpc_cfg = RpcConfig { counters: Some(rpc_counters), ..config.rpc.clone() };
        let rpc = RpcServer::serve_deferrable(addr, handler, rpc_cfg)?;

        // Housekeeping (the coordinator-side duties the single-process
        // Cluster runs): re-queue expired leases, sample queue gauges,
        // and tick the advisory elasticity controller when configured.
        // Free-slot counts live on remote nodes, so the gauge records 0.
        let stop = Arc::new(AtomicBool::new(false));
        let housekeeper = {
            let stop = stop.clone();
            let queue = queue.clone();
            let metrics = metrics.clone();
            let clock = clock.clone();
            let interval = config.housekeeping_interval;
            let autoscale = autoscale.clone();
            std::thread::Builder::new()
                .name("gateway-housekeeping".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        let _ = queue.reap_expired();
                        if let Ok(stats) = queue.stats() {
                            if let Some((scaler, exec)) = &autoscale {
                                let signals = Signals {
                                    queued: stats.queued,
                                    in_flight: stats.in_flight,
                                    classes: stats.classes.clone(),
                                    nodes: exec.nodes(),
                                    free_slots: 0,
                                    warm_instances: 0,
                                };
                                scaler.tick(&signals, clock.now(), exec.as_ref());
                            }
                            metrics.sample_gauge(clock.now(), stats, 0);
                        }
                        clock.sleep(interval);
                    }
                })?
        };

        Ok(GatewayServer {
            rpc,
            coordinator,
            metrics,
            stop,
            housekeeper: Some(housekeeper),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.rpc.addr()
    }

    /// The hosted coordinator (in-process inspection: serve loop, tests).
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coordinator
    }

    /// The gateway-side metrics hub (`REnd`-stamped records + gauges).
    pub fn metrics(&self) -> &Arc<MetricsHub> {
        &self.metrics
    }

    pub fn shutdown(&mut self) {
        self.rpc.shutdown();
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.housekeeper.take() {
            let _ = h.join();
        }
        self.coordinator.shutdown();
    }
}

impl Drop for GatewayServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// TCP implementation of [`HardlessClient`] speaking to a [`GatewayServer`].
pub struct RemoteClient {
    rpc: RpcClient,
}

impl RemoteClient {
    pub fn connect(
        addr: impl std::net::ToSocketAddrs + std::fmt::Debug,
    ) -> Result<RemoteClient> {
        // Multiplexed: many waiters share one socket (a benchmark client
        // waiting on hundreds of submissions is the common case), and a
        // restarted gateway is re-reached by redialing instead of
        // wedging every future call.
        let cfg = ClientConfig { mux: true, reconnect: true, ..ClientConfig::default() };
        Ok(RemoteClient { rpc: RpcClient::connect_with(addr, cfg)? })
    }

    /// RPC round trips issued so far (batching assertions, diagnostics).
    pub fn rpc_calls(&self) -> u64 {
        self.rpc.calls_issued()
    }
}

impl HardlessClient for RemoteClient {
    fn submit(&self, spec: EventSpec) -> Result<String> {
        let out = self
            .rpc
            .call("submit", Json::obj().set("spec", spec.to_json()))?;
        Ok(out.str_of("id")?.to_string())
    }

    fn submit_batch(&self, specs: Vec<EventSpec>) -> Result<Vec<String>> {
        let arr = specs.iter().map(|s| s.to_json()).collect();
        let out = self
            .rpc
            .call("submit_batch", Json::obj().set("specs", Json::Arr(arr)))?;
        Ok(out
            .arr_of("ids")?
            .iter()
            .filter_map(|j| j.as_str().map(String::from))
            .collect())
    }

    fn status(&self, id: &str) -> Result<SubmissionStatus> {
        SubmissionStatus::from_json(&self.rpc.call_idem("status", Json::obj().set("id", id))?)
    }

    fn wait(&self, id: &str, timeout: Duration) -> Result<Option<Invocation>> {
        // Chunked server-side blocking: each RPC parks at the gateway for
        // at most WAIT_CHUNK, far below the client read timeout, so a
        // long wait never looks like a dead server.
        poll_chunked(timeout, |chunk_ms| {
            let out = self.rpc.call_idem(
                "wait",
                Json::obj().set("id", id).set("timeout_ms", chunk_ms),
            )?;
            if out.is_null() {
                Ok(None)
            } else {
                Ok(Some(Invocation::from_json(&out)?))
            }
        })
    }

    fn fetch_result(&self, id: &str) -> Result<Option<Blob>> {
        let (out, blob) =
            self.rpc
                .call_blob("fetch_result", Json::obj().set("id", id), None)?;
        if out.is_null() {
            return Ok(None);
        }
        Ok(Some(Blob::from(blob.ok_or_else(|| {
            anyhow!("gateway fetch_result returned no payload")
        })?)))
    }

    fn cluster_stats(&self) -> Result<ClusterStats> {
        ClusterStats::from_json(&self.rpc.call_idem("stats", Json::obj())?)
    }

    fn list_runtimes(&self) -> Result<Vec<String>> {
        let out = self.rpc.call_idem("runtimes", Json::obj())?;
        Ok(out
            .arr_of("runtimes")?
            .iter()
            .filter_map(|j| j.as_str().map(String::from))
            .collect())
    }

    fn submit_pipeline(&self, spec: PipelineSpec) -> Result<String> {
        let out = self
            .rpc
            .call("submit_pipeline", Json::obj().set("pipeline", spec.to_json()))?;
        Ok(out.str_of("id")?.to_string())
    }

    fn pipeline_status(&self, id: &str) -> Result<Option<PipelineStatus>> {
        let out = self.rpc.call_idem("pipeline_status", Json::obj().set("id", id))?;
        if out.is_null() {
            Ok(None)
        } else {
            Ok(Some(PipelineStatus::from_json(&out)?))
        }
    }
}

/// Node-side completion reporting over RPC — the distributed counterpart
/// of the coordinator's in-process mpsc channel.
///
/// Reconnects on failure: a node outlives gateway restarts and network
/// blips, so a dead connection is dropped and re-dialed on the next
/// report instead of failing fast forever (an `RpcClient` poisons itself
/// after a mid-call failure by design).
pub struct RemoteReporter {
    addr: std::net::SocketAddr,
    rpc: Mutex<Option<RpcClient>>,
}

impl RemoteReporter {
    pub fn connect(
        addr: impl ToSocketAddrs + std::fmt::Debug,
    ) -> Result<RemoteReporter> {
        let resolved = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("no address for {addr:?}"))?;
        let client = RpcClient::connect(resolved)?;
        Ok(RemoteReporter { addr: resolved, rpc: Mutex::new(Some(client)) })
    }

    fn try_report(&self, inv: &Invocation) -> Result<()> {
        let mut guard = self.rpc.lock().expect("reporter poisoned");
        if guard.is_none() {
            *guard = Some(RpcClient::connect(self.addr)?);
        }
        let client = guard.as_ref().expect("just ensured");
        match client.call("report", Json::obj().set("invocation", inv.to_json())) {
            Ok(_) => Ok(()),
            Err(e) => {
                // Drop the (possibly poisoned) connection; the next
                // attempt re-dials.
                *guard = None;
                Err(e)
            }
        }
    }
}

impl CompletionSink for RemoteReporter {
    fn report(&self, inv: Invocation) -> Result<()> {
        // One immediate retry on a fresh connection covers the common
        // gateway-restart case; persistent failure surfaces to the node
        // (which logs and keeps serving), and the next report re-dials
        // again rather than staying broken.
        self.try_report(&inv).or_else(|_| self.try_report(&inv))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Status;
    use crate::queue::{MemQueue, TakeFilter};
    use crate::store::MemStore;
    use crate::util::clock::ScaledClock;
    use std::time::Instant;

    struct Rig {
        gateway: GatewayServer,
        client: RemoteClient,
        queue: Arc<MemQueue>,
        store: Arc<MemStore>,
    }

    fn rig() -> Rig {
        let clock = ScaledClock::new(100.0);
        let queue = MemQueue::new(clock.clone());
        let store = Arc::new(MemStore::new());
        let gateway = GatewayServer::serve(
            "127.0.0.1:0",
            queue.clone(),
            store.clone(),
            clock,
            GatewayConfig {
                announce_runtimes: vec!["tinyyolo".into()],
                ..GatewayConfig::default()
            },
        )
        .unwrap();
        let client = RemoteClient::connect(gateway.addr()).unwrap();
        Rig { gateway, client, queue, store }
    }

    /// Play the node role by hand: take the lease, persist a result,
    /// ack, and report completion to the gateway over RPC.
    fn complete_as_node(r: &Rig, payload: &[u8]) -> String {
        let lease = r.queue.take(&TakeFilter::default()).unwrap().unwrap();
        let mut inv = lease.invocation;
        let key = crate::store::keys::result(&inv.id);
        crate::store::ObjectStore::put(r.store.as_ref(), &key, payload).unwrap();
        inv.result_key = Some(key);
        inv.status = Status::Succeeded;
        r.queue.ack(&inv.id).unwrap();
        let reporter = RemoteReporter::connect(r.gateway.addr()).unwrap();
        let id = inv.id.clone();
        reporter.report(inv).unwrap();
        id
    }

    #[test]
    fn submit_status_wait_fetch_over_tcp() {
        let r = rig();
        let id = r
            .client
            .submit(EventSpec::new("tinyyolo", "datasets/x"))
            .unwrap();
        assert_eq!(r.client.status(&id).unwrap(), SubmissionStatus::InFlight);
        assert_eq!(r.client.cluster_stats().unwrap().queue.queued, 1);

        let completed = complete_as_node(&r, b"detections");
        assert_eq!(completed, id);

        let inv = r
            .client
            .wait(&id, Duration::from_secs(10))
            .unwrap()
            .expect("reported completion reaches the waiter");
        assert_eq!(inv.status, Status::Succeeded);
        assert!(inv.stamps.r_start.is_some(), "RStart stamped at submit");
        assert!(inv.stamps.r_end.is_some(), "REnd stamped at the gateway");
        assert!(inv.stamps.r_end >= inv.stamps.r_start);

        assert_eq!(r.client.fetch_result(&id).unwrap().unwrap(), b"detections");

        let stats = r.client.cluster_stats().unwrap();
        assert_eq!((stats.submitted, stats.completed, stats.succeeded), (1, 1, 1));
        assert_eq!(stats.inflight, 0);
        // the gateway's metrics hub recorded the REnd-stamped completion
        assert_eq!(r.gateway.metrics().len(), 1);
        assert!(r.gateway.metrics().records()[0].r_end.is_some());
    }

    #[test]
    fn batch_submit_over_one_round_trip() {
        let r = rig();
        let ids = r
            .client
            .submit_batch(
                (0..4)
                    .map(|i| EventSpec::new("tinyyolo", format!("datasets/d{i}")))
                    .collect(),
            )
            .unwrap();
        assert_eq!(ids.len(), 4);
        let unique: std::collections::BTreeSet<_> = ids.iter().collect();
        assert_eq!(unique.len(), 4);
        assert_eq!(r.client.cluster_stats().unwrap().queue.queued, 4);
    }

    #[test]
    fn wait_returns_none_on_timeout_without_hanging() {
        let r = rig();
        let id = r
            .client
            .submit(EventSpec::new("tinyyolo", "datasets/x"))
            .unwrap();
        let t0 = Instant::now();
        let got = r.client.wait(&id, Duration::from_millis(300)).unwrap();
        assert!(got.is_none());
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn rpc_transport_stats_surface_in_cluster_stats() {
        let r = rig();
        r.client
            .submit(EventSpec::new("tinyyolo", "datasets/x"))
            .unwrap();
        let stats = r.client.cluster_stats().unwrap();
        assert!(
            !stats.rpc.backend.is_empty(),
            "gateway reports its own transport: {:?}",
            stats.rpc
        );
        assert!(stats.rpc.requests >= 2, "submit + stats counted: {:?}", stats.rpc);
        assert!(stats.rpc.conns_accepted >= 1);
        // The snapshot is taken mid-request: the stats response itself is
        // not yet written, so compare against the *received* frames.
        assert!(stats.rpc.frames_in >= stats.rpc.requests);
    }

    #[test]
    fn client_survives_a_gateway_restart() {
        // A long-lived benchmark client must re-reach a restarted
        // gateway: idempotent calls redial + retry instead of failing
        // fast forever on the poisoned channel.
        let clock = ScaledClock::new(100.0);
        let queue = MemQueue::new(clock.clone());
        let store = Arc::new(MemStore::new());
        let serve = |q: Arc<MemQueue>, s: Arc<MemStore>, c: Arc<ScaledClock>, addr: &str| {
            GatewayServer::serve(addr, q, s, c, GatewayConfig::default())
        };
        let mut gw = serve(queue.clone(), store.clone(), clock.clone(), "127.0.0.1:0").unwrap();
        let addr = gw.addr().to_string();
        let client = RemoteClient::connect(gw.addr()).unwrap();
        client.cluster_stats().unwrap();
        gw.shutdown();
        // nothing listening: even the retry cannot save this call
        assert!(client.cluster_stats().is_err());
        let deadline = Instant::now() + Duration::from_secs(10);
        let _gw2 = loop {
            match serve(queue.clone(), store.clone(), clock.clone(), &addr) {
                Ok(g) => break g,
                Err(e) => {
                    assert!(Instant::now() < deadline, "could not rebind {addr}: {e:#}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        let stats = client.cluster_stats().unwrap();
        assert_eq!(stats.submitted, 0, "fresh coordinator behind the same address");
    }

    #[test]
    fn unknown_ids_are_unknown_and_resultless() {
        let r = rig();
        assert_eq!(
            r.client.status("inv-ghost").unwrap(),
            SubmissionStatus::Unknown
        );
        assert!(r.client.fetch_result("inv-ghost").unwrap().is_none());
    }

    #[test]
    fn pipeline_rpcs_chain_stages_server_side() {
        use crate::pipeline::{PipelineState, StageSpec};
        let r = rig();
        assert!(r.client.pipeline_status("pipe-ghost").unwrap().is_none());
        let pid = r
            .client
            .submit_pipeline(
                PipelineSpec::new("datasets/x")
                    .stage(StageSpec::new("a", "tinyyolo"))
                    .stage(StageSpec::new("b", "tinyyolo").after(["a"])),
            )
            .unwrap();
        // Play both stage executions by hand: stage b only appears in the
        // queue after the gateway's collector processes stage a's report.
        for _ in 0..2 {
            let lease = {
                let deadline = Instant::now() + Duration::from_secs(10);
                loop {
                    if let Some(l) = r.queue.take(&TakeFilter::default()).unwrap() {
                        break l;
                    }
                    assert!(Instant::now() < deadline, "stage never published");
                    std::thread::sleep(Duration::from_millis(2));
                }
            };
            let mut inv = lease.invocation;
            let key = crate::store::keys::result(&inv.id);
            crate::store::ObjectStore::put(r.store.as_ref(), &key, b"x").unwrap();
            inv.result_key = Some(key);
            inv.status = Status::Succeeded;
            r.queue.ack(&inv.id).unwrap();
            RemoteReporter::connect(r.gateway.addr())
                .unwrap()
                .report(inv)
                .unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        let st = loop {
            let st = r.client.pipeline_status(&pid).unwrap().expect("tracked");
            if st.state == PipelineState::Succeeded {
                break st;
            }
            assert!(Instant::now() < deadline, "stuck: {st:?}");
            std::thread::sleep(Duration::from_millis(2));
        };
        // The chain survived the wire: stage b's dataset is stage a's
        // result key.
        let a_inv = st.stages[0].invocation_id.clone().unwrap();
        assert_eq!(
            st.stages[1].dataset.as_deref(),
            Some(crate::store::keys::result(&a_inv).as_str())
        );
        let stats = r.client.cluster_stats().unwrap();
        assert_eq!(stats.pipelines, 1);
    }

    #[test]
    fn evicted_submissions_read_expired_over_the_wire() {
        let r = rig();
        r.gateway.coordinator().set_retention(1);
        let first = r
            .client
            .submit(EventSpec::new("tinyyolo", "datasets/a"))
            .unwrap();
        complete_as_node(&r, b"r1");
        r.client.wait(&first, Duration::from_secs(10)).unwrap().unwrap();
        let second = r
            .client
            .submit(EventSpec::new("tinyyolo", "datasets/b"))
            .unwrap();
        complete_as_node(&r, b"r2");
        r.client.wait(&second, Duration::from_secs(10)).unwrap().unwrap();
        // `first` was evicted by the retention window of 1: Expired, not
        // Unknown — and its result object was GC'd.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let st = r.client.status(&first).unwrap();
            if st == SubmissionStatus::Expired {
                break;
            }
            assert!(Instant::now() < deadline, "still {st:?}");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!r
            .store
            .exists(&crate::store::keys::result(&first))
            .unwrap());
        let stats = r.client.cluster_stats().unwrap();
        assert_eq!(stats.gc_deleted, 1);
        assert_eq!(stats.gc_reclaimed_bytes, 2);
        assert_eq!(
            r.client.status("inv-99999").unwrap(),
            SubmissionStatus::Unknown
        );
        assert!(matches!(
            r.client.status(&second).unwrap(),
            SubmissionStatus::Done(_)
        ));
    }

    #[test]
    fn advisory_autoscale_surfaces_in_stats() {
        let clock = ScaledClock::new(100.0);
        let queue = MemQueue::new(clock.clone());
        let store = Arc::new(MemStore::new());
        let gateway = GatewayServer::serve(
            "127.0.0.1:0",
            queue.clone(),
            store,
            clock,
            GatewayConfig {
                announce_runtimes: vec!["tinyyolo".into()],
                housekeeping_interval: Duration::from_millis(500),
                autoscale: Some(AutoscaleConfig {
                    min_nodes: 0,
                    max_nodes: 4,
                    ..AutoscaleConfig::default()
                }),
            },
        )
        .unwrap();
        let client = RemoteClient::connect(gateway.addr()).unwrap();
        // Backlog with a zero-node (virtual) fleet: the advisory
        // controller must recommend scale-out and surface it in stats.
        for i in 0..3 {
            client
                .submit(EventSpec::new("tinyyolo", format!("datasets/d{i}")))
                .unwrap();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let stats = loop {
            let stats = client.cluster_stats().unwrap();
            if stats.autoscale.scale_ups >= 1 || std::time::Instant::now() > deadline {
                break stats;
            }
            std::thread::sleep(Duration::from_millis(10));
        };
        assert!(stats.autoscale.enabled, "{:?}", stats.autoscale);
        assert!(stats.autoscale.scale_ups >= 1, "{:?}", stats.autoscale);
        assert!(stats.autoscale.nodes >= 1, "virtual fleet moved: {:?}", stats.autoscale);
        assert!(
            !stats.queue.classes.is_empty(),
            "per-class gauges cross the gateway wire: {:?}",
            stats.queue
        );
    }

    #[test]
    fn two_sharded_gateways_compose_into_one_fleet_view() {
        use crate::coordinator::Membership;
        use crate::queue::ShardedQueue;
        let clock = ScaledClock::new(100.0);
        let store = Arc::new(MemStore::new());
        let queues = [
            ShardedQueue::new(clock.clone(), 2),
            ShardedQueue::new(clock.clone(), 2),
        ];
        let gateways: Vec<GatewayServer> = queues
            .iter()
            .map(|q| {
                GatewayServer::serve(
                    "127.0.0.1:0",
                    q.clone(),
                    store.clone(),
                    clock.clone(),
                    GatewayConfig::default(),
                )
                .unwrap()
            })
            .collect();
        let clients: Vec<RemoteClient> = gateways
            .iter()
            .map(|g| RemoteClient::connect(g.addr()).unwrap())
            .collect();

        // Submits route by class through the same rendezvous registry
        // the queue shards use — every class lives wholly behind one
        // gateway, so the fleet merge never double-counts anything.
        let members = Membership::new(["gw-a".into(), "gw-b".into()]);
        let classes = ["bert", "t5", "clip", "deeplab"];
        let mut expected = std::collections::BTreeMap::new();
        for (i, class) in classes.iter().enumerate() {
            let owner = members.index_of(class).unwrap();
            for j in 0..=i {
                clients[owner]
                    .submit(EventSpec::new(*class, format!("datasets/d{j}")))
                    .unwrap();
            }
            expected.insert(*class, (owner, i + 1));
        }
        // Sanity: these four classes really spread over both gateways.
        let owners: std::collections::BTreeSet<usize> =
            expected.values().map(|(o, _)| *o).collect();
        assert_eq!(owners.len(), 2, "classes split across gateways: {expected:?}");

        // Play a node behind the gateway owning `bert`: take, ack,
        // report — the completion lands on that gateway's coordinator.
        let (bert_owner, _) = expected["bert"];
        let lease = queues[bert_owner]
            .take(&TakeFilter::supporting(vec!["bert".into()]))
            .unwrap()
            .unwrap();
        let mut inv = lease.invocation;
        inv.status = Status::Succeeded;
        queues[bert_owner].ack(&inv.id).unwrap();
        let id = inv.id.clone();
        RemoteReporter::connect(gateways[bert_owner].addr())
            .unwrap()
            .report(inv)
            .unwrap();
        clients[bert_owner].wait(&id, Duration::from_secs(10)).unwrap().unwrap();

        let fleet = ClusterStats::merge(
            clients.iter().map(|c| c.cluster_stats().unwrap()),
        );
        let total = 1 + 2 + 3 + 4;
        assert_eq!(fleet.submitted, total);
        assert_eq!(fleet.completed, 1);
        assert_eq!(fleet.inflight, total - 1);
        assert_eq!(fleet.queue.queued + fleet.queue.acked, total);
        // Both gateways' shard sections concatenate: 2 shards each.
        assert_eq!(fleet.queue.shards.len(), 4);
        assert_eq!(
            fleet.queue.shards.iter().map(|s| s.queued).sum::<usize>(),
            fleet.queue.queued
        );
        // Every still-queued class appears exactly once with its full
        // depth, sorted by runtime (bert drained, so its lane is gone).
        let got: Vec<(&str, usize)> = fleet
            .queue
            .classes
            .iter()
            .map(|c| (c.runtime.as_str(), c.queued))
            .collect();
        let want: Vec<(&str, usize)> = expected
            .iter()
            .filter(|(class, _)| **class != "bert")
            .map(|(class, (_, n))| (*class, *n))
            .collect();
        assert_eq!(got, want);
        // The fleet view survives the stats wire format round trip.
        assert_eq!(ClusterStats::from_json(&fleet.to_json()).unwrap(), fleet);
    }

    #[test]
    fn runtimes_union_announced_and_published() {
        let r = rig();
        crate::store::ObjectStore::put(
            r.store.as_ref(),
            "runtimes/tinycls/manifest.json",
            b"{}",
        )
        .unwrap();
        let names = r.client.list_runtimes().unwrap();
        assert_eq!(names, vec!["tinycls".to_string(), "tinyyolo".to_string()]);
    }
}
